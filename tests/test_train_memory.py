"""Memory-lean training path (survey §4.1.3 / §6.1 / §6.2): 1F1B pipeline
schedule vs GPipe vs single-stage equivalence + compiled-memory ordering,
remat-policy gradient equivalence across families, and the ZeRO-1 sharded
update vs the replicated-AdamW oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import InputShape, ParallelPlan, get_smoke_config
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_loss_fn, make_train_step


# ---------------------------------------------------------------------------
# 1F1B pipeline schedule


def test_1f1b_matches_gpipe_and_single_stage(multidevice):
    """Both pipeline schedules reproduce the single-stage loss and grads
    (z_loss threaded through the per-microbatch cross-entropy), and the
    compiled 1F1B backward peaks at less live memory than GPipe's
    reverse-AD-through-the-scan at M >= 2·P."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
Z = 1e-4   # nonzero so the z_loss threading is actually exercised

plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
hyper = Hyper(z_loss=Z)
ref_loss, _ = make_loss_fn(model, hyper)(params, batch)
ref_g = jax.grad(lambda p, b: make_loss_fn(model, hyper)(p, b)[0])(params, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
mems, grads = {}, {}
for sched in ("gpipe", "1f1b"):
    # M = 4 = 2·P microbatches: the acceptance point for the memory claim
    plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                        microbatches=4, pp_schedule=sched)
    lf = pipelined_loss_fn(cfg, plan, mesh, ("data",), z_loss=Z)
    loss, _ = jax.jit(lf)(params, batch)
    assert abs(float(loss) - float(ref_loss)) < 2e-4, (sched, float(loss))
    gf = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))
    compiled = gf.lower(params, batch).compile()
    ma = compiled.memory_analysis()
    if ma is not None:
        mems[sched] = ma.temp_size_in_bytes
    grads[sched] = jax.block_until_ready(gf(params, batch)[1])

for sched in ("gpipe", "1f1b"):
    for a, b in zip(jax.tree.leaves(ref_g), jax.tree.leaves(grads[sched])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5, err_msg=sched)
for a, b in zip(jax.tree.leaves(grads["gpipe"]), jax.tree.leaves(grads["1f1b"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-6)
print("1f1b == gpipe == single-stage OK")

if mems:
    assert mems["1f1b"] < mems["gpipe"], mems
    print(f"peak temp bytes: 1f1b {mems['1f1b']} < gpipe {mems['gpipe']} "
          f"({mems['1f1b']/mems['gpipe']:.2f}x)")
""")


# ---------------------------------------------------------------------------
# remat policies


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "olmoe-1b-7b", "mamba2-370m"])
def test_remat_policies_grad_equivalence(arch):
    """remat in {selective, full} must reproduce remat="none" grads exactly
    (recomputation never changes math) on dense, MoE and Mamba2 smokes."""
    cfg = get_smoke_config(arch)
    shape = InputShape("t", 16, 4, "train")
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    out = {}
    for remat in ("none", "selective", "full"):
        plan = ParallelPlan(remat=remat, compute_dtype="float32")
        model = build_model(cfg, plan)
        params = model.init(jax.random.PRNGKey(0))
        loss_fn = make_loss_fn(model, Hyper(z_loss=0.0))
        (l, _), g = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))(
            params, batch)
        out[remat] = (float(l), g)
    for remat in ("selective", "full"):
        assert abs(out["none"][0] - out[remat][0]) < 1e-5, (arch, remat)
        for a, b in zip(jax.tree.leaves(out["none"][1]),
                        jax.tree.leaves(out[remat][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{arch}/{remat}")


def test_invalid_remat_and_schedule_rejected():
    cfg = get_smoke_config("qwen1.5-4b")
    with pytest.raises(ValueError, match="remat"):
        ParallelPlan(remat="sometimes").validate(cfg)
    with pytest.raises(ValueError, match="pp_schedule"):
        ParallelPlan(pp_schedule="interleaved").validate(cfg)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "gemma2-9b", "olmoe-1b-7b",
                                  "deepseek-moe-16b", "mamba2-370m",
                                  "zamba2-1.2b", "whisper-small",
                                  "pixtral-12b"])
def test_train_step_smoke_selective_remat(arch):
    """One jitted train step per family under remat="selective" — the
    production default recipe — stays finite and actually updates params."""
    cfg = get_smoke_config(arch)
    plan = ParallelPlan(remat="selective", compute_dtype="float32")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, InputShape("t", 32, 4, "train"))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, plan, Hyper(total_steps=10)))
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(new_state.params)))
    assert delta > 0.0, arch


# ---------------------------------------------------------------------------
# ZeRO-1 sharded update


def test_zero1_update_matches_replicated_oracle(multidevice):
    """The mesh-aware train step (reduce-scattered grad accumulator + sharded
    AdamW + param all-gather) must be bit-compatible with the replicated
    update, and the new moments must come out data-sharded."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import Family, InputShape, ModelConfig, ParallelPlan, sharding
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, TrainState, init_train_state, make_train_step
from repro.optim import adamw_init

cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
hyper = Hyper(peak_lr=1e-3, total_steps=10, z_loss=0.0)
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

# oracle: replicated AdamW with grad accumulation
plan0 = ParallelPlan(remat="none", compute_dtype="float32", microbatches=4)
m0 = build_model(cfg, plan0)
s0 = init_train_state(m0, jax.random.PRNGKey(0))
ref_state, ref_metrics = jax.jit(make_train_step(m0, plan0, hyper))(s0, batch)

# ZeRO-1 on a (data=2, model=2) mesh, same microbatching
mesh = jax.make_mesh((2, 2), ("data", "model"))
plan = ParallelPlan(remat="none", compute_dtype="float32", zero_stage=1,
                    microbatches=4)
m1 = build_model(cfg, plan, mesh, ("data",))
s1 = init_train_state(m1, jax.random.PRNGKey(0))
pspecs = sharding.param_specs(s1.params, cfg, plan, mesh)
shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                     is_leaf=lambda x: isinstance(x, P))
params = jax.device_put(s1.params, shard)
state = TrainState(params, adamw_init(params))
new_state, metrics = jax.jit(make_train_step(m1, plan, hyper, mesh=mesh))(
    state, batch)

assert abs(float(metrics["loss"]) - float(ref_metrics["loss"])) < 1e-4
for a, b in zip(jax.tree.leaves(new_state.params),
                jax.tree.leaves(ref_state.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-4)
for ref_m, new_m in [(ref_state.opt.mu, new_state.opt.mu),
                     (ref_state.opt.nu, new_state.opt.nu)]:
    for a, b in zip(jax.tree.leaves(ref_m), jax.tree.leaves(new_m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-4)
print("ZeRO-1 == replicated oracle OK, loss", float(metrics["loss"]))

mu_wq = new_state.opt.mu["layers"]["attn"]["wq"]
assert not mu_wq.sharding.is_fully_replicated, mu_wq.sharding
print("moments data-sharded OK:", mu_wq.sharding.spec)
""")
