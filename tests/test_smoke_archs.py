"""Deliverable (f): per-architecture smoke tests.

Each assigned arch instantiates its REDUCED variant (2 layers, d_model<=512,
<=4 experts) and runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core import ARCH_IDS, InputShape, ParallelPlan, get_smoke_config
from repro.core.config import Family
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

SHAPE = InputShape("smoke", 32, 4, "train")


def _check_reduced(cfg):
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    _check_reduced(cfg)
    plan = ParallelPlan(remat="selective", compute_dtype="float32")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, SHAPE)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

    logits, aux = model.forward(model.init(jax.random.PRNGKey(0)), batch)
    assert logits.shape == (SHAPE.global_batch, SHAPE.seq_len, cfg.vocab)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"
    if cfg.family == Family.MOE:
        assert jnp.isfinite(aux) and aux >= 0.0

    state = init_train_state(model, jax.random.PRNGKey(0))
    step = make_train_step(model, plan, Hyper(total_steps=10))
    new_state, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch}: NaN loss"
    assert jnp.isfinite(metrics["grad_norm"]), f"{arch}: NaN grads"
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     state.params, new_state.params))
    assert delta > 0.0, f"{arch}: optimizer did not update parameters"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(1))
    b = 2
    cache = model.init_cache(b, 16)
    tokens = jnp.array([1, 2], jnp.int32)
    logits, new_cache = model.decode_step(params, cache, tokens, jnp.int32(0))
    assert logits.shape == (b, cfg.vocab)
    assert jnp.isfinite(logits).all(), f"{arch}: NaN decode logits"
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)
