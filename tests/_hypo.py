"""``hypothesis`` or a deterministic fallback.

The property tests import ``given`` / ``settings`` / ``strategies`` from here
instead of from ``hypothesis`` directly, so the suite collects and runs in
minimal environments. With the real package installed the re-exports are
exact; without it, ``given`` runs each test over a small deterministic sample
(strategy bounds first, then seeded interior draws) and ``settings`` is a
no-op.
"""

try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import functools
    import inspect
    import random

    _N_EXAMPLES = 5

    class _Strategy:
        def __init__(self, sampler, edges=()):
            self._sampler = sampler        # rng -> value
            self._edges = tuple(edges)     # always tried first

        def draws(self, n, rng):
            out = list(self._edges[:n])
            while len(out) < n:
                out.append(self._sampler(rng))
            return out

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value),
                             edges=(min_value, max_value,
                                    (min_value + max_value) // 2))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value),
                             edges=(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements), edges=elements)

    def given(*arg_strats, **kw_strats):
        def deco(test):
            sig = inspect.signature(test)
            # positional strategies fill the trailing non-keyword params
            # (hypothesis semantics); everything consumed by a strategy must
            # disappear from the wrapper's signature or pytest will go
            # looking for fixtures with those names
            free = [n for n in sig.parameters if n not in kw_strats]
            pos_names = free[len(free) - len(arg_strats):] if arg_strats else []
            strats = dict(zip(pos_names, arg_strats), **kw_strats)
            remaining = [p for n, p in sig.parameters.items()
                         if n not in strats]

            @functools.wraps(test)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xC0FFEE)
                cols = {n: s.draws(_N_EXAMPLES, rng)
                        for n, s in strats.items()}
                for i in range(_N_EXAMPLES):
                    test(*args, **kwargs,
                         **{n: c[i] for n, c in cols.items()})

            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper
        return deco

    def settings(**_kwargs):
        return lambda test: test
