"""MoE routing/dispatch invariants (single-device).

Expert-parallel equivalence (executor EP route, overlap vs blocking vs
dense routing) lives in tests/test_expert_parallel.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, strategies as st

from repro.core import Family, ModelConfig, MoEConfig
from repro.models.moe import init_moe, moe_dense, router_probs, topk_dispatch


def _cfg(e=4, k=2, cap=2.0, shared=0):
    return ModelConfig("t", Family.MOE, n_layers=1, d_model=16, n_heads=2,
                       n_kv_heads=2, d_ff=0, vocab=64,
                       moe=MoEConfig(num_experts=e, top_k=k, d_expert=8,
                                     capacity_factor=cap,
                                     num_shared_experts=shared))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_dispatch_conservation(seed, e, k):
    """Each token occupies <= k capacity slots; combine weights sum to <= 1
    (== 1 when nothing is dropped); each slot holds at most one token."""
    cfg = _cfg(e=e, k=k)
    rng = np.random.default_rng(seed)
    n = 32
    probs = jax.nn.softmax(jnp.asarray(rng.standard_normal((n, e)),
                                       jnp.float32))
    cap = max(int(n * k / e * cfg.moe.capacity_factor), 1)
    dispatch, combine = topk_dispatch(probs, cfg, cap)
    d = np.asarray(dispatch)
    c = np.asarray(combine)
    # a capacity slot is used by at most one token
    assert (d.sum(axis=0) <= 1.0 + 1e-6).all()
    # each token takes at most k slots
    assert (d.sum(axis=(1, 2)) <= k + 1e-6).all()
    # combine weights live only where dispatch does, and sum <= 1 per token
    assert (c[d == 0] == 0).all()
    assert (c.sum(axis=(1, 2)) <= 1.0 + 1e-5).all()


def test_no_dropping_at_high_capacity():
    cfg = _cfg(e=4, k=2, cap=8.0)
    rng = np.random.default_rng(0)
    n = 16
    probs = jax.nn.softmax(jnp.asarray(rng.standard_normal((n, 4)), jnp.float32))
    cap = max(int(n * 2 / 4 * 8.0), 1)
    _, combine = topk_dispatch(probs, cfg, cap)
    np.testing.assert_allclose(np.asarray(combine).sum(axis=(1, 2)), 1.0,
                               rtol=1e-5)


def test_router_aux_loss_uniform_is_minimal():
    """Aux loss is minimized (== coef) by a perfectly uniform router."""
    cfg = _cfg(e=4, k=1)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    p["router"] = jnp.zeros_like(p["router"])     # uniform logits
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 16)),
                    jnp.float32)
    _, aux = router_probs(p, x, cfg, jnp.float32)
    # E * sum(1/E * density_proxy) where proxy sums to 1 -> coef exactly
    assert abs(float(aux) - cfg.moe.aux_loss_coef) < 1e-5


def test_shared_experts_always_active():
    cfg = _cfg(e=4, k=2, shared=1)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 8, 16)),
                    jnp.float32)
    out1, _ = moe_dense(p, x, cfg, jnp.float32)
    p2 = dict(p, shared=jax.tree.map(jnp.zeros_like, p["shared"]))
    out2, _ = moe_dense(p2, x, cfg, jnp.float32)
    assert float(jnp.abs(out1 - out2).max()) > 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), cap=st.sampled_from([1.0, 1.25, 4.0]))
def test_scatter_dispatch_matches_einsum(seed, cap):
    """MegaBlocks-style index dispatch must reproduce the GShard einsum path
    exactly (same routing, same drops) — the §Perf optimization is semantics-
    preserving."""
    cfg = _cfg(e=8, k=2, cap=cap, shared=1)
    p = init_moe(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 16, 16)), jnp.float32)
    a, aux_a = moe_dense(p, x, cfg, jnp.float32, "einsum")
    b, aux_b = moe_dense(p, x, cfg, jnp.float32, "scatter")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)
    assert abs(float(aux_a) - float(aux_b)) < 1e-7
