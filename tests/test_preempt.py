"""Preemption-aware graceful shutdown (survey §8, spot/preemptible fleets).

Unit level: the PreemptionGuard handler lifecycle, the grace-budget tier
choice, marker read/write/clear, and a real in-process SIGTERM (os.kill)
through ``run_with_recovery`` — clean exit, PREEMPTED marker, flight dump,
and a ``--resume``-style second run landing bit-identical to the
uninterrupted schedule.

The matrix at the bottom delivers SIGTERM mid-run to a 2×2-mesh run of each
model family (dense, MoE, Mamba2) — once between steps and once with a
double-buffered async snapshot in flight — and asserts the same contract:
clean exit + marker + parseable flight JSON, then a bit-identical resume.
"""

import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, MemoryCheckpointTier
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.ft import FlightRecorder, Monitor, run_with_recovery
from repro.ft.preempt import (PreemptionGuard, choose_tier, clear_marker,
                              marker_path, read_marker, write_marker)
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

N_STEPS = 20
CKPT_EVERY = 5
PREEMPT_AT = 13


def _world():
    cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"))
    get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
    step_fn = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    return model, plan, step_fn, get_batch, state


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _quiet():
    return Monitor(min_history=1000, hang_min_seconds=60.0)


# ---------------------------------------------------------------------------
# Guard / tier choice / marker units


def test_guard_installs_and_restores_handlers():
    before = signal.getsignal(signal.SIGTERM)
    with PreemptionGuard(grace=5.0) as g:
        assert signal.getsignal(signal.SIGTERM) == g._handler
        assert not g.requested
    assert signal.getsignal(signal.SIGTERM) == before


def test_guard_real_signal_sets_flag_and_clock():
    with PreemptionGuard(grace=5.0) as g:
        os.kill(os.getpid(), signal.SIGUSR1)
        deadline = time.time() + 2.0
        while not g.requested and time.time() < deadline:
            time.sleep(0.01)
        assert g.requested and g.signum == signal.SIGUSR1
        assert 0.0 < g.remaining() <= 5.0


def test_guard_trigger_without_signal():
    g = PreemptionGuard(grace=9.0, signals=())
    assert g.remaining() == 9.0            # clock idle until the notice
    g.trigger()
    assert g.requested and g.signum == signal.SIGTERM


class _FakeCkpt:
    def __init__(self, snap, d2h, persist):
        self.snapshot_seconds = snap
        self.d2h_seconds = d2h
        self.persist_seconds = persist


def test_choose_tier_prefers_disk_when_it_fits():
    g = PreemptionGuard(grace=30.0, signals=())
    g.trigger()
    mem = object()
    assert choose_tier(g, _FakeCkpt(0.1, 0.1, 0.5), mem) == "disk"
    # measured disk time blows the grace budget -> RAM snapshot
    assert choose_tier(g, _FakeCkpt(10.0, 10.0, 50.0), mem) == "memory"
    # no memory tier: disk is the only option, whatever the estimate
    assert choose_tier(g, _FakeCkpt(10.0, 10.0, 50.0), None) == "disk"
    # nothing measured yet (first checkpoint): no basis to distrust disk
    assert choose_tier(g, _FakeCkpt(0.0, 0.0, 0.0), mem) == "disk"


def test_marker_roundtrip(tmp_path):
    assert read_marker(tmp_path) is None
    write_marker(tmp_path, step=17, tier="disk", signum=15,
                 flight_path="/tmp/f.json")
    mk = read_marker(tmp_path)
    assert mk["step"] == 17 and mk["tier"] == "disk" and mk["signum"] == 15
    assert not marker_path(tmp_path).with_name("PREEMPTED.tmp").exists()
    clear_marker(tmp_path)
    assert read_marker(tmp_path) is None


def test_marker_unreadable_is_none(tmp_path):
    marker_path(tmp_path).write_text("{ not json")
    assert read_marker(tmp_path) is None


# ---------------------------------------------------------------------------
# In-process SIGTERM through the driver: clean exit + marker + bit-identical
# resume (single device; the matrix below covers families on a mesh)


def test_sigterm_mid_run_resumes_bit_identical(tmp_path):
    model, plan, step_fn, get_batch, state0 = _world()

    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))

    flight = FlightRecorder(maxlen=128, path=str(tmp_path / "flight.json"))
    ckpt = CheckpointManager(tmp_path, keep=3, flight=flight)
    mem = MemoryCheckpointTier(keep=2, groups=2, flight=flight)

    def deliver(step, st):
        if step == PREEMPT_AT:
            os.kill(os.getpid(), signal.SIGTERM)
        return st

    with PreemptionGuard(grace=60.0) as guard:
        mid, report = run_with_recovery(
            state0, step_fn, get_batch, N_STEPS, ckpt, _quiet(),
            ckpt_every=CKPT_EVERY, plan=plan, fault_injector=deliver,
            mem_ckpt=mem, preempt=guard, flight=flight)

    assert report.preempted
    # the notice lands mid-step PREEMPT_AT; the driver exits at the next
    # between-steps check, so the snapshot is at PREEMPT_AT + 1
    assert report.preempt_step == PREEMPT_AT + 1
    assert report.steps_done == report.preempt_step < N_STEPS
    mk = read_marker(tmp_path)
    assert mk is not None and mk["step"] == report.preempt_step
    assert mk["tier"] == "disk"            # 60s grace: disk always fits
    assert mk["signum"] == signal.SIGTERM

    # flight black box: parseable, and it names the preemption
    fj = json.loads((tmp_path / "flight.json").read_text())
    assert fj["reason"] == "preempt"
    pe = [e for e in fj["events"] if e["kind"] == "preempt"]
    assert pe and pe[0]["step"] == report.preempt_step

    # resume (fresh process stand-in: new manager, RAM tier gone)
    resumed, report2 = run_with_recovery(
        init_train_state(model, jax.random.PRNGKey(0)), step_fn, get_batch,
        N_STEPS, CheckpointManager(tmp_path, keep=3), _quiet(),
        ckpt_every=CKPT_EVERY, plan=plan, resume=True)
    assert read_marker(tmp_path) is None   # consumed on resume
    assert report2.steps_done == N_STEPS and not report2.preempted
    _assert_trees_equal(resumed.params, ref.params)
    _assert_trees_equal(resumed.opt.mu, ref.opt.mu)


def test_preempt_short_grace_takes_memory_tier(tmp_path):
    """A grace window smaller than the measured disk persist time routes the
    just-in-time snapshot to the RAM tier (the Gemini path: on a fleet the
    peer mirrors survive the host loss)."""
    _, plan, step_fn, get_batch, state0 = _world()
    ckpt = CheckpointManager(tmp_path, keep=3)
    mem = MemoryCheckpointTier(keep=2, groups=2)
    guard = PreemptionGuard(grace=1e-9, signals=())

    def deliver(step, st):
        if step == PREEMPT_AT:
            guard.trigger()
        return st

    _, report = run_with_recovery(
        state0, step_fn, get_batch, N_STEPS, ckpt, _quiet(),
        ckpt_every=CKPT_EVERY, plan=plan, fault_injector=deliver,
        mem_ckpt=mem, preempt=guard)
    assert report.preempted
    mk = read_marker(tmp_path)
    assert mk["tier"] == "memory"
    assert mem.latest_step() == report.preempt_step


# ---------------------------------------------------------------------------
# The preemption matrix (multidevice acceptance): SIGTERM per family on a
# 2×2 mesh, between steps and mid-async-snapshot, then bit-identical resume

_PREEMPT_TEMPLATE = """
import json, os, signal, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager, MemoryCheckpointTier
from repro.core import (Family, InputShape, ModelConfig, MoEConfig, SSMConfig,
                        ParallelPlan, RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import FlightRecorder, Monitor, run_with_recovery
from repro.ft.preempt import PreemptionGuard, read_marker
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

cfg = {cfg}
plan = ParallelPlan(remat="none", compute_dtype="float32", cp=2,
                    zero_stage=1{plan_extra})
mesh = jax.make_mesh((2, 2), ("data", "cp"))
model = build_model(cfg, plan, mesh, ("data",))
ds = SyntheticDataset(cfg, InputShape("t", 16, 8, "train"))
get_batch = lambda s: {{k: jnp.asarray(v) for k, v in ds.batch(s).items()}}
hyper = Hyper(peak_lr=1e-3, total_steps=40, z_loss=0.0)
N, EVERY, PRE = 20, 5, {preempt_at}
quiet = lambda: Monitor(min_history=1000, hang_min_seconds=60.0)

step_fn = jax.jit(make_train_step(model, plan, hyper, mesh=mesh))
fresh = lambda: init_train_state(model, jax.random.PRNGKey(0),
                                 mesh=mesh, plan=plan)

ref = fresh()
for s in range(N):
    ref, _ = step_fn(ref, get_batch(s))

d = tempfile.mkdtemp()
flight = FlightRecorder(maxlen=256, path=d + "/flight.json")
ckpt = CheckpointManager(d, keep=3, async_snapshot={async_snapshot},
                         flight=flight)
mem = MemoryCheckpointTier(keep=2, groups=4, flight=flight)

def deliver(step, st):
    if step == PRE:
        os.kill(os.getpid(), signal.SIGTERM)
    return st

with PreemptionGuard(grace=120.0) as guard:
    _, report = run_with_recovery(
        fresh(), step_fn, get_batch, N, ckpt, quiet(), ckpt_every=EVERY,
        plan=plan, mesh=mesh, fault_injector=deliver,
        mem_ckpt=mem, preempt=guard, flight=flight)

assert report.preempted and report.preempt_step == PRE + 1, report
mk = read_marker(d)
assert mk is not None and mk["step"] == PRE + 1 and mk["tier"] == "disk", mk
fj = json.load(open(report.flight_path))
assert fj["reason"] == "preempt"
kinds = [e["kind"] for e in fj["events"]]
assert "preempt" in kinds and "step" in kinds, kinds

resumed, r2 = run_with_recovery(
    fresh(), step_fn, get_batch, N, CheckpointManager(d, keep=3), quiet(),
    ckpt_every=EVERY, plan=plan, mesh=mesh, resume=True)
assert read_marker(d) is None
assert r2.steps_done == N and not r2.preempted, r2
for a, b in zip(jax.tree.leaves(resumed.params), jax.tree.leaves(ref.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(resumed.opt.mu), jax.tree.leaves(ref.opt.mu)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("preempt matrix OK: clean exit, marker, flight, bit-identical resume")
"""

_DENSE_CFG = """ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab=128)"""
_MOE_CFG = """ModelConfig("tmoe", Family.MOE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                 moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                               num_shared_experts=1, capacity_factor=2.0))"""
_SSM_CFG = """ModelConfig("tssm", Family.SSM, n_layers=2, d_model=64,
                 n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                 ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8))"""


def test_preempt_matrix_dense(multidevice):
    multidevice(_PREEMPT_TEMPLATE.format(
        cfg=_DENSE_CFG, plan_extra="", preempt_at=13,
        async_snapshot="False"), n_devices=4)


def test_preempt_matrix_moe(multidevice):
    multidevice(_PREEMPT_TEMPLATE.format(
        cfg=_MOE_CFG, plan_extra="", preempt_at=13,
        async_snapshot="False"), n_devices=4)


def test_preempt_matrix_mamba2(multidevice):
    multidevice(_PREEMPT_TEMPLATE.format(
        cfg=_SSM_CFG, plan_extra="", preempt_at=13,
        async_snapshot="False"), n_devices=4)


def test_preempt_mid_async_snapshot(multidevice):
    """SIGTERM lands one step after a ckpt_every boundary with
    async_snapshot=True, so the double-buffered snapshot+persist of step 10
    is still in flight when the notice arrives: the driver's preemption
    flush (ckpt.wait) must drain it before the just-in-time snapshot."""
    multidevice(_PREEMPT_TEMPLATE.format(
        cfg=_DENSE_CFG, plan_extra="", preempt_at=10,
        async_snapshot="True"), n_devices=4)
