"""Overlap-aware tensor parallelism (survey §4.1.2/§5.2): ring collective
matmuls + sequence-sharded activations vs the GSPMD baseline.

Equivalence contract: ``tp_impl="overlap"`` computes the *same math* as
``tp_impl="gspmd"`` — same per-token contractions, two-term partial sums, and
psum-of-sums loss reduction. The loss usually reproduces bitwise and is
asserted to ~1 ulp of fp32; gradients are asserted at float-reassociation
tolerance (measured worst ≈ 1e-6 relative) since XLA fuses the ring tiles and
the partitioned GSPMD program differently, which legitimately reassociates
fp32 accumulations.
"""

import jax
import numpy as np
import pytest

from repro.core import Family, ModelConfig, MoEConfig, ParallelPlan, SSMConfig
from repro.kernels.dispatch import select_tp_impl


# ---------------------------------------------------------------------------
# dispatch rules (in-process: no devices needed)


def test_tp_impl_knob_validation():
    cfg = ModelConfig("t", Family.DENSE, 2, 64, 4, 4, 128, 128)
    with pytest.raises(ValueError, match="tp_impl"):
        ParallelPlan(tp_impl="bogus").validate(cfg)
    ParallelPlan(tp_impl="overlap").validate(cfg)   # knob itself is legal


def test_select_tp_impl_resolves_by_backend(monkeypatch):
    with pytest.raises(ValueError, match="tp_impl"):
        select_tp_impl("pallas")                    # not a TP impl name
    assert select_tp_impl("gspmd") == "gspmd"
    assert select_tp_impl("overlap") == "overlap"
    # auto: overlap only on TPU backends (ring ppermutes compile to async
    # DMAs there); gspmd elsewhere
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert select_tp_impl("auto") == "gspmd"
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert select_tp_impl("auto") == "overlap"


def test_overlap_support_preconditions():
    from repro.train.tensor_parallel import check_overlap_support
    ok = ModelConfig("t", Family.DENSE, 2, 64, 4, 2, 128, 128)
    check_overlap_support(ok, ParallelPlan(tp_impl="overlap"), 2)
    # odd kv-head count can't shard 2 ways
    bad_heads = ModelConfig("t", Family.DENSE, 2, 64, 4, 1, 128, 128)
    with pytest.raises(ValueError, match="heads"):
        check_overlap_support(bad_heads, ParallelPlan(), 2)
    # vocab must divide tp (or be padded to it)
    bad_vocab = ModelConfig("t", Family.DENSE, 2, 64, 4, 2, 128, 129)
    with pytest.raises(ValueError, match="vocab"):
        check_overlap_support(bad_vocab, ParallelPlan(), 2)
    check_overlap_support(bad_vocab, ParallelPlan(pad_vocab_to_multiple=2), 2)
    # hybrid family stays on the GSPMD path
    hyb = ModelConfig("t", Family.HYBRID, 2, 64, 4, 2, 128, 128,
                      ssm=SSMConfig(d_state=16), shared_attn_every=2)
    with pytest.raises(ValueError, match="family"):
        check_overlap_support(hyb, ParallelPlan(), 2)
    # multi-group Mamba2 B/C can't replicate per-head
    ssm2 = ModelConfig("t", Family.SSM, 2, 64, 0, 0, 0, 128,
                       ssm=SSMConfig(d_state=16, head_dim=16, n_groups=2))
    with pytest.raises(ValueError, match="n_groups"):
        check_overlap_support(ssm2, ParallelPlan(), 2)


def test_overlap_param_specs_classification():
    from jax.sharding import PartitionSpec as P
    from repro.core.sharding import overlap_spec_for_param
    cfg = ModelConfig("t", Family.DENSE, 2, 64, 4, 2, 128, 128)
    assert overlap_spec_for_param(("layers", "attn", "wq"), (2, 64, 64),
                                  cfg) == P(None, None, "model")
    assert overlap_spec_for_param(("layers", "attn", "wo"), (2, 64, 64),
                                  cfg) == P(None, "model", None)
    assert overlap_spec_for_param(("embed", "tok"), (128, 64),
                                  cfg) == P("model", None)
    assert overlap_spec_for_param(("lm_head", "w"), (64, 128),
                                  cfg) == P(None, "model")
    assert overlap_spec_for_param(("layers", "moe", "experts", "gate"),
                                  (2, 4, 64, 64), cfg) == \
        P(None, None, None, "model")
    assert overlap_spec_for_param(("layers", "moe", "experts", "down"),
                                  (2, 4, 64, 64), cfg) == \
        P(None, None, "model", None)
    # norm scales / SSM per-head leaves stay replicated (sliced in-block)
    assert overlap_spec_for_param(("layers", "norm1", "scale"), (2, 64),
                                  cfg) == P(None, None)
    assert overlap_spec_for_param(("layers", "ssm", "A_log"), (2, 8),
                                  cfg) == P(None, None)


# ---------------------------------------------------------------------------
# ring primitive unit tests


def test_ring_collective_matmuls(multidevice):
    """all_gather_matmul / matmul_reduce_scatter / ring_reduce_scatter against
    the dense references, forward and grad, on a 2-rank ring."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.train.tensor_parallel import (RingCtx, all_gather_matmul,
                                         matmul_reduce_scatter,
                                         ring_all_gather, ring_reduce_scatter)

rng = np.random.default_rng(0)
B, S, D, F, T = 2, 8, 6, 10, 2
x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
w1 = jnp.asarray(rng.standard_normal((D, F)), jnp.float32)
w2 = jnp.asarray(rng.standard_normal((F, D)), jnp.float32)
mesh = jax.make_mesh((T,), ("model",))
ctx = RingCtx("model", T)

def fwd(xl, w1l, w2l):
    (o1,), xg = all_gather_matmul(ctx, xl, (w1l,))
    o2 = matmul_reduce_scatter(ctx, o1, w2l)
    rs = ring_reduce_scatter(ctx, xg)      # sum of T identical copies = T*x
    return o1, o2, xg, rs

o1, o2, xg, rs = jax.jit(shard_map(fwd, mesh=mesh,
    in_specs=(P(None, "model", None), P(None, "model"), P("model", None)),
    out_specs=(P(None, None, "model"), P(None, "model", None), P(),
               P(None, "model", None))))(x, w1, w2)
# column GEMM tiles reproduce the full GEMM bitwise (row-blocking only)
np.testing.assert_array_equal(np.asarray(o1), np.asarray(x @ w1))
np.testing.assert_array_equal(np.asarray(xg), np.asarray(x))
np.testing.assert_array_equal(np.asarray(rs), T * np.asarray(x))
# row GEMM: two-term ring sum vs one fused chain — reassociation only
np.testing.assert_allclose(np.asarray(o2), np.asarray((x @ w1) @ w2),
                           rtol=1e-5, atol=1e-6)

def ring_loss(x, w1, w2):
    def l(xl, w1l, w2l):
        (o1,), _ = all_gather_matmul(ctx, xl, (w1l,))
        o2 = matmul_reduce_scatter(ctx, o1, w2l)
        return jax.lax.psum(jnp.sum(jnp.sin(o2)), "model")[None]
    return shard_map(l, mesh=mesh,
                     in_specs=(P(None, "model", None), P(None, "model"),
                               P("model", None)),
                     out_specs=P())(x, w1, w2)[0]

ref = lambda x, w1, w2: jnp.sum(jnp.sin((x @ w1) @ w2))
ga = jax.jit(jax.grad(ref, argnums=(0, 1, 2)))(x, w1, w2)
gb = jax.jit(jax.grad(ring_loss, argnums=(0, 1, 2)))(x, w1, w2)
for name, a, b in zip("x w1 w2".split(), ga, gb):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-6, err_msg=name)
print("ring collective matmuls OK")
""")


# ---------------------------------------------------------------------------
# overlap == gspmd, per family


_FAMILY_EQUIV_TEMPLATE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (Family, InputShape, ModelConfig, MoEConfig, SSMConfig,
                        ParallelPlan, sharding)
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.tensor_parallel import make_tp_loss_fn

cfg = {cfg}
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {{k: jnp.asarray(v) for k, v in ds.batch(0).items()}}
Z = 1e-4   # nonzero: the z_loss threading through cross_entropy_vp matters

for mesh_shape in [(1, 2), (2, 2)]:
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    plan = ParallelPlan(remat="none", compute_dtype="float32", tp=2,
                        tp_impl="overlap", moe_dispatch={dispatch!r})
    model = build_model(cfg, plan, mesh, ("data",))
    params = model.init(jax.random.PRNGKey(0))
    # gspmd baseline: annotation-sharded params/batch through XLA's partitioner
    pspecs = sharding.param_specs(params, cfg, plan, mesh)
    shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    gp = jax.device_put(params, shard)
    gb = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    lf_g = make_loss_fn(model, Hyper(z_loss=Z))
    g_loss, g_grads = jax.jit(
        jax.value_and_grad(lambda p, b: lf_g(p, b)[0]))(gp, gb)
    lf_o = make_tp_loss_fn(cfg, plan, mesh, ("data",), z_loss=Z)
    o_loss, o_grads = jax.jit(
        jax.value_and_grad(lambda p, b: lf_o(p, b)[0]))(gp, gb)
    assert abs(float(g_loss) - float(o_loss)) < 2e-6, (
        mesh_shape, float(g_loss), float(o_loss))
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(g_grads),
            jax.tree_util.tree_leaves_with_path(o_grads)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6,
            err_msg=f"{{mesh_shape}} {{jax.tree_util.keystr(path)}}")
    print(mesh_shape, "overlap == gspmd, loss", float(o_loss))
"""

_DENSE_CFG = """ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab=128)"""
# capacity_factor >= E/top_k -> no drops: overlap routes per data shard while
# gspmd routes globally, so drop *decisions* may differ; with no drops the
# per-token math is identical (tested), and the aux loss reduces globally
_MOE_CFG = """ModelConfig("tmoe", Family.MOE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                 moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                               num_shared_experts=1, capacity_factor=2.0))"""
_SSM_CFG = """ModelConfig("tssm", Family.SSM, n_layers=2, d_model=64,
                 n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                 ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8))"""


def test_overlap_matches_gspmd_dense(multidevice):
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_DENSE_CFG,
                                              dispatch="einsum"))


def test_overlap_matches_gspmd_moe(multidevice):
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_MOE_CFG,
                                              dispatch="einsum"))


def test_overlap_matches_gspmd_moe_scatter(multidevice):
    """The MegaBlocks-style scatter dispatch path through the executor's
    moe_block_ex."""
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_MOE_CFG,
                                              dispatch="scatter"))


def test_overlap_matches_gspmd_mamba2(multidevice):
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_SSM_CFG,
                                              dispatch="einsum"))


# ---------------------------------------------------------------------------
# TP x PP composition + train-step routing


def test_tp_pp_composition(multidevice):
    """Overlap rings inside each pipeline tick: TP x PP under both schedules
    reproduces the single-device loss/grads (the 1F1B custom-VJP backward
    splits its replicated-loss seed across the tp ranks)."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
Z = 1e-4
plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
ref_loss, _ = make_loss_fn(model, Hyper(z_loss=Z))(params, batch)
ref_g = jax.grad(lambda p, b: make_loss_fn(model, Hyper(z_loss=Z))(p, b)[0])(
    params, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for sched in ("gpipe", "1f1b"):
    plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2, tp=2,
                        microbatches=4, pp_schedule=sched, tp_impl="overlap")
    lf = pipelined_loss_fn(cfg, plan, mesh, ("data",), z_loss=Z)
    loss, grads = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))(
        params, batch)
    assert abs(float(loss) - float(ref_loss)) < 2e-6, (sched, float(loss))
    for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(ref_g),
                                 jax.tree_util.tree_leaves_with_path(grads)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6,
            err_msg=f"{sched} {jax.tree_util.keystr(path)}")
    print(sched, "TP x PP == single-device OK")
""")


def test_tp_pp_moe_aux(multidevice):
    """Pipelined MoE counts every stage's load-balancing aux (each stage owns
    its own routers), matching the per-microbatch single-device reference —
    under both schedules, with the overlap rings inside the ticks."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, MoEConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tmoe", Family.MOE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=0, vocab=128,
                  moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                                capacity_factor=2.0))   # no drops
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))

# reference: per-microbatch losses averaged (routing/aux are microbatch-local
# statistics, the same semantics grad accumulation uses)
M = 4
lf = make_loss_fn(model, Hyper(z_loss=0.0))
mb = {k: v.reshape((M, v.shape[0] // M) + v.shape[1:]) for k, v in batch.items()}
ref = np.mean([float(lf(params, {k: v[i] for k, v in mb.items()})[0])
               for i in range(M)])

mesh = jax.make_mesh((2, 1, 2), ("pod", "data", "model"))
for sched in ("gpipe", "1f1b"):
    plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2, tp=2,
                        microbatches=M, pp_schedule=sched, tp_impl="overlap")
    pf = pipelined_loss_fn(cfg, plan, mesh, ("data",), z_loss=0.0)
    loss, aux = jax.jit(pf)(params, batch)
    assert float(aux["moe_aux"]) > 0.0, (sched, aux)   # all stages counted
    assert abs(float(loss) - ref) < 5e-5, (sched, float(loss), ref)
    print(sched, "pipelined MoE loss+aux ==", float(loss), "ref", ref)
""")


def test_train_step_routes_overlap(multidevice):
    """make_train_step(mesh=...) with tp_impl='overlap' swaps in the ring
    loss and still matches the GSPMD step (params after one ZeRO-1 update),
    and remat policies compose with the ring custom-VJPs."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import Family, InputShape, ModelConfig, ParallelPlan, sharding
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.optim import adamw_init
from repro.train import Hyper, TrainState, make_train_step
from repro.train.tensor_parallel import make_tp_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
mesh = jax.make_mesh((2, 2), ("data", "model"))
hyper = Hyper(peak_lr=1e-3, total_steps=10, z_loss=1e-4)

plan_g = ParallelPlan(remat="none", compute_dtype="float32", tp=2, zero_stage=1)
plan_o = ParallelPlan(remat="none", compute_dtype="float32", tp=2, zero_stage=1,
                      tp_impl="overlap")
model_g = build_model(cfg, plan_g, mesh, ("data",))
params = model_g.init(jax.random.PRNGKey(0))
pspecs = sharding.param_specs(params, cfg, plan_g, mesh)
shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                     is_leaf=lambda x: isinstance(x, P))
gp = jax.device_put(params, shard)
gb = jax.device_put(batch, NamedSharding(mesh, P("data", None)))

sg, _ = jax.jit(make_train_step(model_g, plan_g, hyper, mesh=mesh))(
    TrainState(gp, adamw_init(gp)), gb)
model_o = build_model(cfg, plan_o, mesh, ("data",))
so, met = jax.jit(make_train_step(model_o, plan_o, hyper, mesh=mesh))(
    TrainState(gp, adamw_init(gp)), gb)
for a, b in zip(jax.tree.leaves(sg.params), jax.tree.leaves(so.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)
print("overlap train step == gspmd train step, loss", float(met["loss"]))

# remat policies through the ring custom-VJPs
g0 = None
for remat in ("none", "selective", "full"):
    pl = ParallelPlan(remat=remat, compute_dtype="float32", tp=2,
                      tp_impl="overlap")
    lf = make_tp_loss_fn(cfg, pl, mesh, ("data",), z_loss=0.0)
    g = jax.jit(jax.grad(lambda p, b: lf(p, b)[0]))(params, batch)
    if g0 is None:
        g0 = g
    else:
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6, err_msg=remat)
print("remat none == selective == full under overlap OK")
""")
