"""Deterministic fault injection + SDC defense (survey §8.1/§8.2).

Unit level: FaultSpec determinism, corrupt_array semantics, faulty-twin
tracing, kernel-dispatch fault points, Monitor inf handling, atomic
checkpoint writes, persist retry/backoff, and newest-intact fallback
restores through ``run_with_recovery``.

The headline acceptance is the **chaos matrix** at the bottom: every fault
class — state spike, host hang, NaN ring-payload corruption, rank-masked
SDC at the integrity checksum, and a silently dropped shard write — is
injected at a scheduled step into a 2×2-mesh run of each model family
(dense, MoE, Mamba2) with ``plan.integrity = "audit"`` + ZeRO-1; every
fault is detected, recovered per the policy table, and the final state
bit-matches the fault-free schedule.

The ``slow`` rows extend the matrix with the fail-slow class (survey
§8.1): a seeded, rank-masked delay on one context-parallel ring rank per
family, detected and attributed to ``(rank=1, cp.ring, comm)`` by the
straggler telemetry within its confirm window; delays cost wall clock but
corrupt nothing, so the run still bit-matches the fault-free schedule.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.store import CorruptCheckpointError
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import Monitor, RecoveryExhausted, run_with_recovery
from repro.ft.inject import (CONTROLLER, FaultSpec, InjectedFault, armed,
                             corrupt_array, make_injector, taint,
                             trace_with_faults)
from repro.ft.integrity import replica_divergence, tree_checksum
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

N_STEPS = 20
CKPT_EVERY = 5


def _world():
    cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"))
    get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
    step_fn = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    return model, plan, step_fn, get_batch, state


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# FaultSpec / corrupt_array / taint units


def test_fault_spec_validates_point_and_kind():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("no.such.point", "nan")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("train.step", "gremlin")


def test_fault_spec_key_is_stable():
    a = FaultSpec("train.step", "bitflip", step=7, seed=3)
    b = FaultSpec("train.step", "bitflip", step=7, seed=3)
    c = FaultSpec("train.step", "bitflip", step=7, seed=4)
    assert a.key() == b.key() != c.key()


def test_corrupt_array_bitflip_deterministic():
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8) + 1.0
    sp = FaultSpec("kernel.attention", "bitflip", step=5, seed=1)
    a = np.asarray(corrupt_array(x, sp))
    b = np.asarray(corrupt_array(x, sp))
    np.testing.assert_array_equal(a, b)          # replayable bit-for-bit
    diff = (a != np.asarray(x)).sum()
    assert diff == 1                              # exactly one element flipped
    # the flip hits a high exponent bit: the damage is loud, not subtle
    bad = a[a != np.asarray(x)][0]
    ref = np.asarray(x)[a != np.asarray(x)][0]
    assert abs(bad) > 4 * abs(ref) or abs(bad) < abs(ref) / 4


def test_corrupt_array_nan_poisons_one_element():
    x = jnp.ones((4, 4), jnp.float32)
    out = np.asarray(corrupt_array(
        x, FaultSpec("kernel.attention", "nan", step=3)))
    assert np.isnan(out).sum() == 1


def test_taint_is_identity_when_unarmed():
    x = jnp.ones((3,))
    np.testing.assert_array_equal(np.asarray(taint("tp.ring.tick", x)),
                                  np.asarray(x))
    with pytest.raises(ValueError, match="unknown fault point"):
        taint("not.registered", x)


def test_trace_with_faults_builds_faulty_twin_and_disarms():
    def fn(x):
        return taint("tp.ring.tick", x) * 2.0

    x = jnp.ones((4,), jnp.float32)
    twin = trace_with_faults(
        fn, x, specs=[FaultSpec("tp.ring.tick", "nan", step=0, tick=None)])
    assert np.isnan(np.asarray(twin(x))).any()
    # the controller is clean on exit: a fresh trace is the identity
    assert not CONTROLLER._specs
    assert not np.isnan(np.asarray(jax.jit(fn)(x))).any()


@pytest.mark.parametrize("which", ["attention", "expert_gemm", "ssd"])
def test_kernel_dispatch_fault_points(which):
    """Each dispatcher's output routes through its named fault point: a nan
    armed at trace time lands in the faulty twin's output and nowhere else."""
    from repro.kernels.dispatch import (dispatch_attention,
                                        dispatch_expert_gemm,
                                        dispatch_ssd_scan)
    if which == "attention":
        q = jnp.ones((1, 8, 2, 8), jnp.float32)
        fn = lambda: dispatch_attention(q, q, q, impl="xla")
    elif which == "expert_gemm":
        x = jnp.ones((2, 4, 8), jnp.float32)
        w = jnp.ones((2, 8, 8), jnp.float32)
        fn = lambda: dispatch_expert_gemm(x, w, impl="xla")
    else:
        xs = jnp.ones((1, 8, 2, 4), jnp.float32)
        dt = jnp.full((1, 8, 2), 0.1, jnp.float32)
        A = -jnp.ones((2,), jnp.float32)
        B = jnp.ones((1, 8, 1, 4), jnp.float32)
        fn = lambda: dispatch_ssd_scan(xs, dt, A, B, B, chunk=4, impl="xla")[0]
    point = {"attention": "kernel.attention",
             "expert_gemm": "kernel.expert_gemm",
             "ssd": "kernel.ssd"}[which]
    clean = np.asarray(jax.jit(fn)())
    assert not np.isnan(clean).any()
    twin = trace_with_faults(
        fn, specs=[FaultSpec(point, "nan", step=0, tick=None)])
    assert np.isnan(np.asarray(twin())).any()


def test_make_injector_fires_once_per_times():
    model, _, _, _, state = _world()
    inj = make_injector([FaultSpec("train.step", "nan", step=3, times=1)])
    poisoned = inj(3, state)
    assert any(np.isnan(np.asarray(l)).any()
               for l in jax.tree.leaves(poisoned.params))
    again = inj(3, state)                        # times=1: second pass clean
    _assert_trees_equal(again.params, state.params)


# ---------------------------------------------------------------------------
# Integrity checksums


def test_tree_checksum_exact_single_bit():
    t = {"w": jnp.arange(256, dtype=jnp.float32)}
    base = int(tree_checksum(t))
    flipped = np.asarray(t["w"]).copy()
    flipped_view = flipped.view(np.uint32)
    flipped_view[17] ^= 1                         # lowest mantissa bit
    assert int(tree_checksum({"w": jnp.asarray(flipped)})) != base


def test_replica_divergence_trivial_mesh_is_zero():
    cs, div = replica_divergence({"w": jnp.ones((8,))}, mesh=None)
    assert float(div) == 0.0
    assert int(cs) == int(tree_checksum({"w": jnp.ones((8,))}))


def test_plan_integrity_knob_validated():
    cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    ParallelPlan(integrity="audit").validate(cfg)
    with pytest.raises(ValueError, match="integrity"):
        ParallelPlan(integrity="paranoid").validate(cfg)


def test_integrity_audit_metrics_single_device():
    cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(remat="none", compute_dtype="float32",
                        integrity="audit")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    step_fn = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    _, metrics = step_fn(state, batch)
    assert float(metrics["integrity_div"]) == 0.0
    assert "integrity_checksum" in metrics


# ---------------------------------------------------------------------------
# Monitor: inf is as dead as nan


def test_monitor_inf_loss_is_nan_kind():
    m = Monitor()
    a = m.record(0, float("inf"), 1.0, now=0.0)
    assert a is not None and a.kind == "nan"


def test_monitor_neg_inf_grad_norm_is_nan_kind():
    m = Monitor()
    a = m.record(0, 2.0, float("-inf"), now=0.0)
    assert a is not None and a.kind == "nan"
    assert len(m.losses) == 0     # an anomalous step never enters the window


# ---------------------------------------------------------------------------
# Checkpoint store: atomicity, manifest digests, retry/backoff


def test_persist_is_atomic_no_temp_residue(tmp_path):
    mgr = CheckpointManager(tmp_path, async_persist=False)
    mgr.save(1, {"w": jnp.ones((16,))}, blocking=True)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["ckpt_00000001.json", "ckpt_00000001.npz"]
    man = mgr.manifest(1)
    m0 = man["shards"][0][0]
    assert {"crc32", "dtype", "shape", "checksum"} <= set(m0)


def test_truncated_shard_file_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, async_persist=False)
    mgr.save(1, {"w": jnp.arange(4096, dtype=jnp.float32)}, blocking=True)
    npz = tmp_path / "ckpt_00000001.npz"
    npz.write_bytes(npz.read_bytes()[: npz.stat().st_size // 2])
    with pytest.raises(CorruptCheckpointError):
        mgr.restore({"w": jnp.zeros((4096,), jnp.float32)})


def test_corrupted_manifest_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, async_persist=False)
    mgr.save(1, {"w": jnp.ones((8,))}, blocking=True)
    (tmp_path / "ckpt_00000001.json").write_text("{ not json")
    with pytest.raises(CorruptCheckpointError, match="manifest"):
        mgr.restore({"w": jnp.zeros((8,), jnp.float32)})


def test_bitflipped_shard_detected_as_checksum_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path, async_persist=False)
    mgr.save(1, {"w": jnp.arange(64, dtype=jnp.float32)}, blocking=True)
    npz = tmp_path / "ckpt_00000001.npz"
    data = dict(np.load(npz))
    bits = data["a0"].view(np.uint32)
    bits[7] ^= 1 << 30                            # one flipped bit on disk
    np.savez(str(npz)[:-4], **data)
    with pytest.raises(IOError, match="checksum"):
        mgr.restore({"w": jnp.zeros((64,), jnp.float32)})


def test_persist_retry_recovers_transient_failure(tmp_path):
    """One injected persist exception with io_retries=3: the retry loop
    absorbs it and the checkpoint lands intact."""
    mgr = CheckpointManager(tmp_path, async_persist=False, io_retries=3,
                            io_backoff=0.01)
    tree = {"w": jnp.arange(32, dtype=jnp.float32)}
    with armed([FaultSpec("ckpt.persist", "persist_exc", step=1, times=1)]):
        mgr.save(1, tree, blocking=True)
    _, got = mgr.restore({"w": jnp.zeros((32,), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_persist_retry_exhaustion_raises(tmp_path):
    mgr = CheckpointManager(tmp_path, async_persist=False, io_retries=2,
                            io_backoff=0.01)
    with armed([FaultSpec("ckpt.persist", "persist_exc", step=1, times=99)]):
        with pytest.raises(InjectedFault):
            mgr.save(1, {"w": jnp.ones((8,))}, blocking=True)
    assert mgr.latest_step() is None              # nothing half-written


def test_dropped_shard_write_leaves_listed_but_corrupt(tmp_path):
    """drop_write is *silent*: the manifest lists the checkpoint (that is the
    point — the writer saw no error), restore detects the missing npz."""
    mgr = CheckpointManager(tmp_path, async_persist=False)
    with armed([FaultSpec("ckpt.shard_write", "drop_write", step=1)]):
        mgr.save(1, {"w": jnp.ones((8,))}, blocking=True)
    assert mgr.steps() == [1]
    with pytest.raises(CorruptCheckpointError):
        mgr.restore({"w": jnp.zeros((8,), jnp.float32)})


# ---------------------------------------------------------------------------
# run_with_recovery: fallback restores, ckpt_io policy, exhaustion


def test_recovery_falls_back_to_intact_checkpoint(tmp_path):
    """A dropped shard write at the step-10 save + a NaN at step 13: the
    rollback skips the corrupt latest (10) and replays from 5."""
    model, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    injector = make_injector([FaultSpec("train.step", "nan", step=13)])
    with armed([FaultSpec("ckpt.shard_write", "drop_write", step=10)]):
        final, report = run_with_recovery(
            state, step_fn, get_batch, N_STEPS, ckpt,
            Monitor(min_history=4, hang_min_seconds=30.0),
            ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
            policy=RecoveryPolicy())

    assert report.restores == 1
    assert report.ckpt_fallbacks == 1
    assert (13, "nan", "rollback") in report.actions
    assert any(a.kind == "ckpt_corrupt" for a in report.anomalies)
    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))
    _assert_trees_equal(final.params, ref.params)
    _assert_trees_equal(final.opt.mu, ref.opt.mu)


def test_recovery_truncated_latest_falls_back(tmp_path):
    model, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    injector = make_injector([FaultSpec("train.step", "nan", step=13)])
    with armed([FaultSpec("ckpt.shard_write", "truncate_write", step=10)]):
        final, report = run_with_recovery(
            state, step_fn, get_batch, N_STEPS, ckpt,
            Monitor(min_history=4, hang_min_seconds=30.0),
            ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
            policy=RecoveryPolicy())
    assert report.restores == 1 and report.ckpt_fallbacks == 1
    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))
    _assert_trees_equal(final.params, ref.params)


def test_recovery_ckpt_io_anomaly_ignored_by_default(tmp_path):
    """Exhausted persist retries surface as a ckpt_io anomaly; the default
    policy keeps training (the run itself is healthy)."""
    model, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False,
                             io_retries=2, io_backoff=0.01)
    with armed([FaultSpec("ckpt.persist", "persist_exc", step=5, times=99)]):
        final, report = run_with_recovery(
            state, step_fn, get_batch, N_STEPS, ckpt,
            Monitor(min_history=4, hang_min_seconds=30.0),
            ckpt_every=CKPT_EVERY, plan=plan, policy=RecoveryPolicy())
    assert (5, "ckpt_io", "ignore") in report.actions
    assert any(a.kind == "ckpt_io" for a in report.anomalies)
    assert report.restores == 0
    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))
    _assert_trees_equal(final.params, ref.params)


def test_recovery_exhaustion_attaches_anomaly(tmp_path):
    """max_restores exhaustion raises RecoveryExhausted carrying the anomaly
    that forced the refused restore (kind + step for postmortems)."""
    _, plan, step_fn, get_batch, state = _world()
    injector = make_injector(
        [FaultSpec("train.step", "nan", step=13, times=99)])
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    with pytest.raises(RecoveryExhausted, match="giving up after 2") as ei:
        run_with_recovery(
            state, step_fn, get_batch, N_STEPS, ckpt,
            Monitor(min_history=4, hang_min_seconds=30.0),
            ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
            policy=RecoveryPolicy(max_restores=2))
    assert ei.value.restores == 2
    assert ei.value.anomaly is not None
    assert ei.value.anomaly.kind == "nan"
    assert ei.value.anomaly.step == 13


def test_recovery_all_checkpoints_corrupt_raises(tmp_path):
    _, plan, step_fn, get_batch, state = _world()
    injector = make_injector([FaultSpec("train.step", "nan", step=7)])
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    with armed([FaultSpec("ckpt.shard_write", "drop_write", step=0),
                FaultSpec("ckpt.shard_write", "drop_write", step=5)]):
        with pytest.raises(CorruptCheckpointError):
            run_with_recovery(
                state, step_fn, get_batch, N_STEPS, ckpt,
                Monitor(min_history=4, hang_min_seconds=30.0),
                ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
                policy=RecoveryPolicy())


# ---------------------------------------------------------------------------
# The chaos matrix (multidevice acceptance)

_CHAOS_TEMPLATE = """
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, MoEConfig, SSMConfig,
                        ParallelPlan, RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import Monitor, run_with_recovery
from repro.ft.inject import FaultSpec, armed, make_injector, trace_with_faults
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

cfg = {cfg}
plan = ParallelPlan(remat="none", compute_dtype="float32", cp=2,
                    zero_stage=1, integrity="audit"{plan_extra})
mesh = jax.make_mesh((2, 2), ("data", "cp"))
model = build_model(cfg, plan, mesh, ("data",))
ds = SyntheticDataset(cfg, InputShape("t", 16, 8, "train"))
get_batch = lambda s: {{k: jnp.asarray(v) for k, v in ds.batch(s).items()}}
hyper = Hyper(peak_lr=1e-3, total_steps=40, z_loss=0.0)
N, EVERY = 20, 5

raw_step = make_train_step(model, plan, hyper, mesh=mesh)
step_fn = jax.jit(raw_step)
state0 = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)

# fixed-point layouts: trace the faulty twins on a state the step itself
# produced, so mid-run twin calls hit the compiled trace, never a re-trace
# (a re-trace outside the armed window would silently drop the corruption)
probe, _ = step_fn(state0, get_batch(0))
jax.block_until_ready(jax.tree.leaves(probe))

# scheduled faults: one per class, replayable bit-identically
nan_twin = trace_with_faults(
    raw_step, probe, get_batch(12),
    specs=[FaultSpec("{payload_point}", "nan", step=12, tick=None)])
sdc_twin = trace_with_faults(
    raw_step, probe, get_batch(14),
    specs=[FaultSpec("integrity.checksum", "bitflip", step=14, tick=None,
                     rank=0, axis="cp")])

used = {{12: 0, 14: 0, 17: 0}}
def fault_step_fn(step):
    if step in (12, 17) and used[step] < 1:
        used[step] += 1
        return nan_twin
    if step == 14 and used[14] < 1:
        used[14] += 1
        return sdc_twin
    return None

injector = make_injector([
    FaultSpec("train.step", "spike", step=8, scale=8.0),
    FaultSpec("train.step", "hang", step=18, sleep_s=1.0),
])

ckpt = CheckpointManager(tempfile.mkdtemp(), keep=3, async_persist=False)
monitor = Monitor(min_history=4, hang_min_seconds=0.3)
with armed([FaultSpec("ckpt.shard_write", "drop_write", step=15)]):
    final, report = run_with_recovery(
        state0, step_fn, get_batch, N, ckpt, monitor, ckpt_every=EVERY,
        plan=plan, mesh=mesh, policy=RecoveryPolicy(max_restores=8),
        fault_injector=injector, fault_step_fn=fault_step_fn)

assert report.actions == [
    (8, "spike", "rollback"),      # state spike -> statistical detector
    (12, "nan", "rollback"),       # ring-payload NaN -> nan detector
    (14, "sdc", "rollback"),       # rank-masked checksum flip -> sdc
    (17, "nan", "rollback"),       # second payload fault, after the
                                   # silently-dropped step-15 shard write
    (18, "hang", "ignore"),        # host hang -> watchdog, advisory
], report.actions
assert report.restores == 4, report
assert report.ckpt_fallbacks == 1, report      # corrupt 15 skipped -> 10
assert any(a.kind == "ckpt_corrupt" for a in report.anomalies)
assert report.steps_done == N
assert len(report.losses) == N

# the recovered schedule bit-matches the fault-free one, losses included
ref = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
ref_losses = []
for s in range(N):
    ref, m = step_fn(ref, get_batch(s))
    assert float(m["integrity_div"]) == 0.0, (s, m)
    ref_losses.append(float(m["loss"]))
assert report.losses == ref_losses, (report.losses, ref_losses)
for a, b in zip(jax.tree.leaves(final.params), jax.tree.leaves(ref.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(final.opt.mu), jax.tree.leaves(ref.opt.mu)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("chaos matrix OK: 5 faults detected, recovered, bit-matched")
"""

_DENSE_CFG = """ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab=128)"""
_MOE_CFG = """ModelConfig("tmoe", Family.MOE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                 moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                               num_shared_experts=1, capacity_factor=2.0))"""
_SSM_CFG = """ModelConfig("tssm", Family.SSM, n_layers=2, d_model=64,
                 n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                 ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8))"""


def test_chaos_matrix_dense(multidevice):
    multidevice(_CHAOS_TEMPLATE.format(
        cfg=_DENSE_CFG, payload_point="cp.ring.kv",
        plan_extra=', cp_impl="ring"'), n_devices=4)


def test_chaos_matrix_moe(multidevice):
    multidevice(_CHAOS_TEMPLATE.format(
        cfg=_MOE_CFG, payload_point="cp.ring.kv",
        plan_extra=', cp_impl="ring"'), n_devices=4)


def test_chaos_matrix_mamba2(multidevice):
    """The SSD entering-state chain is the corrupted link for Mamba2."""
    multidevice(_CHAOS_TEMPLATE.format(
        cfg=_SSM_CFG, payload_point="cp.ring.state",
        plan_extra=""), n_devices=4)


_SLOW_TEMPLATE = """
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, MoEConfig, SSMConfig,
                        ParallelPlan, RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import Monitor, StragglerDetector, StragglerTimer, \\
    run_with_recovery
from repro.ft.inject import FaultSpec, armed
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

cfg = {cfg}
plan = ParallelPlan(remat="none", compute_dtype="float32", cp=2,
                    zero_stage=1, integrity="audit"{plan_extra})
mesh = jax.make_mesh((2, 2), ("data", "cp"))
model = build_model(cfg, plan, mesh, ("data",))
ds = SyntheticDataset(cfg, InputShape("t", 16, 8, "train"))
get_batch = lambda s: {{k: jnp.asarray(v) for k, v in ds.batch(s).items()}}
hyper = Hyper(peak_lr=1e-3, total_steps=40, z_loss=0.0)
N = 16

step_fn = jax.jit(make_train_step(model, plan, hyper, mesh=mesh))
state0 = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)

detector = StragglerDetector(factor=2.0, confirm=2, min_seconds=5e-3)
timer = StragglerTimer(cfg=cfg, plan=plan, detector=detector)
ckpt = CheckpointManager(tempfile.mkdtemp(), keep=3, async_persist=False)
# the injected delay lands in the next step's wall-clock interval too —
# keep the hang watchdog out of the straggler ladder's way
monitor = Monitor(min_history=4, hang_min_seconds=60.0)

# rank 1 of the context-parallel ring degrades from step 6 onward
with armed([FaultSpec("{slow_point}", "slow", step=6, span=999, rank=1,
                      sleep_s=0.05)]):
    final, report = run_with_recovery(
        state0, step_fn, get_batch, N, ckpt, monitor, ckpt_every=5,
        plan=plan, mesh=mesh, policy=RecoveryPolicy(),    # straggler: ignore
        straggler=timer)

assert report.steps_done == N, report
strag = [a for a in report.anomalies if a.kind == "straggler"]
assert strag, report.anomalies
assert strag[0].step <= 6 + 2, strag[0]         # within the confirm window
assert "rank=1" in strag[0].detail and "class=comm" in strag[0].detail, \\
    strag[0].detail
assert "cp.ring" in strag[0].detail, strag[0].detail
assert all(k == "straggler" and act == "ignore"
           for _, k, act in report.actions), report.actions
assert report.restores == 0 and report.rebalances == 0, report

# fail-slow delays cost wall clock but corrupt nothing: the run bit-matches
# the fault-free schedule
ref = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
ref_losses = []
for s in range(N):
    ref, m = step_fn(ref, get_batch(s))
    ref_losses.append(float(m["loss"]))
assert report.losses == ref_losses, (report.losses, ref_losses)
for a, b in zip(jax.tree.leaves(final.params), jax.tree.leaves(ref.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("SLOW_OK: attributed (rank=1, cp.ring, comm), run bit-matched")
"""


def test_chaos_slow_dense(multidevice):
    out = multidevice(_SLOW_TEMPLATE.format(
        cfg=_DENSE_CFG, slow_point="cp.ring.kv",
        plan_extra=', cp_impl="ring"'), n_devices=4)
    assert "SLOW_OK" in out


def test_chaos_slow_moe(multidevice):
    out = multidevice(_SLOW_TEMPLATE.format(
        cfg=_MOE_CFG, slow_point="cp.ring.kv",
        plan_extra=', cp_impl="ring"'), n_devices=4)
    assert "SLOW_OK" in out


def test_chaos_slow_mamba2(multidevice):
    """For Mamba2 the degraded link is the SSD entering-state ring."""
    out = multidevice(_SLOW_TEMPLATE.format(
        cfg=_SSM_CFG, slow_point="cp.ring.state",
        plan_extra=""), n_devices=4)
    assert "SLOW_OK" in out


def test_sdc_detected_multidevice(multidevice):
    """plan.integrity='audit' end to end: a rank-masked bitflip on the
    checksum input produces nonzero integrity_div on a real mesh, and the
    clean step reports exactly 0.0."""
    multidevice("""
import jax, jax.numpy as jnp
from repro.ft.inject import FaultSpec, trace_with_faults
from repro.ft.integrity import replica_divergence

mesh = jax.make_mesh((2, 2), ("data", "cp"))
tree = {"w": jnp.arange(64, dtype=jnp.float32)}

def audit(t):
    return replica_divergence(t, mesh=mesh)

cs, div = jax.jit(audit)(tree)
assert float(div) == 0.0, float(div)

twin = trace_with_faults(
    audit, tree,
    specs=[FaultSpec("integrity.checksum", "bitflip", step=0, tick=None,
                     rank=1, axis="data")])
_, div2 = twin(tree)
assert float(div2) != 0.0, float(div2)
print("sdc divergence detected:", float(div2))
""", n_devices=4)
