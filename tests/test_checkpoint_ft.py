"""Checkpointing + fault tolerance: roundtrip, integrity, anomaly detection,
and the recovery-replay-equals-uninterrupted-run property (survey §8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.ft import Monitor, run_with_recovery
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step


def _tiny():
    cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    return cfg, plan, model


def test_checkpoint_roundtrip(tmp_path):
    _, _, model = _tiny()
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2, async_persist=False)
    mgr.save(7, state, blocking=True)
    step, restored = mgr.restore(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    _, _, model = _tiny()
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2, async_persist=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    mgr.wait()
    assert mgr.latest_step() == 4
    assert len(list(tmp_path.glob("ckpt_*.json"))) == 2   # gc keeps 2


def test_checkpoint_integrity_check(tmp_path):
    _, _, model = _tiny()
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, async_persist=False)
    path = mgr.save(1, state, blocking=True)
    # corrupt the npz payload
    data = dict(np.load(str(path) + ".npz"))
    data["a0"] = data["a0"] + 1.0
    np.savez(str(path) + ".npz", **data)
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(state, step=1)


def test_monitor_detects_nan_and_spike():
    m = Monitor(min_history=4)
    for s in range(8):
        assert m.record(s, 2.0 + 0.01 * s, 1.0, now=float(s)) is None
    a = m.record(8, float("nan"), 1.0, now=8.0)
    assert a is not None and a.kind == "nan"
    a = m.record(9, 50.0, 1.0, now=9.0)
    assert a is not None and a.kind == "spike"
    # healthy value after the spike is accepted again
    assert m.record(10, 2.1, 1.0, now=10.0) is None


def test_monitor_detects_hang():
    m = Monitor(min_history=4, hang_factor=5.0)
    t = 0.0
    for s in range(8):
        m.record(s, 2.0, 1.0, now=t)
        t += 1.0
    a = m.record(8, 2.0, 1.0, now=t + 30.0)     # 31s step vs 1s median
    assert a is not None and a.kind == "hang"


def test_recovery_replay_matches_uninterrupted(tmp_path):
    """A run that NaNs at step 13 and rolls back must end bit-identical to an
    uninterrupted run (deterministic pipeline + checkpoint rollback)."""
    cfg, plan, model = _tiny()
    shape = InputShape("t", 16, 4, "train")
    ds = SyntheticDataset(cfg, shape)
    step_fn = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))

    def get_batch(s):
        return {k: jnp.asarray(v) for k, v in ds.batch(s).items()}

    n_steps = 20
    # uninterrupted reference
    state = init_train_state(model, jax.random.PRNGKey(0))
    ref = state
    for s in range(n_steps):
        ref, _ = step_fn(ref, get_batch(s))

    # faulty run: corrupt the params ONCE at step 13 -> NaN loss -> rollback
    fired = {"done": False}

    def injector(step, st):
        if step == 13 and not fired["done"]:
            fired["done"] = True
            bad = jax.tree.map(lambda x: x * jnp.float32("nan"), st.params)
            return st._replace(params=bad)
        return st

    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=3, async_persist=False)
    final, report = run_with_recovery(
        state, step_fn, get_batch, n_steps, mgr, Monitor(min_history=4),
        ckpt_every=5, fault_injector=injector)

    assert report.restores == 1
    assert any(a.kind == "nan" for a in report.anomalies)
    for a, b in zip(jax.tree.leaves(final.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_determinism():
    cfg, _, _ = _tiny()
    shape = InputShape("t", 16, 4, "train")
    a = SyntheticDataset(cfg, shape).batch(5)
    b = SyntheticDataset(cfg, shape).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticDataset(cfg, shape).batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
