"""Prefill → decode continuation must equal the parallel forward pass."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParallelPlan, get_smoke_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma2-9b", "pixtral-12b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=4)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s_prompt, s_total = 2, 5, 9
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s_total)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision_tokens, cfg.d_model)), jnp.float32)
        batch["vision_pos"] = jnp.tile(
            jnp.arange(cfg.vision_tokens, dtype=jnp.int32)[None], (b, 1))

    ref_logits, _ = model.forward(params, batch)

    pre_batch = dict(batch, tokens=tokens[:, :s_prompt])
    logits, cache = model.extras["prefill"](params, pre_batch, max_seq=s_total)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_logits[:, :s_prompt]),
                               rtol=1e-4, atol=1e-4)

    # continue with decode steps
    outs = []
    for t in range(s_prompt, s_total):
        lg, cache = model.decode_step(params, cache, tokens[:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(ref_logits[:, s_prompt:]),
                               rtol=1e-3, atol=1e-3)
