"""Gradients of the fused expert-GEMM / SSD Pallas kernels vs their XLA
oracles, the per-op dispatch rules, and train-step smokes with
``moe_gemm_impl="pallas"`` / ``ssm_impl="pallas"`` (mirrors
test_attention_grad.py for the two remaining fused kernels)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import InputShape, ParallelPlan, get_smoke_config
from repro.data import SyntheticDataset
from repro.kernels import (
    dispatch_ssd_scan,
    expert_gemm,
    select_gemm_impl,
    select_ssd_impl,
)
from repro.kernels.ref import expert_gemm_ref
from repro.models import build_model
from repro.models.ssm import ssd_scan
from repro.train import Hyper, init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# expert GEMM


GEMM_GRAD_CASES = [
    # (e, c, d, f, group_sizes)
    (2, 32, 16, 24, None),
    (3, 33, 20, 17, (33, 7, 0)),       # ragged + empty expert, unaligned dims
    (2, 64, 32, 32, (40, 64)),         # boundary straddles a row tile
    (4, 16, 48, 16, (5, 0, 16, 11)),
]


@pytest.mark.parametrize("case", GEMM_GRAD_CASES)
def test_expert_gemm_grad_matches_oracle(case):
    e, c, d, f, gs_t = case
    rng = np.random.default_rng(abs(hash(case)) % 2**32)
    x = _rand(rng, (e, c, d))
    w = _rand(rng, (e, d, f))
    cot = _rand(rng, (e, c, f))            # cotangent weighting
    gs = None if gs_t is None else jnp.asarray(gs_t, jnp.int32)

    def fused(x, w):
        return jnp.sum(expert_gemm(x, w, gs, block_c=16, block_f=16,
                                   block_d=16) * cot)

    def oracle(x, w):
        return jnp.sum(expert_gemm_ref(x, w, gs) * cot)

    np.testing.assert_allclose(float(fused(x, w)), float(oracle(x, w)),
                               rtol=1e-5)
    g_fused = jax.grad(fused, argnums=(0, 1))(x, w)
    g_ref = jax.grad(oracle, argnums=(0, 1))(x, w)
    for name, a, r in zip(("dx", "dw"), g_fused, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-4,
                                   atol=1e-4, err_msg=f"{name} {case}")


def test_expert_gemm_group_sizes_zero_expert():
    """An expert with zero load must emit zero outputs and zero grads."""
    rng = np.random.default_rng(0)
    x = _rand(rng, (2, 16, 8))
    w = _rand(rng, (2, 8, 8))
    gs = jnp.asarray([0, 16], jnp.int32)
    out = expert_gemm(x, w, gs, block_c=8, block_f=8, block_d=8)
    assert float(jnp.abs(out[0]).max()) == 0.0
    dx, dw = jax.grad(
        lambda x, w: jnp.sum(expert_gemm(x, w, gs, block_c=8, block_f=8,
                                         block_d=8)), argnums=(0, 1))(x, w)
    assert float(jnp.abs(dx[0]).max()) == 0.0
    assert float(jnp.abs(dw[0]).max()) == 0.0
    assert float(jnp.abs(dx[1]).max()) > 0.0


# ---------------------------------------------------------------------------
# SSD chunk scan


SSD_GRAD_CASES = [
    # (b, l, h, p, g, n, chunk)
    (1, 32, 2, 4, 1, 4, 8),
    (2, 48, 4, 8, 2, 8, 16),       # GQA-style g < h
    (1, 24, 4, 4, 2, 4, 24),       # single chunk, g < h
]


@pytest.mark.parametrize("case", SSD_GRAD_CASES)
def test_ssd_grad_matches_oracle(case):
    from repro.kernels import ssd_chunk_scan
    b, l, h, p, g, n, chunk = case
    rng = np.random.default_rng(abs(hash(case)) % 2**32)
    x = _rand(rng, (b, l, h, p))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = _rand(rng, (b, l, g, n))
    C = _rand(rng, (b, l, g, n))
    cy = _rand(rng, (b, l, h, p))
    cst = _rand(rng, (b, h, p, n))         # cotangent on the final state too

    def fused(x, dt, A, B, C):
        y, st = ssd_chunk_scan(
            x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
            B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3), chunk=chunk)
        return jnp.sum(y.transpose(0, 2, 1, 3) * cy) + jnp.sum(st * cst)

    def oracle(x, dt, A, B, C):
        y, st = ssd_scan(x, dt, A, B, C, chunk=chunk)
        return jnp.sum(y * cy) + jnp.sum(st * cst)

    np.testing.assert_allclose(float(fused(x, dt, A, B, C)),
                               float(oracle(x, dt, A, B, C)), rtol=1e-5)
    g_fused = jax.grad(fused, argnums=(0, 1, 2, 3, 4))(x, dt, A, B, C)
    g_ref = jax.grad(oracle, argnums=(0, 1, 2, 3, 4))(x, dt, A, B, C)
    for name, a, r in zip(("dx", "ddt", "dA", "dB", "dC"), g_fused, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-4,
                                   atol=1e-4, err_msg=f"{name} {case}")


# ---------------------------------------------------------------------------
# dispatch layer


def test_per_op_dispatch_rules():
    # explicit choices always honored
    for sel in (select_gemm_impl, select_ssd_impl):
        assert sel("xla") == "xla"
        assert sel("pallas") == "pallas"
        # auto never picks the interpreter off-TPU
        expected = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert sel("auto") == expected
        with pytest.raises(ValueError):
            sel("cuda")
    # the fused SSD kernel starts from a zero state
    assert select_ssd_impl("pallas", has_initial_state=True) == "xla"


def test_plan_validates_impl_knobs():
    cfg = get_smoke_config("mamba2-370m")
    ParallelPlan(moe_gemm_impl="pallas", ssm_impl="pallas").validate(cfg)
    with pytest.raises(ValueError):
        ParallelPlan(moe_gemm_impl="cuda").validate(cfg)
    with pytest.raises(ValueError):
        ParallelPlan(ssm_impl="triton").validate(cfg)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_dispatch_ssd_scan_pads_unaligned_lengths(impl):
    """l % chunk != 0 must pad to the boundary (dt=0 rides the state through),
    matching the single-chunk exact reformulation — not crash, not collapse."""
    rng = np.random.default_rng(4)
    b, l, h, p, g, n = 1, 40, 2, 4, 1, 4
    x = _rand(rng, (b, l, h, p))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = _rand(rng, (b, l, g, n))
    C = _rand(rng, (b, l, g, n))
    y, st = dispatch_ssd_scan(x, dt, A, B, C, chunk=16, impl=impl)
    y_ref, st_ref = ssd_scan(x, dt, A, B, C, chunk=l)   # chunk-invariant oracle
    assert y.shape == (b, l, h, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=2e-4,
                               atol=2e-4)


def test_ssm_block_unaligned_keeps_configured_chunk(monkeypatch):
    """ssm_block on an unaligned length must keep the configured chunk size
    (padding to the boundary), never degrade to one whole-sequence chunk whose
    (q, q) decay matrix is quadratic in L."""
    import repro.models.ssm as S
    from repro.core import Family, ModelConfig, SSMConfig

    cfg = ModelConfig("t", Family.SSM, n_layers=1, d_model=32, n_heads=0,
                      n_kv_heads=0, d_ff=0, vocab=64,
                      ssm=SSMConfig(d_state=8, head_dim=16, expand=2, chunk=16))
    p = S.init_ssm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, l = 2, 24                               # 16 < l, l % 16 != 0
    x = _rand(rng, (b, l, 32))

    seen = {}
    orig = S.ssd_scan

    def spy(x, dt, A, B, C, chunk, initial_state=None):
        seen["chunk"], seen["l"] = chunk, x.shape[1]
        return orig(x, dt, A, B, C, chunk, initial_state)

    monkeypatch.setattr(S, "ssd_scan", spy)
    out = S.ssm_block(p, x, cfg, jnp.float32, plan=ParallelPlan(ssm_impl="xla"))
    assert out.shape == (b, l, 32)
    assert seen["chunk"] == cfg.ssm.chunk, "collapsed to a whole-sequence chunk"
    assert seen["l"] == 32                    # padded to the chunk boundary

    # numerics unchanged vs the exact whole-sequence reformulation
    monkeypatch.setattr(S, "ssd_scan", orig)
    import dataclasses
    cfg_whole = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                                 chunk=l))
    ref = S.ssm_block(p, x, cfg_whole, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-4,
                               atol=5e-4)


# ---------------------------------------------------------------------------
# end-to-end: train steps differentiate through the fused kernels


SHAPE = InputShape("t", 16, 2, "train")


def _train_metrics(cfg, plan):
    ds = SyntheticDataset(cfg, SHAPE)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    model = build_model(cfg, plan)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, plan, Hyper(total_steps=10)))
    _, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    return m


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "deepseek-moe-16b"])
def test_train_step_moe_gemm_impl_pallas_matches_xla(arch):
    cfg = get_smoke_config(arch)
    metrics = {
        impl: _train_metrics(cfg, ParallelPlan(remat="none",
                                               compute_dtype="float32",
                                               moe_gemm_impl=impl))
        for impl in ("xla", "pallas")
    }
    np.testing.assert_allclose(float(metrics["pallas"]["loss"]),
                               float(metrics["xla"]["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(metrics["pallas"]["grad_norm"]),
                               float(metrics["xla"]["grad_norm"]), rtol=1e-3)


@pytest.mark.parametrize("arch", ["mamba2-370m", "zamba2-1.2b"])
def test_train_step_ssm_impl_pallas_matches_xla(arch):
    cfg = get_smoke_config(arch)
    metrics = {
        impl: _train_metrics(cfg, ParallelPlan(remat="none",
                                               compute_dtype="float32",
                                               ssm_impl=impl))
        for impl in ("xla", "pallas")
    }
    np.testing.assert_allclose(float(metrics["pallas"]["loss"]),
                               float(metrics["xla"]["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(metrics["pallas"]["grad_norm"]),
                               float(metrics["xla"]["grad_norm"]), rtol=1e-3)
