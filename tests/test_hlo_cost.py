"""Trip-count-aware HLO cost walker: validated against analytic FLOPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf.hlo_cost import analyze_hlo
from repro.perf.roofline import model_flops_for
from repro.core import ModelConfig, ParallelPlan, Family, InputShape
from repro.models import build_model
from repro.train import TrainState, make_train_step
from repro.optim import adamw_init


def test_scan_trip_count_multiplied():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((256,), jnp.float32)

    def single(w, x):
        return w @ x

    def scanned(w, x):
        def body(c, _):
            return w @ c, None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    f1 = analyze_hlo(jax.jit(single).lower(w, x).compile().as_text(), 1).flops
    f12 = analyze_hlo(jax.jit(scanned).lower(w, x).compile().as_text(), 1).flops
    assert f1 == 2 * 256 * 256
    assert f12 == 12 * f1


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    comp = jax.jit(lambda a, b: jnp.einsum("bij,bjk->bik", a, b)).lower(a, b).compile()
    flops = analyze_hlo(comp.as_text(), 1).flops
    assert flops == 2 * 4 * 64 * 16 * 32


def test_train_step_flops_near_6nd():
    """hlo_flops must land between 6ND (no remat would be ~6ND + attn/head
    overhead) and ~10ND (full remat re-runs the forward)."""
    cfg = ModelConfig("t", Family.DENSE, n_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=4, d_ff=1024, vocab=1024)
    plan = ParallelPlan(remat="full", compute_dtype="float32")
    model = build_model(cfg, plan)
    step = make_train_step(model, plan)
    b, s = 4, 128
    state = jax.eval_shape(
        lambda r: TrainState(model.init(r), adamw_init(model.init(r))),
        jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    comp = jax.jit(step).lower(state, batch).compile()
    flops = analyze_hlo(comp.as_text(), 1).flops
    nd6 = 6 * cfg.param_count() * b * s
    assert 0.9 * nd6 < flops < 1.8 * nd6, flops / nd6


def test_collectives_parsed_with_group_size(multidevice):
    multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.perf.hlo_cost import analyze_hlo
mesh = jax.make_mesh((2, 4), ("data", "model"))

def f(w, x):
    return (x @ w).sum()

comp = jax.jit(jax.grad(f), in_shardings=(
    NamedSharding(mesh, P(None, "model")),
    NamedSharding(mesh, P("data", None)))).lower(
    jax.ShapeDtypeStruct((64, 128), jnp.float32),
    jax.ShapeDtypeStruct((32, 64), jnp.float32)).compile()
a = analyze_hlo(comp.as_text(), 8)
assert a.collective_counts["all-reduce"] >= 1, a.collective_counts
assert a.collective_link_bytes > 0
print("collectives:", {k: v for k, v in a.collective_counts.items() if v})
""")


def test_all_to_all_pricing_formula():
    """all-to-all link bytes follow the ring model — (n-1)/n of the result
    bytes, with the async ``-start`` form halved (its tuple result carries
    operand + destination buffers) and the ``-done`` marker free."""
    txt = """
HloModule m

ENTRY %main (p0: f32[4,64]) -> f32[4,64] {
  %p0 = f32[4,64]{1,0} parameter(0)
  %a2a = f32[4,64]{1,0} all-to-all(f32[4,64]{1,0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %a2as = (f32[4,64]{1,0}, f32[4,64]{1,0}) all-to-all-start(f32[4,64]{1,0} %a2a), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  ROOT %a2ad = f32[4,64]{1,0} all-to-all-done((f32[4,64]{1,0}, f32[4,64]{1,0}) %a2as)
}
"""
    a = analyze_hlo(txt, 8)
    # f32[4,64] = 1024 B in groups of 4 -> 3/4 * 1024 = 768 per exchange;
    # the -start tuple (2048 B) halves back to one 1024 B payload
    assert a.collective_counts["all-to-all"] == 2, a.collective_counts
    assert a.collective_bytes_by_kind["all-to-all"] == 768.0 * 2
    assert a.collective_link_bytes == 768.0 * 2


def test_all_to_all_priced_from_lowered(multidevice):
    """The EP dispatch exchange as XLA actually lowers it (variadic tuple
    all-to-all under shard_map) is recognized and priced at (n-1)/n of the
    tuple total."""
    multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.perf.hlo_cost import analyze_hlo

mesh = jax.make_mesh((8,), ("model",))

def body(x):
    return jax.lax.all_to_all(x, "model", split_axis=0, concat_axis=0,
                              tiled=False)

f = shard_map(body, mesh, in_specs=P(None, "model"), out_specs=P(None, "model"))
x = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
a = analyze_hlo(jax.jit(f).lower(x).compile().as_text(), 8)
assert a.collective_counts["all-to-all"] == 1, a.collective_counts
# 8 pieces of f32[1,8,32] (1024 B each) -> 7/8 * 8192 = 7168 link bytes
assert a.collective_bytes_by_kind["all-to-all"] == 7.0 / 8.0 * 8 * 1024, \\
    a.collective_bytes_by_kind
print("a2a priced:", a.collective_bytes_by_kind["all-to-all"])
""")


def test_model_flops_for_shapes():
    cfg = ModelConfig("t", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab=256)
    n = cfg.param_count()
    train = model_flops_for(cfg, InputShape("t", 128, 4, "train"))
    prefill = model_flops_for(cfg, InputShape("p", 128, 4, "prefill"))
    decode = model_flops_for(cfg, InputShape("d", 128, 4, "decode"))
    assert train == 6 * n * 512
    assert prefill == 2 * n * 512
    assert decode == 2 * n * 4
