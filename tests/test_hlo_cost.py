"""Trip-count-aware HLO cost walker: validated against analytic FLOPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf.hlo_cost import analyze_hlo
from repro.perf.roofline import model_flops_for
from repro.core import ModelConfig, ParallelPlan, Family, InputShape
from repro.models import build_model
from repro.train import TrainState, make_train_step
from repro.optim import adamw_init


def test_scan_trip_count_multiplied():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((256,), jnp.float32)

    def single(w, x):
        return w @ x

    def scanned(w, x):
        def body(c, _):
            return w @ c, None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    f1 = analyze_hlo(jax.jit(single).lower(w, x).compile().as_text(), 1).flops
    f12 = analyze_hlo(jax.jit(scanned).lower(w, x).compile().as_text(), 1).flops
    assert f1 == 2 * 256 * 256
    assert f12 == 12 * f1


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    comp = jax.jit(lambda a, b: jnp.einsum("bij,bjk->bik", a, b)).lower(a, b).compile()
    flops = analyze_hlo(comp.as_text(), 1).flops
    assert flops == 2 * 4 * 64 * 16 * 32


def test_train_step_flops_near_6nd():
    """hlo_flops must land between 6ND (no remat would be ~6ND + attn/head
    overhead) and ~10ND (full remat re-runs the forward)."""
    cfg = ModelConfig("t", Family.DENSE, n_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=4, d_ff=1024, vocab=1024)
    plan = ParallelPlan(remat="full", compute_dtype="float32")
    model = build_model(cfg, plan)
    step = make_train_step(model, plan)
    b, s = 4, 128
    state = jax.eval_shape(
        lambda r: TrainState(model.init(r), adamw_init(model.init(r))),
        jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    comp = jax.jit(step).lower(state, batch).compile()
    flops = analyze_hlo(comp.as_text(), 1).flops
    nd6 = 6 * cfg.param_count() * b * s
    assert 0.9 * nd6 < flops < 1.8 * nd6, flops / nd6


def test_collectives_parsed_with_group_size(multidevice):
    multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.perf.hlo_cost import analyze_hlo
mesh = jax.make_mesh((2, 4), ("data", "model"))

def f(w, x):
    return (x @ w).sum()

comp = jax.jit(jax.grad(f), in_shardings=(
    NamedSharding(mesh, P(None, "model")),
    NamedSharding(mesh, P("data", None)))).lower(
    jax.ShapeDtypeStruct((64, 128), jnp.float32),
    jax.ShapeDtypeStruct((32, 64), jnp.float32)).compile()
a = analyze_hlo(comp.as_text(), 8)
assert a.collective_counts["all-reduce"] >= 1, a.collective_counts
assert a.collective_link_bytes > 0
print("collectives:", {k: v for k, v in a.collective_counts.items() if v})
""")


def test_model_flops_for_shapes():
    cfg = ModelConfig("t", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab=256)
    n = cfg.param_count()
    train = model_flops_for(cfg, InputShape("t", 128, 4, "train"))
    prefill = model_flops_for(cfg, InputShape("p", 128, 4, "prefill"))
    decode = model_flops_for(cfg, InputShape("d", 128, 4, "decode"))
    assert train == 6 * n * 512
    assert prefill == 2 * n * 512
    assert decode == 2 * n * 4
