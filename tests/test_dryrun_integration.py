"""End-to-end dry-run path on a forced 8-device host mesh: stepbuilder →
jit(in_shardings) → lower → compile → HLO cost walk, for representative archs
and all three step kinds, using the reduced (smoke) configs."""

import pytest


def _script(arch: str, kind: str) -> str:
    return f"""
import dataclasses, jax, jax.numpy as jnp
from repro.core import ParallelPlan, SHAPES_BY_NAME
from repro.core.config import Family, InputShape
from repro.launch.stepbuilder import build_step, resolve_config
from repro.perf.hlo_cost import analyze_hlo

mesh = jax.make_mesh((2, 4), ("data", "model"))
arch = "{arch}"
cfg = resolve_config(arch, "train_4k", smoke=True)
# MoE archs fold the expert ring onto the 4-wide model axis (ep is a
# degree now; the old ep=True/False bool is rejected by validate())
plan = ParallelPlan(remat="full", ep=4 if cfg.family == Family.MOE else 1)

# patch a reduced shape in place of the production ones
import repro.core.config as cc
import repro.launch.stepbuilder as sb
shape = InputShape("{kind}_t", 64, 8, "{kind}")
sb.SHAPES_BY_NAME = dict(sb.SHAPES_BY_NAME)
sb.SHAPES_BY_NAME[shape.name] = shape

fn, args, shardings, meta = build_step(arch, shape.name, mesh, plan, smoke=True)
with mesh:
    compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
hc = analyze_hlo(compiled.as_text(), mesh.size)
assert hc.flops > 0
print(arch, "{kind}", "flops", hc.flops, "coll", hc.collective_link_bytes)
"""


@pytest.mark.parametrize("arch,kind", [
    ("qwen1.5-4b", "train"),
    ("olmoe-1b-7b", "train"),
    ("mamba2-370m", "decode"),
    ("zamba2-1.2b", "decode"),
    ("whisper-small", "prefill"),
    ("pixtral-12b", "prefill"),
])
def test_dryrun_smoke_mesh(multidevice, arch, kind):
    multidevice(_script(arch, kind))
