"""Fast-recovery tier (survey §8.3.1): in-memory peer-redundant checkpoints,
verify-before-evict GC, the always-flushed persist fence, and the crash
flight recorder.

Covers the tentpole acceptance at unit/integration level:

- the RAM ring restores bit-identically, and a peer rebuild after a
  simulated lost host-group bit-matches the disk restore of the same step;
- the recovery driver restores memory-tier-first (``mem_restores``) and
  falls back to the verified disk walk when the tier is lost;
- ``CheckpointManager._gc`` never evicts the newest *intact* checkpoint
  even when a burst of silently-dropped shard writes makes every kept
  checkpoint corrupt (the regression the keep-floor exists for);
- background persist failures surface on *every* exit path (the ``finally``
  fence), including exception exits;
- every failure mode leaves a parseable flight-recorder JSON naming the
  anomaly, step, and action (``RecoveryExhausted`` carries the path).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, CorruptCheckpointError,
                              MemoryCheckpointTier)
from repro.checkpoint.store import layout_diffs
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import (FlightRecorder, Monitor, RecoveryExhausted,
                      run_with_recovery)
from repro.ft.inject import FaultSpec, armed, make_injector
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

N_STEPS = 20
CKPT_EVERY = 5


def _world():
    cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"))
    get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
    step_fn = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    return model, plan, step_fn, get_batch, state


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _quiet():
    return Monitor(min_history=1000, hang_min_seconds=60.0)


# ---------------------------------------------------------------------------
# Memory tier units


def test_memory_tier_roundtrip_and_ring_eviction():
    tree = {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
            "b": jnp.ones((6,), jnp.float32)}
    mem = MemoryCheckpointTier(keep=2, groups=2)
    for s in (3, 6, 9):
        mem.save(s, tree)
    assert mem.steps() == [6, 9]           # ring maxlen evicted step 3
    assert mem.latest_step() == 9
    step, got = mem.restore(tree)
    assert step == 9
    _assert_trees_equal(got, tree)
    assert mem.last_rebuild == 0           # pure primary fast path
    step, _ = mem.restore(tree, step=6)
    assert step == 6
    with pytest.raises(CorruptCheckpointError, match="not in memory tier"):
        mem.restore(tree, step=3)
    mem.clear()
    with pytest.raises(CorruptCheckpointError, match="empty"):
        mem.restore(tree)


def test_memory_tier_peer_rebuild_bit_matches_disk(tmp_path):
    """Acceptance: after a simulated lost host-group, the peer-rebuilt RAM
    restore bit-matches the disk restore of the same step — on a real train
    state (params + ZeRO opt moments), not a toy tree."""
    model, plan, step_fn, get_batch, state = _world()
    for s in range(3):
        state, _ = step_fn(state, get_batch(s))
    disk = CheckpointManager(tmp_path, async_persist=False)
    disk.save(3, state, blocking=True, plan=plan)
    mem = MemoryCheckpointTier(keep=2, groups=4)
    mem.save(3, state, plan=plan)

    template = init_train_state(model, jax.random.PRNGKey(0))
    lost = mem.lose_group(1)
    assert lost > 0
    s_mem, from_mem = mem.restore(template, plan=plan)
    assert mem.last_rebuild > 0            # mirrors actually served shards
    s_disk, from_disk = disk.restore(template)
    assert s_mem == s_disk == 3
    _assert_trees_equal(from_mem.params, from_disk.params)
    _assert_trees_equal(from_mem.opt.mu, from_disk.opt.mu)
    _assert_trees_equal(from_mem.opt.nu, from_disk.opt.nu)


def test_memory_tier_double_loss_unrecoverable():
    tree = {"w": jnp.ones((8, 8), jnp.float32)}
    mem = MemoryCheckpointTier(keep=1, groups=3)
    mem.save(1, tree)
    mem.lose_group(0)                      # primary gone
    mem.lose_group(1)                      # ...and its mirror holder
    with pytest.raises(CorruptCheckpointError, match="lost from memory"):
        mem.restore(tree)


def test_memory_tier_without_redundancy_single_loss_fatal():
    tree = {"w": jnp.ones((8, 8), jnp.float32)}
    mem = MemoryCheckpointTier(keep=1, groups=2, peer_redundancy=False)
    mem.save(1, tree)
    mem.lose_group(0)
    with pytest.raises(CorruptCheckpointError):
        mem.restore(tree)


def test_memory_tier_mirror_is_digest_verified():
    """Rebuilt bytes crossed a (simulated) host loss: a corrupted mirror
    must be detected, never silently restored."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    mem = MemoryCheckpointTier(keep=1, groups=2)
    mem.save(1, tree)
    mem.lose_group(0)
    for buf in mem._ring[0]["mirror"][1].values():
        buf[...] = 0.0                     # flip the surviving mirror bytes
    with pytest.raises(CorruptCheckpointError, match="digest mismatch"):
        mem.restore(tree)


def test_memory_tier_layout_mismatch_refuses():
    tree = {"w": jnp.ones((8, 8), jnp.float32)}
    mem = MemoryCheckpointTier(keep=1, groups=2)
    mem.save(1, tree, plan=ParallelPlan(cp=1))
    with pytest.raises(ValueError, match="layout mismatch"):
        mem.restore(tree, plan=ParallelPlan(cp=2))


def test_layout_diffs_helper():
    man = {"plan": {"tp": 1, "cp": 2, "dp_shard": 1, "zero_stage": 1,
                    "ep": False, "pp": 1},
           "mesh_axes": {"data": 2, "cp": 2}}
    assert layout_diffs(man, ParallelPlan(cp=2)) == {}
    assert "cp" in layout_diffs(man, ParallelPlan(cp=4))
    assert layout_diffs({"plan": None, "mesh_axes": None},
                        ParallelPlan(cp=4)) == {}


# ---------------------------------------------------------------------------
# Driver integration: memory-tier-first restore, disk fallback


def test_rollback_served_by_memory_tier(tmp_path):
    """A NaN rollback restores from RAM (mem_restores) and the finished run
    bit-matches the fault-free schedule — no disk read on the hot path."""
    model, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    mem = MemoryCheckpointTier(keep=2, groups=2)
    injector = make_injector([FaultSpec("train.step", "nan", step=13)])
    final, report = run_with_recovery(
        state, step_fn, get_batch, N_STEPS, ckpt, _quiet(),
        ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
        policy=RecoveryPolicy(), mem_ckpt=mem)
    assert report.restores == 1
    assert report.mem_restores == 1        # served from RAM, not disk
    assert (13, "nan", "rollback") in report.actions
    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))
    _assert_trees_equal(final.params, ref.params)
    _assert_trees_equal(final.opt.mu, ref.opt.mu)


def test_lost_memory_tier_falls_back_to_disk(tmp_path):
    """Both host-groups of the RAM ring die before the anomaly: the tiered
    restore drops to the verified disk walk and still bit-matches.

    ``mem_every=CKPT_EVERY`` so the ring is not repopulated between the
    simulated host loss (step 12) and the NaN (step 13)."""
    model, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    mem = MemoryCheckpointTier(keep=2, groups=2)
    nan_inj = make_injector([FaultSpec("train.step", "nan", step=13)])

    def injector(step, st):
        if step == 12:                     # simulated total host loss
            mem.lose_group(0)
            mem.lose_group(1)
        return nan_inj(step, st)

    final, report = run_with_recovery(
        state, step_fn, get_batch, N_STEPS, ckpt, _quiet(),
        ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
        policy=RecoveryPolicy(), mem_ckpt=mem, mem_every=CKPT_EVERY)
    assert report.restores == 1
    assert report.mem_restores == 0        # RAM couldn't serve: disk did
    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))
    _assert_trees_equal(final.params, ref.params)


# ---------------------------------------------------------------------------
# GC keep-floor regression (satellite): a drop_write burst must not evict
# the last restorable checkpoint


def test_gc_spares_newest_intact_under_drop_write_burst(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_persist=False)
    tree = {"w": jnp.arange(32, dtype=jnp.float32)}
    mgr.save(0, tree, blocking=True)
    mgr.save(5, tree, blocking=True)
    with armed([FaultSpec("ckpt.shard_write", "drop_write", step=10),
                FaultSpec("ckpt.shard_write", "drop_write", step=15),
                FaultSpec("ckpt.shard_write", "drop_write", step=20)]):
        mgr.save(10, tree, blocking=True)
        mgr.save(15, tree, blocking=True)
        mgr.save(20, tree, blocking=True)
    # pre-fix GC kept only the newest `keep` (15, 20 — both corrupt) and
    # deleted every restorable checkpoint; the keep-floor spares intact 5
    steps = set(mgr.steps())
    assert 5 in steps, steps
    _, got = mgr.restore({"w": jnp.zeros((32,), jnp.float32)}, step=5)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    for bad in steps - {5}:
        with pytest.raises(CorruptCheckpointError):
            mgr.restore({"w": jnp.zeros((32,), jnp.float32)}, step=bad)


def test_gc_still_trims_when_newest_is_intact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_persist=False)
    tree = {"w": jnp.ones((8,), jnp.float32)}
    for s in range(0, 25, 5):
        mgr.save(s, tree, blocking=True)
    assert mgr.steps() == [15, 20]         # healthy runs GC exactly as before


def test_recovery_survives_drop_write_burst_via_keep_floor(tmp_path):
    """Driver-level regression: burst-corrupt the newest checkpoints, then a
    NaN — the fallback walk lands on the GC-spared intact checkpoint and the
    run still bit-matches the fault-free schedule."""
    model, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=2, async_persist=False)
    injector = make_injector([FaultSpec("train.step", "nan", step=17)])
    with armed([FaultSpec("ckpt.shard_write", "drop_write", step=10),
                FaultSpec("ckpt.shard_write", "drop_write", step=15)]):
        final, report = run_with_recovery(
            state, step_fn, get_batch, N_STEPS, ckpt, _quiet(),
            ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
            policy=RecoveryPolicy())
    assert report.restores == 1
    assert report.ckpt_fallbacks == 2      # corrupt 15 and 10 both skipped
    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))
    _assert_trees_equal(final.params, ref.params)


# ---------------------------------------------------------------------------
# Exit discipline (satellite): ckpt.wait() in finally on every exit path


def test_persist_failure_surfaces_on_exception_exit(tmp_path):
    """An async persist failure used to vanish when the loop exited via an
    exception; the finally-fence converts it to a ckpt_io anomaly."""
    _, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=True,
                             io_retries=1, io_backoff=0.01)
    monitor = _quiet()

    def bomb(step, st):
        if step == 7:
            raise RuntimeError("unrelated crash")
        return st

    with armed([FaultSpec("ckpt.persist", "persist_exc", step=5, times=99)]):
        with pytest.raises(RuntimeError, match="unrelated crash"):
            run_with_recovery(
                state, step_fn, get_batch, N_STEPS, ckpt, monitor,
                ckpt_every=CKPT_EVERY, plan=plan, fault_injector=bomb,
                policy=RecoveryPolicy())
    assert any(a.kind == "ckpt_io" for a in monitor.anomalies)


# ---------------------------------------------------------------------------
# Flight recorder


def test_flight_ring_is_bounded(tmp_path):
    fl = FlightRecorder(maxlen=8, path=str(tmp_path / "f.json"))
    for i in range(20):
        fl.record("step", i, loss=float(i))
    assert len(fl.events) == 8
    fl.dump("test")
    d = json.loads((tmp_path / "f.json").read_text())
    assert d["n_events"] == 8
    assert [e["step"] for e in d["events"]] == list(range(12, 20))


def test_flight_dump_sanitizes_nonfinite(tmp_path):
    fl = FlightRecorder(maxlen=8, path=str(tmp_path / "f.json"))
    fl.record("step", 0, loss=float("nan"), grad_norm=float("inf"),
              arr=np.float32(2.5))
    p = fl.dump("test")
    d = json.loads(open(p).read())         # must parse: no bare nan tokens
    e = d["events"][0]
    assert e["loss"] == "nan" and e["grad_norm"] == "inf"
    assert e["arr"] == 2.5


def test_flight_dump_without_path_is_noop():
    fl = FlightRecorder(maxlen=8)
    fl.record("step", 0)
    assert fl.dump("test") is None


def test_recovery_exhausted_leaves_parseable_flight_json(tmp_path):
    """Acceptance: a failure mode that kills the run leaves a flight JSON
    naming the anomaly, the step, and the recovery action taken."""
    _, plan, step_fn, get_batch, state = _world()
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    fl = FlightRecorder(maxlen=128, path=str(tmp_path / "flight.json"))
    injector = make_injector(
        [FaultSpec("train.step", "nan", step=13, times=99)])
    with pytest.raises(RecoveryExhausted) as ei:
        run_with_recovery(
            state, step_fn, get_batch, N_STEPS, ckpt, _quiet(),
            ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
            policy=RecoveryPolicy(max_restores=2), flight=fl)
    assert ei.value.flight_path == str(tmp_path / "flight.json")
    d = json.loads((tmp_path / "flight.json").read_text())
    assert d["reason"] == "RecoveryExhausted"
    assert d["extra"]["step"] == 13
    anomalies = [e for e in d["events"] if e["kind"] == "anomaly"]
    policies = [e for e in d["events"] if e["kind"] == "policy"]
    faults = [e for e in d["events"] if e["kind"] == "fault"]
    restores = [e for e in d["events"] if e["kind"] == "restore"]
    assert anomalies and anomalies[0]["anomaly"] == "nan" \
        and anomalies[0]["step"] == 13
    assert policies and policies[0]["action"] == "rollback"
    assert faults and faults[0]["fault_kind"] == "nan"
    assert restores and restores[0]["tier"] == "disk"


def test_flight_logs_gc_and_persist_events(tmp_path):
    fl = FlightRecorder(maxlen=64, path=str(tmp_path / "f.json"))
    mgr = CheckpointManager(tmp_path, keep=1, async_persist=False, flight=fl)
    tree = {"w": jnp.ones((8,), jnp.float32)}
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    kinds = [e["kind"] for e in fl.events]
    assert kinds.count("ckpt.persist") == 2
    persists = [e for e in fl.events if e["kind"] == "ckpt.persist"]
    assert all(e["tier"] == "disk" for e in persists)
