"""Fail-slow defense (survey §8.1): straggler attribution + rebalancing.

Unit level: the ``slow`` fault class (windowed, rank-maskable, replayable),
the cross-rank and own-history detectors (work-share normalization keeps an
intentionally uneven ``pp_layout`` quiet), :func:`choose_pp_layout`'s greedy
min-max re-partition, ``pp_layout`` config validation, the Monitor's
compile-interval discard, the vectorized synthetic-token generator's
bit-identity with the reference loop, the prefetcher, the
KeyboardInterrupt flight dump, and ``check_plan`` routing a ``pp_layout``
change as an elastic reshard.

Multidevice acceptance at the bottom: (a) uneven layouts ((3,1), (1,3))
produce the same loss/grads as even (2,2) and single-device, under both
schedules; (b) the end-to-end ladder — a seeded ``slow`` fault pinned to
one pipeline stage is detected, attributed to (rank, compute), the
``rebalance`` policy re-partitions ``pp_layout`` through a checkpoint
reshard restore, and the run completes on the new layout.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy)
from repro.data import Prefetcher, SyntheticDataset
from repro.ft import (FlightRecorder, Monitor, StragglerDetector,
                      choose_pp_layout, effective_layout, run_with_recovery)
from repro.ft.inject import CONTROLLER, FaultSpec, armed, slow_spec_for
from repro.ft.straggler import SECTION_CLASSES, SECTION_POINTS, StragglerTimer
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step


# ---------------------------------------------------------------------------
# the "slow" fault class


def test_slow_spec_window_and_rank_mask():
    sp = FaultSpec("pp.stage.tick", "slow", step=5, span=3, rank=1,
                   sleep_s=0.01)
    with armed([sp]):
        assert slow_spec_for("pp.stage.tick", 4, rank=1) is None   # before
        assert slow_spec_for("pp.stage.tick", 5, rank=1) is sp
        assert slow_spec_for("pp.stage.tick", 7, rank=1) is sp     # last in
        assert slow_spec_for("pp.stage.tick", 8, rank=1) is None   # after
        assert slow_spec_for("pp.stage.tick", 6, rank=0) is None   # masked
        assert slow_spec_for("data.fetch", 6, rank=1) is None      # point
    assert ("pp.stage.tick", "slow", 5) in CONTROLLER.fired


def test_slow_spec_unmasked_hits_every_rank():
    sp = FaultSpec("cp.ring.kv", "slow", step=0, span=1000, sleep_s=0.01)
    with armed([sp]):
        assert slow_spec_for("cp.ring.kv", 3, rank=0) is sp
        assert slow_spec_for("cp.ring.kv", 3, rank=7) is sp
        assert slow_spec_for("cp.ring.kv", 3, rank=None) is sp


def test_slow_spec_validates():
    with pytest.raises(ValueError, match="span"):
        FaultSpec("train.step", "slow", span=0)
    with pytest.raises(ValueError, match="unknown fault point"):
        slow_spec_for("no.such.point", 0)


def test_section_tables_agree():
    assert set(SECTION_POINTS) == set(SECTION_CLASSES)
    from repro.ft.inject import FAULT_POINTS
    for pts in SECTION_POINTS.values():
        for p in pts:
            assert p in FAULT_POINTS, p


# ---------------------------------------------------------------------------
# detector units


def test_detector_cross_rank_confirm_latency():
    det = StragglerDetector(factor=2.0, confirm=3, min_seconds=1e-3)
    for step in range(5):
        shares = {0: 0.01, 1: 0.01, 2: 0.01, 3: 0.05}
        ev = det.observe_group("pp.stage", step, shares)
        if step < 2:
            assert ev is None, step       # streak still building
        elif step == 2:
            assert ev is not None         # confirm=3 -> third slow step
            assert ev.rank == 3 and ev.section == "pp.stage"
            assert ev.cls == "compute" and ev.slowdown > 2.0


def test_detector_streak_resets_on_healthy_sample():
    det = StragglerDetector(factor=2.0, confirm=3, min_seconds=1e-3)
    slow = {0: 0.01, 1: 0.05}
    ok = {0: 0.01, 1: 0.01}
    assert det.observe_group("tp.ring", 0, slow) is None
    assert det.observe_group("tp.ring", 1, slow) is None
    assert det.observe_group("tp.ring", 2, ok) is None     # streak broken
    assert det.observe_group("tp.ring", 3, slow) is None
    assert det.observe_group("tp.ring", 4, slow) is None
    assert det.observe_group("tp.ring", 5, slow) is not None


def test_detector_work_share_normalization_uneven_layout_quiet():
    """An intentionally uneven pp_layout must not read as a straggler."""
    det = StragglerDetector(factor=2.0, confirm=1, min_seconds=1e-3)
    layout = (3, 1)
    weights = {0: 3.0, 1: 1.0}
    for step in range(6):
        # stage 0 takes 3x stage 1's time — exactly its work share
        ev = det.observe_group("pp.stage", step, {0: 0.03, 1: 0.01},
                               weights=weights)
        assert ev is None, (step, ev)
    # the same raw times WITHOUT weights would fire immediately
    det2 = StragglerDetector(factor=2.0, confirm=1, min_seconds=1e-3)
    assert det2.observe_group("pp.stage", 0, {0: 0.03, 1: 0.01}) is not None
    # and a degraded rank fires even under normalization: slow per layer
    ev = det.observe_group("pp.stage", 9, {0: 0.03, 1: 0.025},
                           weights=weights)
    assert ev is not None and ev.rank == 1


def test_detector_own_history_and_grace():
    det = StragglerDetector(factor=2.0, confirm=1, min_seconds=1e-3,
                            min_history=3)
    # step 0 is the compile step: a huge time must be discarded, not learned
    assert det.observe("step.compute", None, 10.0, 0) is None
    for s in range(1, 5):
        assert det.observe("step.compute", None, 0.01, s) is None
    ev = det.observe("step.compute", None, 0.05, 5)
    assert ev is not None and ev.cls == "compute" and ev.rank is None
    det.reset()
    # post-reset grace re-arms: the next observation is discarded again
    assert det.observe("step.compute", None, 10.0, 6) is None
    assert ("step.compute", None) not in det._hist


def test_detector_recent_reflects_degraded_regime():
    det = StragglerDetector(window=16, confirm=3)
    for s in range(10):
        det.observe_group("pp.stage", s, {0: 0.01, 1: 0.01})
    for s in range(10, 13):
        det.observe_group("pp.stage", s, {0: 0.01, 1: 0.07})
    recent = det.recent("pp.stage")
    assert recent[1] == pytest.approx(0.07)   # degraded values, not the
    assert recent[0] == pytest.approx(0.01)   # healthy full-window median


# ---------------------------------------------------------------------------
# choose_pp_layout


def test_choose_pp_layout_sheds_from_slow_stage():
    # stage 1 is 2x slower per layer -> it gives up a layer
    assert choose_pp_layout({0: 1.0, 1: 2.0}, (2, 2)) == (3, 1)
    assert choose_pp_layout({0: 2.0, 1: 1.0}, (2, 2)) == (1, 3)


def test_choose_pp_layout_balanced_is_identity():
    assert choose_pp_layout({0: 1.0, 1: 1.0}, (2, 2)) == (2, 2)
    # (3,1) with stage 1 paying 3x per layer: keeping the skew IS optimal
    assert choose_pp_layout({0: 3.0, 1: 3.0}, (3, 1)) == (3, 1)
    # equal per-layer costs under a skewed layout: evening out wins
    assert choose_pp_layout({0: 3.0, 1: 1.0}, (3, 1)) == (2, 2)
    assert choose_pp_layout({}, (2, 2)) == (2, 2)


def test_choose_pp_layout_one_layer_floor():
    # however degraded, every stage keeps >= 1 layer
    out = choose_pp_layout({0: 1.0, 1: 1000.0}, (4, 4))
    assert out == (7, 1)
    assert sum(out) == 8 and min(out) >= 1


def test_effective_layout():
    cfg = ModelConfig("t", Family.DENSE, n_layers=4, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64)
    assert effective_layout(ParallelPlan(), cfg) is None            # no pp
    assert effective_layout(ParallelPlan(pp=2, microbatches=2), cfg) == (2, 2)
    p = ParallelPlan(pp=2, microbatches=2, pp_layout=(3, 1))
    assert effective_layout(p) == (3, 1)                            # no cfg
    assert effective_layout(None) is None


def test_pp_layout_config_validation():
    cfg = ModelConfig("t", Family.DENSE, n_layers=4, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64)
    ParallelPlan(pp=2, microbatches=2, pp_layout=(3, 1)).validate(cfg)
    with pytest.raises(ValueError, match="pp_layout"):
        ParallelPlan(pp_layout=(4,)).validate(cfg)          # needs pp > 1
    with pytest.raises(ValueError, match="pp_layout"):
        ParallelPlan(pp=2, microbatches=2, pp_layout=(4,)).validate(cfg)
    with pytest.raises(ValueError, match="pp_layout"):
        ParallelPlan(pp=2, microbatches=2, pp_layout=(4, 0)).validate(cfg)
    with pytest.raises(ValueError, match="pp_layout"):
        ParallelPlan(pp=2, microbatches=2, pp_layout=(2, 3)).validate(cfg)
    # odd split without an explicit layout still refuses
    cfg5 = dataclasses.replace(cfg, n_layers=5)
    with pytest.raises(ValueError, match="pp_layout"):
        ParallelPlan(pp=2, microbatches=2).validate(cfg5)
    # lists normalize to tuples (hashable; JSON round-trip comparable)
    assert ParallelPlan(pp=2, microbatches=2, pp_layout=[3, 1]).pp_layout \
        == (3, 1)


# ---------------------------------------------------------------------------
# Monitor: compile interval must not poison the wall-time window


def test_monitor_discards_first_interval():
    mon = Monitor(min_history=2, hang_factor=4.0, hang_min_seconds=1e-3)
    t = 100.0
    mon.record(0, 1.0, 1.0, now=t)            # arms the heartbeat
    mon.record(1, 1.0, 1.0, now=t + 10.0)     # the 10s JIT-compile interval
    assert 10.0 not in mon.times              # discarded, not learned
    mon.record(2, 1.0, 1.0, now=t + 10.1)
    mon.record(3, 1.0, 1.0, now=t + 10.2)
    out = mon.record(4, 1.0, 1.0, now=t + 10.7)   # 0.5s vs 0.1s median
    assert out is not None and out.kind == "hang"


def test_monitor_without_discard_would_mask():
    """The regression shape: with the compile interval in the window the
    median is poisoned and the same slowdown passes silently."""
    mon = Monitor(min_history=2, hang_factor=4.0, hang_min_seconds=1e-3)
    mon._skip_next_interval = False           # simulate the old behaviour
    t = 100.0
    mon.record(0, 1.0, 1.0, now=t)
    mon.record(1, 1.0, 1.0, now=t + 10.0)     # compile spike enters times
    mon.record(2, 1.0, 1.0, now=t + 10.1)
    out = mon.record(3, 1.0, 1.0, now=t + 10.6)
    assert out is None                        # masked by the poisoned median
    assert 10.0 in mon.times


def test_monitor_reset_rearms_discard():
    mon = Monitor(min_history=2, hang_min_seconds=1e-3)
    t = 50.0
    mon.record(0, 1.0, 1.0, now=t)
    mon.record(1, 1.0, 1.0, now=t + 0.1)      # first interval: discarded
    mon.record(2, 1.0, 1.0, now=t + 0.2)
    assert len(mon.times) == 1
    mon.reset_heartbeat(now=t + 5.0)          # e.g. after a restore
    mon.record(3, 1.0, 1.0, now=t + 15.0)     # re-JIT interval: discarded
    assert len(mon.times) == 1


# ---------------------------------------------------------------------------
# StragglerTimer: sections, modeled shares, armed slow delays


def test_timer_section_times_and_attributes_host_io():
    det = StragglerDetector(factor=2.0, confirm=1, min_seconds=1e-3,
                            min_history=2)
    timer = StragglerTimer(detector=det)
    for s in range(4):
        with timer.section("data.fetch", s):
            pass
    with armed([FaultSpec("data.fetch", "slow", step=4, span=2,
                          sleep_s=0.02)]):
        with timer.section("data.fetch", 4):
            pass
    ev = timer.after_step(4, 0.001)
    assert ev is not None and ev.section == "data.fetch"
    assert ev.cls == "host-io"


def test_timer_models_stage_shares_and_sleeps_per_layer():
    cfg = ModelConfig("t", Family.DENSE, n_layers=4, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(pp=2, microbatches=2)
    det = StragglerDetector(factor=2.0, confirm=2, min_seconds=1e-3)
    timer = StragglerTimer(cfg=cfg, plan=plan, detector=det)
    with armed([FaultSpec("pp.stage.tick", "slow", step=0, span=100, rank=1,
                          sleep_s=0.01)]):
        assert timer.after_step(0, 0.004) is None     # streak 1 of 2
        ev = timer.after_step(1, 0.004)
    assert ev is not None and ev.rank == 1 and ev.section == "pp.stage"
    assert ev.cls == "compute"
    # the degraded stage's recent time includes the injected delay
    # (2 layers x 0.01s), so the rebalancer plans against reality
    times = timer.stage_times()
    assert times[1] > times[0]
    assert choose_pp_layout(times, (2, 2)) == (3, 1)


def test_timer_ring_attribution():
    plan = ParallelPlan(cp=2)
    det = StragglerDetector(factor=2.0, confirm=2, min_seconds=1e-3)
    timer = StragglerTimer(plan=plan, detector=det)
    with armed([FaultSpec("cp.ring.kv", "slow", step=0, span=100, rank=1,
                          sleep_s=0.02)]):
        timer.after_step(0, 0.004)
        ev = timer.after_step(1, 0.004)
    assert ev is not None and ev.rank == 1
    assert ev.section == "cp.ring" and ev.cls == "comm"


# ---------------------------------------------------------------------------
# data pipeline: vectorized generator bit-identity + prefetcher


def test_tokens_vectorized_bit_identical_to_loop():
    cfg = ModelConfig("t", Family.DENSE, n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=128)
    for b, s in [(4, 16), (8, 1), (3, 2), (1, 33)]:
        ds = SyntheticDataset(cfg, InputShape("t", s, b, "train"), seed=3)
        for step in range(3):
            r1 = np.random.default_rng((3, step))
            r2 = np.random.default_rng((3, step))
            np.testing.assert_array_equal(ds._tokens(r1, b, s),
                                          ds._tokens_loop(r2, b, s))
            # the generator state must match too, or downstream draws
            # (AUDIO frames, VLM embeds) would diverge
            assert r1.bit_generator.state == r2.bit_generator.state


def test_prefetcher_identical_including_random_access():
    cfg = ModelConfig("t", Family.DENSE, n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=128)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"), seed=1)
    with Prefetcher(ds) as pf:
        # sequential, a forward jump, and a rollback-style backward jump
        for step in [0, 1, 2, 7, 3, 4, 4]:
            got, want = pf.batch(step), ds.batch(step)
            assert set(got) == set(want)
            for k in want:
                np.testing.assert_array_equal(got[k], want[k])


# ---------------------------------------------------------------------------
# KeyboardInterrupt dumps the flight recorder (satellite regression)


def test_keyboard_interrupt_dumps_flight(tmp_path):
    cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"))
    get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
    step_fn = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    flight = FlightRecorder(maxlen=64, path=str(tmp_path / "flight.json"))

    def injector(step, st):
        if step == 3:
            raise KeyboardInterrupt
        return st

    ckpt = CheckpointManager(str(tmp_path / "ck"), async_persist=False)
    with pytest.raises(KeyboardInterrupt) as ei:
        run_with_recovery(state, step_fn, get_batch, 8, ckpt,
                          Monitor(), ckpt_every=4, fault_injector=injector,
                          flight=flight)
    fp = getattr(ei.value, "flight_path", None)
    assert fp is not None and (tmp_path / "flight.json").exists()
    import json
    payload = json.loads((tmp_path / "flight.json").read_text())
    assert payload["reason"] == "KeyboardInterrupt"
    assert any(e["kind"] == "step" for e in payload["events"])


# ---------------------------------------------------------------------------
# checkpoint: a pp_layout change is a layout change -> elastic reshard


def test_check_plan_routes_pp_layout_change_as_reshard(tmp_path):
    cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    state = init_train_state(model, jax.random.PRNGKey(0))
    even = ParallelPlan(pp=2, microbatches=2, pp_layout=(2, 2))
    ckpt = CheckpointManager(str(tmp_path), async_persist=False)
    ckpt.save(0, state, blocking=True, plan=even)
    same = ParallelPlan(pp=2, microbatches=2, pp_layout=(2, 2))
    assert ckpt.check_plan(same, step=0) == "replay"
    skew = ParallelPlan(pp=2, microbatches=2, pp_layout=(3, 1))
    assert ckpt.check_plan(skew, step=0, elastic=True) == "reshard"
    with pytest.raises(ValueError, match="pp_layout"):
        ckpt.check_plan(skew, step=0, elastic=False)
    # None (implicit even) vs an explicit layout is also a relayout
    none_lay = ParallelPlan(pp=2, microbatches=2)
    assert ckpt.check_plan(none_lay, step=0, elastic=True) == "reshard"


# ---------------------------------------------------------------------------
# multidevice acceptance


def test_uneven_pp_layout_matches_even_and_single(multidevice):
    """(3,1) == (1,3) == (2,2) == non-pipelined, both schedules, fwd+grad."""
    multidevice("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
ds = SyntheticDataset(cfg, InputShape("t", 16, 8, "train"))
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

model = build_model(cfg, ParallelPlan(remat="none", compute_dtype="float32"))
params = model.init(jax.random.PRNGKey(0))
ref_loss, _ = make_loss_fn(model, Hyper(z_loss=0.0))(params, batch)
ref_g = jax.grad(lambda p, b: make_loss_fn(model, Hyper(z_loss=0.0))(p, b)[0]
                 )(params, batch)

mesh = jax.make_mesh((2, 2), ("pod", "data"))
base = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                    microbatches=4)
for layout in [(2, 2), (3, 1), (1, 3)]:
    for sched in ["1f1b", "gpipe"]:
        pl = dataclasses.replace(base, pp_layout=layout, pp_schedule=sched)
        lf = pipelined_loss_fn(cfg, pl, mesh, ("data",))
        loss, _ = jax.jit(lf)(params, batch)
        assert abs(float(loss) - float(ref_loss)) < 1e-6, (
            layout, sched, float(loss), float(ref_loss))
        g = jax.grad(lambda p, b: lf(p, b)[0])(params, batch)
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref_g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-5)
        print(layout, sched, "OK", float(loss))
print("uneven pp_layout equivalence OK")
""", n_devices=4)


def test_straggler_rebalance_end_to_end(multidevice):
    """The whole ladder: seeded slow fault on stage 1 -> detected within the
    confirm window, attributed (rank=1, compute) -> policy rebalances
    pp_layout via a checkpoint reshard restore -> run completes."""
    multidevice("""
import dataclasses, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import (Monitor, RemeshSpec, StragglerDetector, StragglerTimer,
                      run_with_recovery)
from repro.ft.inject import FaultSpec, armed
from repro.models import build_model
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
mesh = jax.make_mesh((2, 2), ("pod", "data"))
plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                    microbatches=4)
ds = SyntheticDataset(cfg, InputShape("t", 16, 8, "train"))
get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}

model = build_model(cfg, ParallelPlan(remat="none", compute_dtype="float32"))
params0 = model.init(jax.random.PRNGKey(0))

def make_step(pl):
    lf = pipelined_loss_fn(cfg, pl, mesh, ("data",))
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p, b: lf(p, b)[0])(state["params"], batch)
        params = jax.tree.map(lambda p, g: p - 1e-3 * g,
                              state["params"], grads)
        gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                          for g in jax.tree.leaves(grads)))
        return {"params": params}, {"loss": loss, "grad_norm": gn}
    return jax.jit(step)

state0 = {"params": params0}
N = 16
detector = StragglerDetector(window=8, factor=2.0, confirm=3,
                             min_seconds=1e-3)
timer = StragglerTimer(cfg=cfg, plan=plan, detector=detector)
policy = RecoveryPolicy(straggler="rebalance", max_restores=4,
                        straggler_confirm=3)
monitor = Monitor(hang_min_seconds=60.0)   # the straggler ladder owns this

applied = []
def rebalance(layout):
    applied.append(tuple(layout))
    pl2 = dataclasses.replace(plan, pp_layout=tuple(layout))
    return RemeshSpec(train_step=make_step(pl2), state_template=state0,
                      plan=pl2, mesh=mesh)

ckpt = CheckpointManager(tempfile.mkdtemp(), keep=4, async_persist=False)
# stage 1 degrades from step 6 on: 50ms of extra host time per layer held
with armed([FaultSpec("pp.stage.tick", "slow", step=6, span=999, rank=1,
                      sleep_s=0.05)]):
    final, report = run_with_recovery(
        state0, make_step(plan), get_batch, N, ckpt, monitor,
        ckpt_every=3, plan=plan, mesh=mesh, policy=policy,
        straggler=timer, rebalance=rebalance)

assert report.steps_done == N, report
assert report.rebalances == 1, report
assert applied and applied[0] == (3, 1), applied     # stage 1 shed a layer
strag = [a for a in report.anomalies if a.kind == "straggler"]
assert strag, report.anomalies
# detected within the confirm window of the fault landing
assert strag[0].step <= 6 + 3, strag[0]
assert "rank=1" in strag[0].detail and "class=compute" in strag[0].detail
assert any(k == "straggler" and act == "rebalance"
           for _, k, act in report.actions), report.actions
# the reshard restore rode the elastic checkpoint path (old layout on disk)
assert report.restores >= 1, report
# a re-attribution of the already-rebalanced rank must not loop the ladder
assert report.rebalances == 1
assert all(np.isfinite(l) for l in report.losses[-3:])
print("straggler rebalance e2e OK:", applied[0], "losses fine")
""", n_devices=4)
