"""Optimizer / schedule / clipping unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, strategies as st

from repro.optim import (
    adamw_init, adamw_update, clip_by_global_norm, cosine_schedule,
)


def test_adamw_matches_reference_math():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((3,)), jnp.float32)}
    g = jax.tree.map(lambda x: jnp.ones_like(x) * 0.5, p)
    st_ = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, new_st = adamw_update(g, st_, p, lr, b1=b1, b2=b2, eps=eps,
                                 weight_decay=wd)

    # reference (step 1): mhat = g, vhat = g², delta = g/|g|
    for k, nd in [("w", 2), ("b", 1)]:
        gk = np.asarray(g[k], np.float64)
        pk = np.asarray(p[k], np.float64)
        delta = gk / (np.abs(gk) + eps)
        wd_k = wd if nd > 1 else 0.0             # no decay on 1-D params
        expect = pk - lr * (delta + wd_k * pk)
        np.testing.assert_allclose(np.asarray(new_p[k]), expect, rtol=1e-5)
    assert int(new_st.step) == 1


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), max_norm=st.floats(0.1, 10.0))
def test_clip_by_global_norm_property(seed, max_norm):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((5,)), jnp.float32)}
    clipped, norm = clip_by_global_norm(g, max_norm)
    out_norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                  for x in jax.tree.leaves(clipped))))
    assert out_norm <= max_norm * (1 + 1e-5)
    if float(norm) <= max_norm:   # no-op when under the bound
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(clipped)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(step=st.integers(0, 10_000))
def test_cosine_schedule_bounds(step):
    peak, warm, total = 3e-4, 100, 10_000
    lr = float(cosine_schedule(jnp.int32(step), peak, warm, total))
    assert 0.0 < lr <= peak * (1 + 1e-6)
    if step >= total:
        assert abs(lr - 0.1 * peak) < 1e-9      # floor at min_ratio


def test_schedule_monotone_warmup():
    lrs = [float(cosine_schedule(jnp.int32(s), 1e-3, 50, 1000))
           for s in range(0, 50, 5)]
    assert all(b >= a for a, b in zip(lrs, lrs[1:]))
