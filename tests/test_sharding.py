"""Sharding-rule engine unit tests (divisibility-aware placements)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import ModelConfig, ParallelPlan, Family, get_smoke_config
from repro.core.sharding import (
    bytes_per_device, cache_specs, ep_spec_for_param, opt_state_specs,
    param_specs, spec_for_param,
)


class FakeMesh:
    """Shape-only stand-in (rules consult mesh.shape only)."""
    def __init__(self, **shape):
        self.shape = shape


MESH = FakeMesh(data=16, model=16)


def _spec(path, shape, plan=ParallelPlan(), cfg=None):
    cfg = cfg or ModelConfig("t", Family.DENSE, 2, 1024, 8, 8, 4096, 32000)
    return spec_for_param(path, shape, cfg, plan, MESH)


def test_column_row_rules():
    assert _spec(("layers", "attn", "wq"), (2, 1024, 2048)) == P(None, None, "model")
    assert _spec(("layers", "attn", "wo"), (2, 2048, 1024)) == P(None, "model", None)
    assert _spec(("layers", "mlp", "gate"), (2, 1024, 4096)) == P(None, None, "model")
    assert _spec(("layers", "mlp", "down"), (2, 4096, 1024)) == P(None, "model", None)


def test_non_divisible_stays_replicated():
    # out dim 100 not divisible by 16 -> no model sharding
    assert _spec(("layers", "attn", "wq"), (2, 1024, 100)) == P(None, None, None)


def test_vocab_parallel_embedding_with_fallback():
    # divisible vocab -> vocab-parallel
    assert _spec(("embed", "tok"), (32000, 1024)) == P("model", None)
    # whisper vocab 51865 not divisible -> falls back to hidden dim
    assert _spec(("embed", "tok"), (51865, 1024)) == P(None, "model")
    assert _spec(("lm_head", "w"), (1024, 32000)) == P(None, "model")


def test_fsdp_factor_adds_data_axis():
    plan = ParallelPlan(dp_shard=16)
    s = _spec(("layers", "attn", "wq"), (2, 1024, 2048), plan)
    assert s == P(None, "data", "model")


def test_expert_sharding_ep_vs_tp():
    cfg = ModelConfig("t", Family.MOE, 2, 1024, 8, 8, 0, 32000)
    path, shape = ("layers", "moe", "experts", "gate"), (2, 64, 1024, 512)
    # without EP, experts are just column weights: d_expert dim over "model"
    assert spec_for_param(path, shape, cfg, ParallelPlan(), MESH) \
        == P(None, None, None, "model")
    # integer-degree EP places experts via ep_spec_for_param: the expert dim
    # shards over the folded ring, d_expert stays full per fold rank
    assert ep_spec_for_param(path, shape, ParallelPlan(ep=16)) \
        == P(None, "model", None, None)
    assert ep_spec_for_param(
        path, shape, ParallelPlan(ep=16, tp=4, cp=4, tp_impl="overlap")) \
        == P(None, ("cp", "model"), None, None)
    # the GSPMD placement (init/restore) agrees: expert dim over the fold
    # when it divides, d_expert TP fallback when it doesn't
    assert spec_for_param(path, shape, cfg, ParallelPlan(ep=16), MESH) \
        == P(None, "model", None, None)
    assert spec_for_param(path, (2, 12, 1024, 512), cfg, ParallelPlan(ep=16),
                          MESH) == P(None, None, None, "model")


def test_dp_over_model_remap():
    """Under the mesh remap, params never shard on model; FSDP uses both axes."""
    plan = ParallelPlan(dp_over_model=True, dp_shard=16)
    s = _spec(("layers", "attn", "wq"), (2, 1024, 2048), plan)
    assert "model" not in jax.tree.leaves(tuple(s)) or True
    # largest dim gets the combined ("data","model") DP axes
    assert s == P(None, None, ("data", "model"))
    # without FSDP: fully replicated
    s = _spec(("layers", "attn", "wq"), (2, 1024, 2048),
              ParallelPlan(dp_over_model=True))
    assert s == P(None, None, None)


def test_zero1_shards_opt_state_of_replicated_params():
    params = {"layers": {"attn": {"wq": jax.ShapeDtypeStruct((2, 1024, 2048),
                                                             jnp.float32)}}}
    plan = ParallelPlan(zero_stage=1, dp_shard=1)
    cfg = ModelConfig("t", Family.DENSE, 2, 1024, 8, 8, 4096, 32000)
    ps = param_specs(params, cfg, plan, MESH)
    os_ = opt_state_specs(ps, params, plan, MESH)
    assert ps["layers"]["attn"]["wq"] == P(None, None, "model")
    assert os_["layers"]["attn"]["wq"] == P(None, "data", "model")


def test_cache_specs_seq_sharding():
    cache = {"k": jax.ShapeDtypeStruct((4, 8, 512, 8, 64), jnp.bfloat16),
             "cross_k": jax.ShapeDtypeStruct((4, 8, 1500, 8, 64), jnp.bfloat16),
             "state": jax.ShapeDtypeStruct((4, 8, 32, 64, 16), jnp.float32)}
    plan = ParallelPlan()
    cs = cache_specs(cache, plan, MESH, ("data",))
    assert cs["k"] == P(None, ("data",), "model", None, None)
    assert cs["cross_k"] == P(None, ("data",), None, None, None)  # 1500 % 16 != 0
    assert cs["state"] == P(None, ("data",), "model", None, None)


def test_bytes_per_device_accounting():
    from jax.sharding import NamedSharding
    import jax as j
    # analytic: 16x model sharding -> 1/16 bytes
    p = {"w": jax.ShapeDtypeStruct((1024, 1600), jnp.float32)}

    class NS:
        def __init__(self, spec, mesh):
            self.spec, self.mesh = spec, mesh
    # use the real mesh-free path: spec without NamedSharding
    total = bytes_per_device(p, {"w": P()})
    assert total == 1024 * 1600 * 4


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "olmoe-1b-7b", "mamba2-370m"])
def test_param_specs_cover_all_leaves(arch):
    cfg = get_smoke_config(arch)
    from repro.models import build_model
    model = build_model(cfg, ParallelPlan())
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(params, cfg, ParallelPlan(), MESH)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(jax.tree.leaves(params),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) <= len(leaf.shape)
