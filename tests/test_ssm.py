"""Mamba2 SSD: chunked scan vs naive recurrence oracle + step-form equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, strategies as st

from repro.core import ModelConfig, SSMConfig, Family
from repro.models.ssm import init_ssm, init_ssm_cache, ssd_scan, ssm_block, ssm_step


def naive_ssd(x, dt, A, B, C):
    """O(L²)-free scalar recurrence oracle: h_t = h_{t-1}·exp(dt·A) + dt·x_t⊗B_t."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, l, h, p), np.float64)
    xd, dtn = np.asarray(x, np.float64), np.asarray(dt, np.float64)
    An, Bn, Cn = np.asarray(A, np.float64), np.asarray(B, np.float64), np.asarray(C, np.float64)
    for t in range(l):
        decay = np.exp(dtn[:, t] * An)                        # (b, h)
        Bh = np.repeat(Bn[:, t], hpg, axis=1)                 # (b, h, n)
        Ch = np.repeat(Cn[:, t], hpg, axis=1)
        inp = (xd[:, t] * dtn[:, t][..., None])[..., None] * Bh[:, :, None, :]
        state = state * decay[..., None, None] + inp
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch)
    return ys, state


@pytest.mark.parametrize("l,chunk", [(16, 4), (32, 8), (24, 24), (64, 16)])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_scan_matches_naive_recurrence(l, chunk, g):
    rng = np.random.default_rng(l * 7 + g)
    b, h, p, n = 2, 4, 8, 6
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    y, final = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_ref, state_ref = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), state_ref, rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), chunk=st.sampled_from([2, 4, 8]))
def test_ssd_chunk_invariance(seed, chunk):
    """Result must be independent of the chunk size (pure reformulation)."""
    rng = np.random.default_rng(seed)
    b, l, h, p, n = 1, 16, 2, 4, 4
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, 1, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, 1, n)), jnp.float32)
    y1, f1 = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y2, f2 = ssd_scan(x, dt, A, B, C, chunk=l)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=2e-4,
                               atol=2e-4)


def test_ssm_block_step_equivalence():
    """Full-sequence ssm_block == token-by-token ssm_step (decode path)."""
    cfg = ModelConfig("t", Family.SSM, n_layers=1, d_model=32, n_heads=0,
                      n_kv_heads=0, d_ff=0, vocab=64,
                      ssm=SSMConfig(d_state=8, head_dim=16, expand=2))
    rng = np.random.default_rng(0)
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    b, l = 2, 12
    x = jnp.asarray(rng.standard_normal((b, l, 32)), jnp.float32)
    y_full = ssm_block(p, x, cfg, jnp.float32)
    cache = init_ssm_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(l):
        y, cache = ssm_step(p, x[:, t], cache, cfg, jnp.float32)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=5e-4, atol=5e-4)
