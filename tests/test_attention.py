"""Attention implementations: blockwise (memory-efficient) vs direct, plus
hypothesis property tests on the shared invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, strategies as st

from repro.models.layers import (
    attention_blockwise, attention_direct, attn_mask, rope,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("s,block", [(64, 16), (128, 32), (96, 32)])
@pytest.mark.parametrize("window", [0, 17])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_blockwise_matches_direct(s, block, window, hq, hkv):
    rng = np.random.default_rng(0)
    b, hd = 2, 16
    q, k, v = (_rand(rng, (b, s, hq, hd)), _rand(rng, (b, s, hkv, hd)),
               _rand(rng, (b, s, hkv, hd)))
    ref = attention_direct(q, k, v, causal=True, window=window)
    out = attention_blockwise(q, k, v, causal=True, window=window,
                              block_size=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_softcap_and_offset():
    rng = np.random.default_rng(1)
    b, s, t, h, hd = 1, 8, 64, 2, 16
    q = _rand(rng, (b, s, h, hd))
    k, v = _rand(rng, (b, t, h, hd)), _rand(rng, (b, t, h, hd))
    ref = attention_direct(q, k, v, causal=True, softcap=20.0, q_offset=40)
    out = attention_blockwise(q, k, v, causal=True, softcap=20.0, q_offset=40,
                              block_size=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# hypothesis properties


@settings(max_examples=25, deadline=None)
@given(s=st.integers(2, 24), window=st.integers(0, 30))
def test_mask_properties(s, window):
    m = np.asarray(attn_mask(jnp.arange(s), jnp.arange(s), causal=True,
                             window=window))
    # diagonal always attends (self)
    assert m.diagonal().all()
    # strictly upper triangle never attends
    assert not np.triu(m, 1).any()
    if window:
        i, j = np.nonzero(m)
        assert ((i - j) < window).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_softmax_rows_sum_to_one(seed):
    rng = np.random.default_rng(seed)
    b, s, h, hd = 1, 12, 2, 8
    q = _rand(rng, (b, s, h, hd))
    k, v = _rand(rng, (b, s, h, hd)), jnp.eye(s)[None, :, None, :].repeat(h, 2)
    # with V = identity over positions, outputs are the attention probs
    out = attention_direct(q, k, v.astype(jnp.float32)[..., :hd] if hd <= s
                           else v.astype(jnp.float32), causal=True)
    assert jnp.isfinite(out).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_rope_preserves_norm_and_relativity(seed):
    """Rope is a rotation (norm-preserving) and q·k depends only on i-j."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, (1, 6, 2, 16))
    pos = jnp.arange(6)
    y = rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)

    q = _rand(rng, (1, 1, 1, 16))
    k = _rand(rng, (1, 1, 1, 16))
    def dot_at(pi, pj):
        qr = rope(q, jnp.array([pi]))
        kr = rope(k, jnp.array([pj]))
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4   # same offset
