import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_multidevice(script: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N forced host devices.

    Tests and benches in-process must see 1 device (per the dry-run contract),
    so anything needing a mesh runs out-of-process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed\n--- stdout ---\n{proc.stdout}"
            f"\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
