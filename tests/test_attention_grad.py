"""Gradients of the fused Pallas attention vs the XLA blockwise oracle, the
kernel-dispatch rules, and a train-step smoke with ``attn_impl="pallas"``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.kernels import flash_attention, select_impl
from repro.models import build_model
from repro.models.layers import attention, attention_blockwise, attention_direct
from repro.train import Hyper, init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def _hm(x):  # kernel head-major (B,H,S,hd) <-> models (B,S,H,hd)
    return x.transpose(0, 2, 1, 3)


GRAD_CASES = [
    # (b, hq, hkv, s, t, hd, causal, window, softcap, q_offset)
    (1, 4, 2, 64, 64, 32, True, 0, 0.0, 0),        # GQA
    (2, 2, 2, 48, 48, 32, True, 0, 0.0, 0),        # unaligned seq len
    (1, 2, 1, 64, 64, 32, True, 12, 0.0, 0),       # sliding window + GQA
    (1, 2, 2, 64, 64, 32, True, 0, 15.0, 0),       # logit softcap
    (1, 2, 2, 64, 64, 32, False, 0, 0.0, 0),       # bidirectional
    (1, 2, 2, 32, 96, 32, True, 0, 0.0, 64),       # chunked-prefill q_offset
    (1, 4, 1, 40, 72, 32, True, 16, 30.0, 32),     # everything, unaligned
]


@pytest.mark.parametrize("case", GRAD_CASES)
def test_flash_grad_matches_blockwise_oracle(case):
    b, hq, hkv, s, t, hd, causal, window, cap, qoff = case
    rng = np.random.default_rng(abs(hash(case)) % 2**32)
    q = _rand(rng, (b, hq, s, hd))
    k = _rand(rng, (b, hkv, t, hd))
    v = _rand(rng, (b, hkv, t, hd))
    w = _rand(rng, (b, hq, s, hd))          # cotangent weighting
    kw = dict(causal=causal, window=window, softcap=cap, q_offset=qoff)

    def fused(q, k, v):
        return jnp.sum(flash_attention(q, k, v, block_q=32, block_k=32,
                                       **kw) * w)

    def oracle(q, k, v):
        out = attention_blockwise(_hm(q), _hm(k), _hm(v), block_size=8, **kw)
        return jnp.sum(_hm(out) * w)

    np.testing.assert_allclose(float(fused(q, k, v)), float(oracle(q, k, v)),
                               rtol=1e-4)
    g_fused = jax.grad(fused, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(oracle, argnums=(0, 1, 2))(q, k, v)
    for name, a, r in zip("qkv", g_fused, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-3,
                                   atol=1e-3, err_msg=f"d{name} {case}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_grad_dtype_preserved(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), dtype)
    loss = lambda q, k, v: jnp.sum(
        flash_attention(q, k, v, block_q=32, block_k=32).astype(jnp.float32))
    grads = jax.grad(loss, argnums=(0, 1, 2))(q, q, q)
    for g in grads:
        assert g.dtype == dtype
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


# ---------------------------------------------------------------------------
# dispatch layer


def test_dispatch_rules():
    # explicit choices always honored (static masks)
    assert select_impl("xla", head_dim=128, window=0, q_offset=0) == "xla"
    assert select_impl("pallas", head_dim=128, window=0, q_offset=0) == "pallas"
    # traced mask params (gemma2 alternation) force XLA
    traced = jnp.int32(4)
    assert select_impl("pallas", head_dim=128, window=traced, q_offset=0) == "xla"
    # auto never picks the interpreter off-TPU
    expected = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert select_impl("auto", head_dim=128, window=0, q_offset=0) == expected
    with pytest.raises(ValueError):
        select_impl("cuda", head_dim=128, window=0, q_offset=0)


def test_dispatch_pallas_matches_xla_in_model_layout():
    rng = np.random.default_rng(1)
    q = _rand(rng, (2, 48, 4, 32))
    k = _rand(rng, (2, 48, 2, 32))
    v = _rand(rng, (2, 48, 2, 32))
    a = attention(q, k, v, causal=True, window=8, impl="xla")
    b = attention(q, k, v, causal=True, window=8, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_unaligned_long_kv_stays_blockwise(monkeypatch):
    """KV lengths that don't divide the block size must pad + stay blockwise,
    never silently fall back to the O(S·T) direct path."""
    import repro.models.layers as L

    rng = np.random.default_rng(2)
    s = t = 72                                  # > 2*32 and 72 % 32 != 0
    q = _rand(rng, (1, s, 2, 16))
    k = _rand(rng, (1, t, 2, 16))
    v = _rand(rng, (1, t, 2, 16))
    ref = attention_direct(q, k, v, causal=True, window=20)

    def _no_direct(*a, **kw):
        raise AssertionError("quadratic fallback taken for unaligned long KV")

    monkeypatch.setattr(L, "attention_direct", _no_direct)
    out = attention(q, k, v, causal=True, window=20, block_size=32, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_blockwise_kv_len_masks_padding():
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 8, 2, 16))
    k = _rand(rng, (1, 40, 2, 16))
    v = _rand(rng, (1, 40, 2, 16))
    ref = attention_direct(q, k, v, causal=False)
    pad = ((0, 0), (0, 24), (0, 0), (0, 0))
    out = attention_blockwise(q, jnp.pad(k, pad), jnp.pad(v, pad),
                              causal=False, block_size=16, kv_len=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# end-to-end: the train step differentiates through the fused kernel


def test_train_step_attn_impl_pallas_matches_xla():
    cfg = ModelConfig("t", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=128)
    shape = InputShape("t", 32, 4, "train")
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

    metrics = {}
    for impl in ("xla", "pallas"):
        plan = ParallelPlan(remat="none", compute_dtype="float32",
                            attn_impl=impl)
        model = build_model(cfg, plan)
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, plan, Hyper(total_steps=10)))
        _, m = step(state, batch)
        assert np.isfinite(float(m["loss"])), impl
        assert np.isfinite(float(m["grad_norm"])), impl
        metrics[impl] = m

    np.testing.assert_allclose(float(metrics["pallas"]["loss"]),
                               float(metrics["xla"]["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(metrics["pallas"]["grad_norm"]),
                               float(metrics["xla"]["grad_norm"]), rtol=1e-3)
