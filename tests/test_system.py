"""End-to-end system tests: training learns, microbatching is exact, the
multi-device train step + pipeline parallelism agree with the references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_loss_fn, make_train_step


def _tiny(**kw):
    cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=128, **kw)
    return cfg


def test_training_learns_markov_structure():
    cfg = _tiny()
    plan = ParallelPlan(remat="selective", compute_dtype="float32")
    shape = InputShape("t", 32, 8, "train")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, shape)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model, plan, Hyper(peak_lr=1e-2, warmup_steps=10, total_steps=60)))
    losses = []
    for i in range(60):
        state, m = step(state, {k: jnp.asarray(v) for k, v in ds.batch(i).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_microbatch_accumulation_matches_full_batch():
    cfg = _tiny()
    shape = InputShape("t", 16, 8, "train")
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    hyper = Hyper(peak_lr=1e-3, total_steps=10, z_loss=0.0)

    outs = {}
    for mb in (1, 4):
        plan = ParallelPlan(remat="none", compute_dtype="float32",
                            microbatches=mb)
        model = build_model(cfg, plan)
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, plan, hyper))
        new_state, metrics = step(state, batch)
        outs[mb] = (new_state, metrics)

    np.testing.assert_allclose(float(outs[1][1]["loss"]),
                               float(outs[4][1]["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0].params),
                    jax.tree.leaves(outs[4][0].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_remat_policies_do_not_change_loss():
    cfg = _tiny()
    shape = InputShape("t", 16, 4, "train")
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    losses = {}
    grads = {}
    for remat in ("none", "selective", "full"):
        plan = ParallelPlan(remat=remat, compute_dtype="float32")
        model = build_model(cfg, plan)
        params = model.init(jax.random.PRNGKey(0))
        loss_fn = make_loss_fn(model, Hyper(z_loss=0.0))
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        losses[remat] = float(l)
        grads[remat] = g
    assert abs(losses["none"] - losses["full"]) < 1e-5
    assert abs(losses["none"] - losses["selective"]) < 1e-5
    for a, b in zip(jax.tree.leaves(grads["none"]), jax.tree.leaves(grads["full"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


def test_sharded_train_step_matches_single_device(multidevice):
    """The pjit'd train step on a (2,4) mesh must reproduce the single-device
    result (parallelism is an implementation detail, not a math change)."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import Family, InputShape, ModelConfig, ParallelPlan, sharding
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, TrainState, init_train_state, make_train_step
from repro.optim import adamw_init

cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
hyper = Hyper(peak_lr=1e-3, total_steps=10, z_loss=0.0)
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

# reference: single device
plan0 = ParallelPlan(remat="none", compute_dtype="float32")
m0 = build_model(cfg, plan0)
s0 = init_train_state(m0, jax.random.PRNGKey(0))
ref_state, ref_metrics = jax.jit(make_train_step(m0, plan0, hyper))(s0, batch)

# sharded: (data=2, model=4) mesh with TP+ZeRO1
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = ParallelPlan(remat="none", compute_dtype="float32", tp=4, zero_stage=1)
m1 = build_model(cfg, plan, mesh, ("data",))
s1 = init_train_state(m1, jax.random.PRNGKey(0))
pspecs = sharding.param_specs(s1.params, cfg, plan, mesh)
shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                     is_leaf=lambda x: isinstance(x, P))
params = jax.device_put(s1.params, shard)
state = TrainState(params, adamw_init(params))
new_state, metrics = jax.jit(make_train_step(m1, plan, hyper))(state, batch)

assert abs(float(metrics["loss"]) - float(ref_metrics["loss"])) < 1e-4, (
    float(metrics["loss"]), float(ref_metrics["loss"]))
for a, b in zip(jax.tree.leaves(new_state.params), jax.tree.leaves(ref_state.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
print("sharded == single-device OK, loss", float(metrics["loss"]))
""")


def test_pipeline_parallel_loss_matches(multidevice):
    """GPipe over the pod axis == non-pipelined loss (same math)."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
hyper = Hyper(z_loss=0.0)

plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
ref_loss, _ = make_loss_fn(model, hyper)(params, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
# pin the gpipe schedule: this test covers reverse-AD through the forward
# scan; tests/test_train_memory.py covers 1f1b (and both against gpipe)
plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                    microbatches=4, pp_schedule="gpipe")
pipe_loss_fn = pipelined_loss_fn(cfg, plan, mesh, ("data",))
pipe_loss, _ = jax.jit(pipe_loss_fn)(params, batch)
print("ref", float(ref_loss[0] if isinstance(ref_loss, tuple) else ref_loss),
      "pipe", float(pipe_loss))
assert abs(float(ref_loss) - float(pipe_loss)) < 2e-4

# gradients flow end to end
g = jax.grad(lambda p, b: pipe_loss_fn(p, b)[0])(params, batch)
gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
print("pipeline grad norm OK", gn)
""")
