"""Elastic fault tolerance (survey §8.3): anomaly-driven recovery policies,
double-buffered snapshots, and cross-mesh reshard-restore.

The fault matrix runs {nan, spike, repeated-spike, hang} × {dense, MoE,
Mamba2}: each case asserts the policy table chose the expected action AND
that the recovered run is numerically indistinguishable from the matching
clean run (the deterministic pipeline makes these comparisons exact).
The multidevice test is the §8.3.2 acceptance: k steps on a 2×2 mesh,
simulated host loss to 1×2, reshard-restore (params + ZeRO-1 moments), and
a bit-matching resumed loss sequence.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy)
from repro.core.config import MoEConfig, SSMConfig
from repro.data import SyntheticDataset
from repro.ft import Monitor, run_with_recovery
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

FAULT_STEP = 13
N_STEPS = 20
CKPT_EVERY = 5


def _arch(family: str):
    if family == "dense":
        cfg = ModelConfig("tiny-d", Family.DENSE, n_layers=2, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
    elif family == "moe":
        cfg = ModelConfig("tiny-m", Family.MOE, n_layers=2, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=0, vocab=64,
                          moe=MoEConfig(num_experts=4, top_k=2, d_expert=32,
                                        capacity_factor=2.0))
    else:
        cfg = ModelConfig("tiny-s", Family.SSM, n_layers=2, d_model=32,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab=64,
                          ssm=SSMConfig(d_state=8, head_dim=16, expand=2,
                                        chunk=8))
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    return cfg, plan, build_model(cfg, plan)


def _world(family):
    cfg, plan, model = _arch(family)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"))
    get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
    step_fn = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    return model, step_fn, get_batch, state


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Fault matrix


@pytest.mark.parametrize("family", ["dense", "moe", "ssm"])
@pytest.mark.parametrize("fault", ["nan", "spike", "repeated_spike", "hang"])
def test_fault_matrix(tmp_path, family, fault):
    model, step_fn, get_batch, state = _world(family)
    _, plan, _ = _arch(family)

    fired = {"n": 0}

    def injector(step, st):
        if step != FAULT_STEP:
            return st
        fired["n"] += 1
        if fault == "nan" and fired["n"] == 1:
            return st._replace(params=jax.tree.map(
                lambda x: x * jnp.float32("nan"), st.params))
        if fault == "spike" and fired["n"] == 1:
            return st._replace(params=jax.tree.map(
                lambda x: x * 8.0, st.params))
        if fault == "repeated_spike":   # persistent: fires on every replay
            return st._replace(params=jax.tree.map(
                lambda x: x * 8.0, st.params))
        if fault == "hang" and fired["n"] == 1:
            time.sleep(1.0)
        return st

    # hang tests need a low absolute floor; everything else pins it high so
    # scheduler jitter can never inject a hang into an unrelated case
    monitor = Monitor(min_history=4,
                      hang_min_seconds=0.3 if fault == "hang" else 30.0)
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    final, report = run_with_recovery(
        state, step_fn, get_batch, N_STEPS, ckpt, monitor,
        ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
        policy=RecoveryPolicy())

    # clean reference on the same jitted step; repeated_spike escalates to
    # skip-batch (no rescue_step given), so its reference skips the update
    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        if fault == "repeated_spike" and s == FAULT_STEP:
            continue
        ref, _ = step_fn(ref, get_batch(s))

    if fault == "nan":
        assert report.actions == [(FAULT_STEP, "nan", "rollback")]
        assert report.restores == 1
    elif fault == "spike":
        assert report.actions == [(FAULT_STEP, "spike", "rollback")]
        assert report.restores == 1
    elif fault == "repeated_spike":
        assert report.actions == [(FAULT_STEP, "spike", "rollback"),
                                  (FAULT_STEP, "spike", "lr_rescue")]
        assert report.restores == 2
        assert np.isnan(report.losses[FAULT_STEP])   # the skipped batch
    else:
        assert (FAULT_STEP, "hang", "ignore") in report.actions
        assert report.restores == 0

    assert report.steps_done == N_STEPS
    assert len(report.losses) == N_STEPS
    _assert_trees_equal(final.params, ref.params)
    _assert_trees_equal(final.opt.mu, ref.opt.mu)


def test_lr_rescue_uses_rescue_step(tmp_path):
    """With a rescue_step provided, the second spike at a step rolls back and
    replays that step with the damped-LR twin instead of skipping it."""
    model, step_fn, get_batch, state = _world("dense")
    _, plan, _ = _arch("dense")
    rescue_fn = jax.jit(make_train_step(
        model, plan, Hyper(peak_lr=3e-4 * 0.1, total_steps=30)))

    fired = {"n": 0}

    def injector(step, st):   # transient bad host: fires on first 2 attempts
        if step == FAULT_STEP and fired["n"] < 2:
            fired["n"] += 1
            return st._replace(params=jax.tree.map(
                lambda x: x * 8.0, st.params))
        return st

    monitor = Monitor(min_history=4, hang_min_seconds=30.0)
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    final, report = run_with_recovery(
        state, step_fn, get_batch, N_STEPS, ckpt, monitor,
        ckpt_every=CKPT_EVERY, plan=plan, fault_injector=injector,
        policy=RecoveryPolicy(), rescue_step=rescue_fn)

    assert report.actions == [(FAULT_STEP, "spike", "rollback"),
                              (FAULT_STEP, "spike", "lr_rescue")]
    assert report.restores == 2

    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        fn = rescue_fn if s == FAULT_STEP else step_fn
        ref, _ = fn(ref, get_batch(s))
    _assert_trees_equal(final.params, ref.params)


def test_recovery_gives_up_after_max_restores(tmp_path):
    """A persistent NaN exhausts max_restores and raises instead of looping."""
    model, step_fn, get_batch, state = _world("dense")

    def injector(step, st):
        if step == FAULT_STEP:
            return st._replace(params=jax.tree.map(
                lambda x: x * jnp.float32("nan"), st.params))
        return st

    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    with pytest.raises(RuntimeError, match="giving up after 2"):
        run_with_recovery(
            state, step_fn, get_batch, N_STEPS, ckpt,
            Monitor(min_history=4, hang_min_seconds=30.0),
            ckpt_every=CKPT_EVERY, fault_injector=injector,
            policy=RecoveryPolicy(max_restores=2))


def test_resume_continues_from_latest(tmp_path):
    """resume=True picks up at the latest checkpoint and the completed run
    matches an uninterrupted one (same-layout replay route)."""
    model, step_fn, get_batch, state = _world("dense")
    _, plan, _ = _arch("dense")
    ckpt = CheckpointManager(tmp_path, keep=3, async_persist=False)
    run_with_recovery(state, step_fn, get_batch, 10, ckpt,
                      Monitor(hang_min_seconds=30.0), ckpt_every=5, plan=plan)
    assert ckpt.latest_step() == 10

    tmpl = init_train_state(model, jax.random.PRNGKey(0))
    final, report = run_with_recovery(
        tmpl, step_fn, get_batch, N_STEPS, ckpt,
        Monitor(hang_min_seconds=30.0), ckpt_every=5, plan=plan, resume=True)

    ref = init_train_state(model, jax.random.PRNGKey(0))
    for s in range(N_STEPS):
        ref, _ = step_fn(ref, get_batch(s))
    assert report.steps_done == N_STEPS
    _assert_trees_equal(final.params, ref.params)


# ---------------------------------------------------------------------------
# Monitor units


def test_monitor_hang_window_not_contaminated():
    """A hang's wall-time must not enter the trailing median — otherwise one
    hang inflates the threshold and masks the next one."""
    m = Monitor(min_history=4, hang_factor=5.0)
    t = 0.0
    for s in range(8):
        m.record(s, 2.0, 1.0, now=t)
        t += 1.0
    a = m.record(8, 2.0, 1.0, now=t + 30.0)     # 31s vs 1s median
    assert a is not None and a.kind == "hang"
    assert max(m.times) == pytest.approx(1.0)   # 31s never entered the window
    # an identical second hang right after is still detected (median intact)
    a = m.record(9, 2.0, 1.0, now=t + 61.0)
    assert a is not None and a.kind == "hang"


def test_monitor_heartbeat_reset():
    """reset_heartbeat() absorbs non-step wall-time (checkpoint restore) —
    without it the next record() sees the gap as a hung step."""
    m = Monitor(min_history=4)
    t = 0.0
    for s in range(8):
        m.record(s, 2.0, 1.0, now=t)
        t += 1.0
    m.reset_heartbeat(now=t + 120.0)            # a 2-minute restore
    assert m.record(8, 2.0, 1.0, now=t + 121.0) is None


def test_monitor_hang_min_seconds_floor():
    m = Monitor(min_history=2, hang_min_seconds=10.0)
    t = 0.0
    for s in range(6):
        assert m.record(s, 2.0, 1.0, now=t) is None
        t += 0.01
    # 100x the median but under the absolute floor: not a hang
    assert m.record(6, 2.0, 1.0, now=t + 1.0) is None


# ---------------------------------------------------------------------------
# Checkpoint store: async snapshot, failure surfacing, reshard routing


def test_async_snapshot_isolated_from_donation(tmp_path):
    """The double-buffered snapshot clones on device before save() returns,
    so deleting the source buffers (what donation does) while the background
    copy drains must not corrupt the checkpoint."""
    tree = {"w": jnp.arange(4096, dtype=jnp.float32).reshape(64, 64),
            "step": jnp.asarray(7, jnp.int32)}
    want = {k: np.asarray(v) for k, v in tree.items()}
    mgr = CheckpointManager(tmp_path, async_snapshot=True)
    mgr.save(1, tree)
    assert mgr.snapshot_seconds < 1.0
    tree["w"].delete()                          # simulate donation
    tree["step"].delete()
    mgr.wait()
    fresh = {"w": jnp.zeros((64, 64), jnp.float32),
             "step": jnp.asarray(0, jnp.int32)}
    _, restored = mgr.restore(fresh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), want["w"])
    assert int(restored["step"]) == 7


def test_async_snapshot_matches_blocking(tmp_path):
    tree = {"w": jnp.arange(128, dtype=jnp.float32)}
    a = CheckpointManager(tmp_path / "a", async_snapshot=True)
    b = CheckpointManager(tmp_path / "b", async_snapshot=False)
    a.save(3, tree)
    b.save(3, tree, blocking=True)
    a.wait()
    za = np.load(tmp_path / "a" / "ckpt_00000003.npz")
    zb = np.load(tmp_path / "b" / "ckpt_00000003.npz")
    assert sorted(za.files) == sorted(zb.files)
    for k in za.files:
        np.testing.assert_array_equal(za[k], zb[k])


def test_persist_failure_surfaces_at_next_call(tmp_path):
    """A background persist failure must raise at the next save()/wait(),
    not vanish with the daemon thread."""
    import shutil
    d = tmp_path / "ckpts"
    mgr = CheckpointManager(d)
    tree = {"w": jnp.ones((8,))}
    shutil.rmtree(d)
    d.write_text("not a directory")             # make every write fail
    mgr.save(1, tree)
    with pytest.raises(RuntimeError, match="background checkpoint persist"):
        mgr.wait()
    mgr.wait()                                  # error raised once, then clear
    mgr.save(2, tree)
    with pytest.raises(RuntimeError, match="background checkpoint persist"):
        mgr.save(3, tree)                       # save() also surfaces it


def test_check_plan_routes_replay_reshard(tmp_path):
    tree = {"w": jnp.ones((8, 8))}
    mgr = CheckpointManager(tmp_path, async_persist=False)
    plan = ParallelPlan(cp=1)
    mgr.save(1, tree, blocking=True, plan=plan)
    assert mgr.check_plan(plan) == "replay"
    assert mgr.check_plan(ParallelPlan(cp=1), elastic=True) == "replay"
    # layout change: strict call refuses, elastic routes to reshard
    with pytest.raises(ValueError, match="layout mismatch"):
        mgr.check_plan(ParallelPlan(zero_stage=0))
    assert mgr.check_plan(ParallelPlan(zero_stage=0), elastic=True) == "reshard"
    # schedule/impl knobs are not layout: still replay
    assert mgr.check_plan(ParallelPlan(pp_schedule="gpipe")) == "replay"


def test_restore_resharded_matches_restore_single_device(tmp_path):
    """With no layout change, restore_resharded degrades to restore."""
    _, _, model = _arch("dense")
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, async_persist=False)
    mgr.save(4, state, blocking=True)
    _, a = mgr.restore(state)
    _, b = mgr.restore_resharded(state)
    _assert_trees_equal(a, b)


# ---------------------------------------------------------------------------
# Multidevice: cross-mesh reshard + the elastic 2×2 -> 1×2 acceptance run


def test_restore_resharded_cross_mesh(multidevice):
    """A checkpoint written row-sharded on a (4,) mesh restores column-
    sharded on a (2,2) mesh with identical values and the target layout."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

devs = jax.devices()
m1 = jax.make_mesh((4,), ("data",))
m2 = jax.make_mesh((2, 2), ("data", "model"))
x = jax.device_put(jnp.arange(32 * 32, dtype=jnp.float32).reshape(32, 32),
                   NamedSharding(m1, P("data", None)))
mgr = CheckpointManager(tempfile.mkdtemp(), async_persist=False)
mgr.save(1, {"w": x}, blocking=True, mesh=m1)

tgt = NamedSharding(m2, P(None, ("data", "model")))
step, out = mgr.restore_resharded({"w": x}, shardings={"w": tgt})
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
assert out["w"].sharding == tgt
assert len(out["w"].sharding.device_set) == 4
# every device now holds a (32, 8) column slice
assert out["w"].addressable_shards[0].data.shape == (32, 8)
print("cross-mesh reshard OK")
""", n_devices=4)


def test_elastic_remesh_2x2_to_1x2(multidevice):
    """The §8.3.2 acceptance: train on a 2×2 (data, model) mesh with ZeRO-1,
    hang at step 13 (simulated host loss), remesh to the surviving 1×2,
    reshard-restore params + data-scattered AdamW moments, and finish. The
    whole loss sequence and the final state must bit-match a reference that
    ran the same schedule with a direct device_put re-layout at the same
    boundary — i.e. the checkpoint/reshard path adds zero numerical
    perturbation."""
    multidevice("""
import time, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy, sharding)
from repro.data import SyntheticDataset
from repro.ft import Monitor, RemeshSpec, run_with_recovery
from repro.launch.mesh import shrink_mesh
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
plan = ParallelPlan(remat="none", compute_dtype="float32", zero_stage=1)
hyper = Hyper(peak_lr=1e-3, total_steps=40, z_loss=0.0)
ds = SyntheticDataset(cfg, InputShape("t", 16, 8, "train"))
get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
N, FAULT, EVERY = 20, 13, 5

mesh = jax.make_mesh((2, 2), ("data", "model"))
model = build_model(cfg, plan, mesh, ("data",))
state0 = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
step_big = jax.jit(make_train_step(model, plan, hyper, mesh=mesh))

# the surviving world: one data slice lost -> 1x2
mesh2 = shrink_mesh(mesh, "data", lost=1)
assert dict(mesh2.shape) == {"data": 1, "model": 2}
model2 = build_model(cfg, plan, mesh2, ("data",))
tmpl = init_train_state(model2, jax.random.PRNGKey(1), mesh=mesh2, plan=plan)
shardings = sharding.train_state_shardings(tmpl, cfg, plan, mesh2)
step_small = jax.jit(make_train_step(model2, plan, hyper, mesh=mesh2))
# warm the 1x2 compile now, on exactly the layout restore_resharded will
# produce (every leaf committed to its target sharding): the first
# post-remesh step's wall-time feeds the hang watchdog, and a cold compile
# there would read as another hang
tmpl = jax.tree.map(jax.device_put, tmpl, shardings)
jax.block_until_ready(step_small(tmpl, get_batch(0))[0].params)

def remesh():
    return RemeshSpec(train_step=step_small, state_template=tmpl,
                      shardings=shardings, plan=plan, mesh=mesh2)

fired = {"n": 0}
def injector(step, st):
    if step == FAULT and fired["n"] == 0:
        fired["n"] = 1
        time.sleep(1.0)          # the lost host: one step hangs
    return st

ckpt = CheckpointManager(tempfile.mkdtemp(), keep=3, async_persist=False)
final, report = run_with_recovery(
    state0, step_big, get_batch, N, ckpt,
    Monitor(min_history=4, hang_min_seconds=0.3),
    ckpt_every=EVERY, plan=plan, mesh=mesh,
    policy=RecoveryPolicy(hang="remesh"), fault_injector=injector,
    remesh=remesh)

assert report.remeshes == 1, report
assert report.restores == 1, report
assert report.actions == [(FAULT, "hang", "remesh")], report.actions
assert report.steps_done == N

# post-remesh checkpoints record the shrunken mesh
assert ckpt.manifest()["mesh_axes"] == {"data": 1, "model": 2}

# reference: same prefix on 2x2 (identical program), direct device_put
# re-layout at the rollback boundary (step 10), same continuation program
ref = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
ref_losses = []
for s in range(2 * EVERY):
    ref, m = step_big(ref, get_batch(s))
    ref_losses.append(float(m["loss"]))
ref = jax.tree.map(jax.device_put, ref, shardings)
for s in range(2 * EVERY, N):
    ref, m = step_small(ref, get_batch(s))
    ref_losses.append(float(m["loss"]))

assert report.losses == ref_losses, (report.losses, ref_losses)
for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(ref)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# the restored moments really live on the new layout (ZeRO-1 re-scatter)
mu_wq = final.opt.mu["layers"]["attn"]["wq"]
assert mu_wq.sharding.mesh.shape == mesh2.shape
print("elastic 2x2 -> 1x2 OK: losses bit-match, remeshes=1")
""", n_devices=4)
