"""Serving correctness: token-by-token decode must reproduce the parallel
forward pass for every family, and the distributed decode attention must match
the single-device path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ARCH_IDS, ParallelPlan, get_smoke_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=4)
    if cfg.moe:
        # capacity-based dropping is batch-composition dependent (a known MoE
        # train/serve inconsistency); decode parity is only exact dropless
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 8
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if "frames" in (model.cfg.family,):
        pass
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_frames, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision_tokens, cfg.d_model)), jnp.float32)
        batch["vision_pos"] = jnp.tile(
            jnp.arange(cfg.vision_tokens, dtype=jnp.int32)[None], (b, 1))

    logits, _ = model.forward(params, batch)
    cache = model.init_cache(b, s)
    if cfg.family == "audio":
        cache = model.extras["fill_cross"](params, cache, batch["frames"])

    if cfg.family == "vlm":
        # decode parity for VLM is checked on the pure-text region only
        pytest.skip("vlm decode parity covered by dense path (vision is prefill-only)")

    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t],
                                      jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    err = float(jnp.abs(dec - logits).max())
    assert err < 5e-3, f"{arch}: decode/forward mismatch {err}"


def test_distributed_decode_attention(multidevice):
    """shard_map logsumexp-combine decode attention == local reference,
    including the masked cache write, GQA, and sliding window."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.serve.attention import decode_attention

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
b, t, hq, hkv, hd = 4, 32, 8, 2, 16
q = jnp.asarray(rng.standard_normal((b, 1, hq, hd)), jnp.float32)
kc = jnp.asarray(rng.standard_normal((b, t, hkv, hd)), jnp.float32)
vc = jnp.asarray(rng.standard_normal((b, t, hkv, hd)), jnp.float32)
kn = jnp.asarray(rng.standard_normal((b, 1, hkv, hd)), jnp.float32)
vn = jnp.asarray(rng.standard_normal((b, 1, hkv, hd)), jnp.float32)

for pos in [0, 7, 31]:
    for window in [0, 5]:
        ref, rk, rv = decode_attention(q, kc, vc, kn, vn, jnp.int32(pos),
                                       window=window, mesh=None)
        out, ok, ov = decode_attention(q, kc, vc, kn, vn, jnp.int32(pos),
                                       window=window, mesh=mesh,
                                       batch_axes=("data",))
        err = float(jnp.abs(ref - out).max())
        cache_err = float(jnp.abs(jnp.asarray(rk) - jnp.asarray(ok)).max())
        assert err < 1e-5, (pos, window, err)
        assert cache_err < 1e-6, (pos, window, cache_err)
print("distributed decode attention OK")
""")
