"""Expert parallelism through the block executor (survey §4.1.5).

Equivalence contract: ``plan.ep > 1`` shards the routed experts over the
*folded* cp × model device ring (MoE parallel folding — attention keeps its
cp/tp mapping while the MoE sublayer re-reads the same devices as one flat
expert axis) and computes the same math as the single-device dense-dispatch
path, for BOTH ``ep_impl`` choices: the blocking all-to-all and the
overlapped ``ppermute``-tick ring of
:func:`repro.kernels.dispatch.dispatch_ep_a2a`. Exact when no tokens drop
(capacity_factor >= E/top_k — the same shard-local-routing contract cp/tp
use); loss to ~1 ulp of fp32 and gradients at reassociation tolerance.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Family, ModelConfig, MoEConfig, ParallelPlan
from repro.kernels.dispatch import EP_IMPLS, dispatch_ep_a2a, select_ep_impl


def _moe_cfg(e=4, k=2, cap=2.0, shared=0, layers=2):
    return ModelConfig("tmoe", Family.MOE, n_layers=layers, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                       moe=MoEConfig(num_experts=e, top_k=k, d_expert=64,
                                     num_shared_experts=shared,
                                     capacity_factor=cap))


# ---------------------------------------------------------------------------
# knob / dispatch / layout units (in-process: no devices needed)


def test_ep_knob_validation():
    cfg = _moe_cfg()
    with pytest.raises(ValueError, match="ep_impl"):
        ParallelPlan(ep_impl="ring").validate(cfg)
    # the legacy bool knob is rejected with a migration hint, not coerced
    with pytest.raises(ValueError, match="use ep=<degree>"):
        ParallelPlan(ep=True).validate(cfg)
    with pytest.raises(ValueError, match="use ep=<degree>"):
        ParallelPlan(ep=False).validate(cfg)
    with pytest.raises(ValueError, match="ep must be"):
        ParallelPlan(ep=0).validate(cfg)
    dense = ModelConfig("t", Family.DENSE, 2, 64, 4, 2, 128, 128)
    with pytest.raises(ValueError, match="MoE"):
        ParallelPlan(ep=2).validate(dense)
    # ep composes with tp only via the explicit rings
    with pytest.raises(ValueError, match="overlap"):
        ParallelPlan(ep=2, tp=2, tp_impl="gspmd").validate(cfg)
    with pytest.raises(ValueError, match="dp_over_model"):
        ParallelPlan(ep=2, dp_over_model=True).validate(cfg)
    # MoE parallel folding pins ep to cp×tp when either is engaged
    with pytest.raises(ValueError, match="must equal cp×tp"):
        ParallelPlan(ep=2, cp=2, tp=2, tp_impl="overlap").validate(cfg)
    ParallelPlan(ep=4, cp=2, tp=2, tp_impl="overlap").validate(cfg)
    ParallelPlan(ep=2, cp=2).validate(cfg)
    # expert count must split evenly over the ring
    with pytest.raises(ValueError, match="must divide num_experts"):
        ParallelPlan(ep=3).validate(_moe_cfg(e=4))
    # ep-only (mesh-checked later) and the cp-only composition are fine
    ParallelPlan(ep=2).validate(cfg)


def test_ep_token_dropping_divergence_is_flagged():
    """Shard-local routing with a token-dropping capacity factor warns at
    validation time (same documented divergence as cp / overlap-tp)."""
    dropping = _moe_cfg(cap=1.0)
    with pytest.warns(UserWarning, match="token-dropping"):
        ParallelPlan(ep=2).validate(dropping)
    # no-drop capacity (>= E/top_k) is exact: no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ParallelPlan(ep=2).validate(_moe_cfg(cap=2.0))


def test_select_ep_impl_rules():
    assert EP_IMPLS == ("auto", "blocking", "overlap")
    assert select_ep_impl("auto") == "overlap"
    assert select_ep_impl("blocking") == "blocking"
    assert select_ep_impl("overlap") == "overlap"
    with pytest.raises(ValueError, match="ep_impl"):
        select_ep_impl("bogus")


def test_dispatch_ep_a2a_degenerate_cases():
    """size == 1 delegates straight to fn; a non-divisible expert dim is a
    loud error before any collective is traced."""
    w = jnp.ones((4, 8, 8), jnp.float32)
    h = jnp.ones((4, 3, 8), jnp.float32)
    fn = lambda w_, h_: h_ + 1.0
    out = dispatch_ep_a2a(fn, w, h, axis="model", size=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(h) + 1.0)
    with pytest.raises(ValueError, match="divide"):
        dispatch_ep_a2a(fn, w, h, axis="model", size=3)
    with pytest.raises(ValueError, match="ep_impl"):
        dispatch_ep_a2a(fn, w, h, axis="model", size=2, impl="nope")


def test_ep_fold_layout_units():
    """ep_fold_axes / ep_spec_for_param are the single source of truth for
    the folded expert layout."""
    from jax.sharding import PartitionSpec as P
    from repro.core.sharding import ep_fold_axes, ep_spec_for_param

    assert ep_fold_axes(ParallelPlan()) == ()
    assert ep_fold_axes(ParallelPlan(ep=2)) == ("model",)
    assert ep_fold_axes(ParallelPlan(ep=2, cp=2)) == ("cp",)
    assert ep_fold_axes(ParallelPlan(ep=4, cp=2, tp=2, tp_impl="overlap")) \
        == ("cp", "model")
    assert ep_fold_axes(ParallelPlan(ep=2, tp=2, tp_impl="overlap")) \
        == ("model",)

    plan = ParallelPlan(ep=4, cp=2, tp=2, tp_impl="overlap")
    # stacked (layers) expert leaves shard the expert dim (dim 1)
    assert ep_spec_for_param(("layers", "moe", "experts", "gate"),
                             (2, 4, 64, 64), plan) \
        == P(None, ("cp", "model"), None, None)
    # unstacked expert leaves shard dim 0
    assert ep_spec_for_param(("moe", "experts", "down"), (4, 64, 64),
                             ParallelPlan(ep=2)) == P("model", None, None)
    # shared experts and the router replicate full-width
    assert ep_spec_for_param(("layers", "moe", "shared", "gate"),
                             (2, 64, 64), plan) == P(None, None, None)
    assert ep_spec_for_param(("layers", "moe", "router"), (2, 64, 4), plan) \
        == P(None, None, None)
    # non-MoE leaves keep their base (tp / replicated) classification
    assert ep_spec_for_param(("layers", "attn", "wq"), (2, 64, 64), plan) \
        is None
    assert ep_spec_for_param(("layers", "moe", "experts", "gate"),
                             (2, 4, 64, 64), ParallelPlan()) is None


def test_ep_dispatch_routing():
    """resolve_context folds the expert ring onto the resolved placement."""
    from repro.train.executor import resolve_context
    cfg = _moe_cfg(cap=2.0)

    class M:
        shape = {"data": 1, "model": 2}
    # ep-only: experts ride the model axis, attention becomes a cp ring on it
    ctx = resolve_context(cfg, ParallelPlan(ep=2), M, ("data",))
    assert ctx.tp is None and ctx.ep is not None
    assert ctx.ep.size == 2 and ctx.ep.axis == "model"
    assert ctx.cp is not None and ctx.cp.axis == "model" and ctx.cp.size == 2
    assert ctx.ep_impl == "overlap" and ctx.n_rep == 2
    assert ctx.aux_axes == ("data", "model")

    class M2:
        shape = {"data": 1, "cp": 2, "model": 2}
    ctx = resolve_context(
        cfg, ParallelPlan(ep=4, cp=2, tp=2, tp_impl="overlap",
                          ep_impl="blocking"), M2, ("data",))
    assert ctx.ep.size == 4 and ctx.ep.axis == ("cp", "model")
    assert ctx.tp.size == 2 and ctx.cp.axis == "cp" and ctx.cp.size == 2
    assert ctx.ep_impl == "blocking"
    assert ctx.aux_axes == ("data", "cp", "model") and ctx.n_rep == 4

    # a fold-size mismatch against the actual mesh is an error, not a
    # silent re-mapping
    with pytest.raises(ValueError, match="folded"):
        resolve_context(cfg, ParallelPlan(ep=2, cp=2, tp=2,
                                          tp_impl="overlap"), M2, ("data",))
    # ep-only needs a model axis of exactly that size to ride
    with pytest.raises(ValueError, match="model"):
        resolve_context(cfg, ParallelPlan(ep=4), M, ("data",))


def test_train_step_routes_ep():
    """make_train_step raises loudly when plan.ep has no mesh to fold onto
    (no silent GSPMD fallback for an explicit ep request)."""
    from repro.models import build_model
    from repro.train import Hyper, make_train_step
    cfg = _moe_cfg()
    plan = ParallelPlan(ep=2, compute_dtype="float32")
    model = build_model(cfg, plan)
    with pytest.raises(ValueError, match="ep"):
        make_train_step(model, plan, Hyper(), mesh=None)


# ---------------------------------------------------------------------------
# overlap == blocking == dense single-device, per MoE flavor


_FAMILY_EQUIV_TEMPLATE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import (Family, InputShape, ModelConfig, MoEConfig,
                        ParallelPlan)
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.executor import make_executor_loss_fn

cfg = {cfg}
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {{k: jnp.asarray(v) for k, v in ds.batch(0).items()}}
Z = 1e-4   # nonzero: z_loss must thread through the sharded nll reduction

plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
lf = make_loss_fn(model, Hyper(z_loss=Z))
ref_loss, ref_g = jax.jit(
    jax.value_and_grad(lambda p, b: lf(p, b)[0]))(params, batch)

def check(tag, plan, mesh, baxes, atol):
    elf = make_executor_loss_fn(cfg, plan, mesh, baxes, z_loss=Z)
    el, eg = jax.jit(jax.value_and_grad(lambda p, b: elf(p, b)[0]))(
        params, batch)
    assert abs(float(ref_loss) - float(el)) < 2e-6, (
        tag, float(ref_loss), float(el))
    for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(ref_g),
                                 jax.tree_util.tree_leaves_with_path(eg)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=atol,
            err_msg=f"{{tag}} {{jax.tree_util.keystr(path)}}")
    print(tag, "== single-device, loss", float(el))

# ep-only: 1x2 and 2x2 (data, model) meshes — experts ride the model axis
for mesh_shape in [(1, 2), (2, 2)]:
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    for impl in ("blocking", "overlap"):
        plan = ParallelPlan(remat="none", compute_dtype="float32", ep=2,
                            ep_impl=impl{extra_knobs})
        check(("ep-only", mesh_shape, impl), plan, mesh, ("data",), 1e-6)

# folded: ep == cp x tp == 4 on a (data, cp, model) mesh — attention and
# MoE use different mappings of the same four devices
mesh = jax.make_mesh((1, 2, 2), ("data", "cp", "model"))
for impl in ("blocking", "overlap"):
    plan = ParallelPlan(remat="none", compute_dtype="float32", cp=2, tp=2,
                        tp_impl="overlap", cp_impl="ring", ep=4,
                        ep_impl=impl{extra_knobs})
    check(("folded", impl), plan, mesh, ("data",), 3e-6)
print("EP_EQUIV_OK")
"""

# capacity_factor >= E/top_k -> no drops: ep routes per shard while the
# baseline routes globally, so drop *decisions* could differ; with no drops
# the per-token math is identical (the dropping case warns at validation —
# see test_ep_token_dropping_divergence_is_flagged)
_OLMOE_CFG = """ModelConfig("tmoe", Family.MOE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                 moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                               capacity_factor=2.0))"""
_DEEPSEEK_CFG = """ModelConfig("tmoe", Family.MOE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                 moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                               num_shared_experts=1, capacity_factor=2.0))"""


def test_ep_matches_single_device_olmoe(multidevice):
    """OLMoE-style routed-only MoE: overlap == blocking == dense."""
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_OLMOE_CFG, extra_knobs=""))


def test_ep_matches_single_device_deepseek_shared(multidevice):
    """DeepSeek-style shared experts stay replicated full-width next to the
    fold-sharded routed experts."""
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_DEEPSEEK_CFG,
                                              extra_knobs=""))


def test_ep_matches_single_device_scatter_dispatch(multidevice):
    """The MegaBlocks-style scatter dispatch feeds the same (E, C, d)
    buffers into the a2a seam as the einsum dispatch."""
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(
        cfg=_DEEPSEEK_CFG, extra_knobs=', moe_dispatch="scatter"'))


# ---------------------------------------------------------------------------
# EP x TP x CP x PP composition


def test_ep_pp_composition(multidevice):
    """The expert ring inside each pipeline tick, under both schedules, vs
    the per-microbatch single-device oracle (routing/aux are microbatch-local
    statistics — grad-accumulation semantics)."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, MoEConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tmoe", Family.MOE, n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                  moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                                num_shared_experts=1, capacity_factor=2.0))
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
Z = 1e-4
M = 4
plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
lf = make_loss_fn(model, Hyper(z_loss=Z))
mb = {k: v.reshape((M, v.shape[0] // M) + v.shape[1:])
      for k, v in batch.items()}
vg = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))
ref_losses, ref_gs = [], []
for i in range(M):
    l, g = vg(params, {k: v[i] for k, v in mb.items()})
    ref_losses.append(float(l)); ref_gs.append(g)
ref_loss = np.mean(ref_losses)
ref_g = jax.tree.map(lambda *x: sum(x) / M, *ref_gs)

def check(tag, plan, mesh, baxes, atol):
    plf = pipelined_loss_fn(cfg, plan, mesh, baxes, z_loss=Z)
    pl, pg = jax.jit(jax.value_and_grad(lambda p, b: plf(p, b)[0]))(
        params, batch)
    assert abs(float(ref_loss) - float(pl)) < 2e-6, (tag, float(pl))
    for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(ref_g),
                                 jax.tree_util.tree_leaves_with_path(pg)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=atol,
            err_msg=f"{tag} {jax.tree_util.keystr(path)}")
    print(tag, "== per-microbatch oracle, loss", float(pl))

# EP x CP x PP: the expert ring folds onto cp alone, both schedules
mesh = jax.make_mesh((2, 1, 2), ("pod", "data", "cp"))
for sched in ("gpipe", "1f1b"):
    plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2, cp=2,
                        ep=2, ep_impl="overlap", microbatches=M,
                        pp_schedule=sched, cp_impl="ring")
    check(("ep x cp x pp", sched), plan, mesh, ("data",), 1e-6)

# EP x TP x CP x PP: all four explicit axes in one 1F1B tick
mesh = jax.make_mesh((2, 2, 2), ("pod", "cp", "model"))
plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2, cp=2, tp=2,
                    ep=4, ep_impl="overlap", microbatches=M,
                    tp_impl="overlap", cp_impl="ring")
check("ep x tp x cp x pp (1f1b)", plan, mesh, (), 3e-6)

# ep-only has no axis to fold onto under pp — rejected, not mislaid
mesh = jax.make_mesh((2, 1), ("pod", "data"))
try:
    pipelined_loss_fn(cfg, ParallelPlan(pp=2, ep=2, microbatches=M),
                      mesh, ("data",))
    raise SystemExit("expected ep-only x pp to raise")
except ValueError as e:
    assert "ep-only" in str(e), e
print("EP_PP_OK")
""")


# ---------------------------------------------------------------------------
# checkpoint: the folded expert layout round-trips and reshards


def test_ep_checkpoint_reshard(multidevice):
    """EP-sharded state saves per-device expert shards, the manifest records
    ep + ep_impl, a mismatched ep layout is refused for replay, and
    restore_resharded re-places the experts onto a *different* ep fold."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np, json, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.core import Family, ModelConfig, MoEConfig, ParallelPlan
from repro.core.sharding import ep_spec_for_param
from repro.models.moe import init_moe

cfg = ModelConfig("tmoe", Family.MOE, 2, 64, 4, 2, 0, 128,
                  moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                                num_shared_experts=1, capacity_factor=2.0))
params = init_moe(jax.random.PRNGKey(0), cfg)

# save under the ep-only layout: experts over a 2-wide model axis
mesh_a = jax.make_mesh((1, 2), ("data", "model"))
plan_a = ParallelPlan(ep=2, ep_impl="overlap")

def place(params, plan, mesh):
    def one(path, leaf):
        names = tuple(str(getattr(p, "key", p)) for p in path)
        spec = ep_spec_for_param(names, tuple(leaf.shape), plan)
        return jax.device_put(
            leaf, NamedSharding(mesh, spec if spec is not None else P()))
    return jax.tree_util.tree_map_with_path(one, params)

placed = place(params, plan_a, mesh_a)
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, async_persist=False)
    path = mgr.save(5, placed, blocking=True, plan=plan_a, mesh=mesh_a)
    man = json.loads(path.with_suffix(".json").read_text())
    assert man["plan"]["ep"] == 2 and man["plan"]["ep_impl"] == "overlap"
    # the expert leaves persisted as per-device expert shards
    gi = man["names"].index("experts/gate")
    assert len(man["shards"][gi]) == 2, man["shards"][gi]
    data = np.load(str(path) + ".npz")
    for m in man["shards"][gi]:
        assert data[m["key"]].shape == (2, 64, 64), data[m["key"]].shape

    # same layout replays; a different ep fold is a layout mismatch
    mgr.check_plan(plan_a)
    mgr.check_plan(ParallelPlan(ep=2, ep_impl="blocking"))  # impl-only: fine
    try:
        mgr.check_plan(ParallelPlan(ep=4, cp=2, tp=2, tp_impl="overlap"))
        raise SystemExit("expected ep layout mismatch to raise")
    except ValueError as e:
        assert "layout mismatch" in str(e)

    # elastic reshard: restore onto the folded ep=4 layout (cp x model)
    plan_b = ParallelPlan(ep=4, cp=2, tp=2, tp_impl="overlap")
    mesh_b = jax.make_mesh((1, 2, 2), ("data", "cp", "model"))
    def shardings(path, leaf):
        names = tuple(str(getattr(p, "key", p)) for p in path)
        spec = ep_spec_for_param(names, tuple(leaf.shape), plan_b)
        return NamedSharding(mesh_b, spec if spec is not None else P())
    tgt = jax.tree_util.tree_map_with_path(shardings, params)
    step, back = mgr.restore_resharded(placed, tgt)
    assert step == 5
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # routed experts landed 4-way fold-sharded on the new mesh
    assert back["experts"]["gate"].sharding.spec == P(("cp", "model"),
                                                      None, None)
print("EP_CKPT_OK")
""")
