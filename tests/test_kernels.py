"""Per-kernel shape/dtype sweeps asserting allclose against the ref.py oracles
(interpret mode executes the kernel bodies in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import expert_gemm, flash_attention
from repro.kernels.ref import expert_gemm_ref, flash_attention_ref


def _t(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


FLASH_CASES = [
    # (b, hq, hkv, s, t, hd, causal, window, softcap)
    (2, 4, 2, 128, 128, 64, True, 0, 0.0),
    (1, 4, 4, 256, 256, 64, True, 32, 0.0),
    (2, 2, 1, 100, 100, 32, True, 0, 30.0),     # non-divisible seq (padding)
    (1, 8, 2, 128, 128, 128, False, 0, 0.0),
    (1, 2, 2, 64, 192, 64, True, 0, 0.0),       # cross lengths (q != kv)
    (1, 4, 1, 128, 128, 256, True, 4096, 50.0), # gemma2-like head_dim
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_allclose(case, dtype):
    b, hq, hkv, s, t, hd, causal, window, cap = case
    rng = np.random.default_rng(hash(case) % 2**32)
    q = _t(rng, (b, hq, s, hd), dtype)
    k = _t(rng, (b, hkv, t, hd), dtype)
    v = _t(rng, (b, hkv, t, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=cap)
    err = np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32))
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    assert err.max() < tol, f"{case} {dtype}: max err {err.max()}"


@pytest.mark.parametrize("blocks", [(32, 32, 32), (64, 128, 64)])
def test_flash_attention_block_shape_independence(blocks):
    """Output must not depend on the tiling choice."""
    bq, bk, _ = blocks
    rng = np.random.default_rng(7)
    q = _t(rng, (1, 2, 128, 64), jnp.float32)
    k = _t(rng, (1, 2, 128, 64), jnp.float32)
    v = _t(rng, (1, 2, 128, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=bq, block_k=bk)
    b = flash_attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


GEMM_CASES = [
    (4, 64, 128, 256),
    (2, 100, 130, 70),       # non-divisible everything (padding)
    (8, 128, 256, 512),
    (1, 32, 512, 64),
]


@pytest.mark.parametrize("case", GEMM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_gemm_allclose(case, dtype):
    e, c, d, f = case
    rng = np.random.default_rng(hash(case) % 2**32)
    x = _t(rng, (e, c, d), dtype)
    w = _t(rng, (e, d, f), dtype)
    out = expert_gemm(x, w, block_c=64, block_f=64, block_d=64)
    ref = expert_gemm_ref(x, w)
    a, r = np.asarray(out, np.float32), np.asarray(ref, np.float32)
    denom = np.maximum(np.abs(r), 1.0)
    rel = (np.abs(a - r) / denom).max()
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-5   # blocked accumulation order
    assert rel < tol, f"{case} {dtype}: max rel err {rel}"


SSD_CASES = [
    # (b, l, h, p, g, n, chunk)
    (2, 64, 4, 8, 2, 16, 16),
    (1, 128, 2, 16, 1, 32, 32),
    (1, 96, 4, 8, 4, 8, 24),       # chunk not power of two
    (2, 32, 8, 4, 2, 8, 32),       # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_chunk_scan_allclose(case):
    """Fused SSD kernel vs the pure-jnp ssd_scan oracle (y and final state)."""
    from repro.kernels import ssd_chunk_scan
    from repro.models.ssm import ssd_scan
    b, l, h, p, g, n, chunk = case
    rng = np.random.default_rng(hash(case) % 2**32)
    x = _t(rng, (b, l, h, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = _t(rng, (b, l, g, n), jnp.float32)
    C = _t(rng, (b, l, g, n), jnp.float32)
    y_ref, s_ref = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_k, s_k = ssd_chunk_scan(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
        B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_k.transpose(0, 2, 1, 3)),
                               np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_state_carries_across_chunks():
    """Zeroing the first chunk's inputs must change later chunks only through
    the carried state (which must then be exactly the remaining recurrence)."""
    from repro.kernels import ssd_chunk_scan
    rng = np.random.default_rng(5)
    b, l, h, p, g, n, chunk = 1, 64, 2, 8, 1, 8, 16
    x = _t(rng, (b, h, l, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.2, (b, h, l)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = _t(rng, (b, g, l, n), jnp.float32)
    C = _t(rng, (b, g, l, n), jnp.float32)
    y, _ = ssd_chunk_scan(x, dt, A, B, C, chunk=chunk)
    x2 = x.at[:, :, :chunk].set(0.0)
    y2, _ = ssd_chunk_scan(x2, dt, A, B, C, chunk=chunk)
    # first chunk output changed, later chunks differ (state propagated)
    assert float(jnp.abs(y[:, :, :chunk]).max()) > 0
    assert float(jnp.abs(y2[:, :, :chunk]).max()) < 1e-6
    assert float(jnp.abs(y[:, :, chunk:] - y2[:, :, chunk:]).max()) > 1e-6


def test_expert_gemm_expert_isolation():
    """Each expert's output must depend only on its own weight slice."""
    rng = np.random.default_rng(3)
    x = _t(rng, (4, 32, 64), jnp.float32)
    w = _t(rng, (4, 64, 32), jnp.float32)
    base = np.asarray(expert_gemm(x, w, block_c=32, block_f=32, block_d=32))
    w2 = w.at[2].set(0.0)
    out = np.asarray(expert_gemm(x, w2, block_c=32, block_f=32, block_d=32))
    assert np.allclose(out[2], 0.0)
    np.testing.assert_allclose(out[[0, 1, 3]], base[[0, 1, 3]], rtol=1e-6)
