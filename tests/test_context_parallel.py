"""Unified block executor + ring context parallelism (survey §4.1.4).

Equivalence contract: ``plan.cp > 1`` shards the *sequence* over the "cp"
mesh axis end to end and computes the same math as the single-device path —
ring attention merges per-chunk (out, lse) partials exactly (chunked
softmax), the SSD entering-state chain reproduces the sequential scan, MoE
routes on local shards (exact when no tokens drop). Loss is asserted to ~1
ulp of fp32 and gradients at float-reassociation tolerance (the same ≤1e-6
contract the overlap-TP suite uses; the cp×tp composition gets 3e-6 atol —
two ring reductions' reassociations stack).
"""

import warnings

import jax
import numpy as np
import pytest

from repro.core import Family, ModelConfig, MoEConfig, ParallelPlan, SSMConfig
from repro.kernels.dispatch import select_cp_impl


# ---------------------------------------------------------------------------
# knob / dispatch / layout units (in-process: no devices needed)


def test_cp_knob_validation():
    cfg = ModelConfig("t", Family.DENSE, 2, 64, 4, 4, 128, 128)
    with pytest.raises(ValueError, match="cp_impl"):
        ParallelPlan(cp_impl="bogus").validate(cfg)
    with pytest.raises(ValueError, match="cp must be"):
        ParallelPlan(cp=0).validate(cfg)
    ParallelPlan(cp=2, cp_impl="ring").validate(cfg)
    # cp composes with tp only via the explicit rings
    with pytest.raises(ValueError, match="overlap"):
        ParallelPlan(cp=2, tp=2, tp_impl="gspmd").validate(cfg)
    ParallelPlan(cp=2, tp=2, tp_impl="overlap").validate(cfg)
    # unsupported families are rejected up front
    hyb = ModelConfig("t", Family.HYBRID, 2, 64, 4, 2, 128, 128,
                      ssm=SSMConfig(d_state=16), shared_attn_every=2)
    with pytest.raises(ValueError, match="dense/moe/ssm"):
        ParallelPlan(cp=2).validate(hyb)


def test_cp_token_dropping_divergence_is_flagged():
    """Documented divergence (PR 4 / cp): shard-local routing with a
    token-dropping capacity factor must warn at validation time instead of
    silently differing from the global-routing baseline."""
    dropping = ModelConfig("t", Family.MOE, 2, 64, 4, 2, 0, 128,
                           moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                                         capacity_factor=1.0))
    with pytest.warns(UserWarning, match="token-dropping"):
        ParallelPlan(cp=2).validate(dropping)
    with pytest.warns(UserWarning, match="token-dropping"):
        ParallelPlan(tp=2, tp_impl="overlap").validate(dropping)
    # no-drop capacity (>= E/top_k) is exact: no warning
    nodrop = ModelConfig("t", Family.MOE, 2, 64, 4, 2, 0, 128,
                         moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                                       capacity_factor=2.0))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ParallelPlan(cp=2).validate(nodrop)
        ParallelPlan(tp=2, tp_impl="overlap").validate(nodrop)
    # GSPMD global routing never warns, dropping or not
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ParallelPlan().validate(dropping)


def test_select_cp_impl_rules():
    with pytest.raises(ValueError, match="cp_impl"):
        select_cp_impl("pallas")
    assert select_cp_impl("auto") == "ring"
    assert select_cp_impl("gather") == "gather"
    # sliding windows force gather (ring's per-pair masks are static)
    assert select_cp_impl("auto", window=128) == "gather"
    assert select_cp_impl("auto", local_global_alternating=True) == "gather"
    with pytest.raises(ValueError, match="ring"):
        select_cp_impl("ring", window=128)
    # the SSM family always runs the state chain (no KV to gather)
    assert select_cp_impl("gather", family=Family.SSM) == "ring"


def test_zigzag_layout_units():
    from repro.train.executor import zigzag_pair_counts, zigzag_permutation
    for seq, cp in [(16, 2), (32, 4), (48, 2)]:
        perm = zigzag_permutation(seq, cp)
        # a bijection over positions
        assert sorted(perm.tolist()) == list(range(seq))
        # rank r owns sub-chunks r and 2cp-1-r, each contiguous
        lc = seq // (2 * cp)
        for r in range(cp):
            chunk = perm[r * (seq // cp):(r + 1) * (seq // cp)]
            np.testing.assert_array_equal(chunk[:lc],
                                          np.arange(r * lc, (r + 1) * lc))
            np.testing.assert_array_equal(
                chunk[lc:], np.arange((2 * cp - 1 - r) * lc,
                                      (2 * cp - r) * lc))
        # load balance: every rank attends exactly the same number of causal
        # (q, k) pairs — the point of the zigzag
        counts = zigzag_pair_counts(seq, cp)
        assert counts.min() == counts.max(), counts
    # contiguous chunks are badly imbalanced by comparison (sanity)
    seq, cp = 32, 4
    contiguous = [int(np.sum(np.arange(r * 8, (r + 1) * 8) + 1))
                  for r in range(cp)]
    assert max(contiguous) > 3 * min(contiguous)


def test_executor_dispatch_routing():
    """The executor context resolves placement from plan + mesh shape."""
    from repro.train.executor import (ParallelContext, local_context,
                                      resolve_context)
    cfg = ModelConfig("t", Family.DENSE, 2, 64, 4, 2, 128, 128)

    class M:
        shape = {"data": 1, "cp": 2}
    ctx = resolve_context(cfg, ParallelPlan(cp=2), M, ("data",))
    assert ctx.tp is None and ctx.cp is not None and ctx.cp.size == 2
    assert ctx.cp_impl == "ring" and ctx.n_rep == 2

    class M2:
        shape = {"data": 2, "model": 2}
    ctx = resolve_context(cfg, ParallelPlan(tp=2, tp_impl="overlap"), M2,
                          ("data",))
    assert ctx.cp is None and ctx.tp is not None and ctx.tp.size == 2

    class M3:
        shape = {"data": 1, "cp": 2, "model": 2}
    ctx = resolve_context(
        cfg, ParallelPlan(cp=2, tp=2, tp_impl="overlap"), M3, ("data",))
    assert ctx.tp.size == 2 and ctx.cp.size == 2
    assert ctx.aux_axes == ("data", "cp")

    # plan.cp without a cp mesh axis is an error, not a silent fallback
    with pytest.raises(ValueError, match="cp"):
        resolve_context(cfg, ParallelPlan(cp=2), M2, ("data",))

    # the local context is the identity placement
    lc = local_context()
    assert isinstance(lc, ParallelContext)
    assert lc.tp is None and lc.cp is None and lc.n_tp == lc.n_cp == 1

    # the residual-stream layout contract: seq carries cp (and model when
    # the tp rings are on too)
    from jax.sharding import PartitionSpec as P
    from repro.core.sharding import cp_activation_spec
    assert cp_activation_spec(M, ParallelPlan(cp=2)) == \
        P(("data",), "cp", None)
    assert cp_activation_spec(
        M3, ParallelPlan(cp=2, tp=2, tp_impl="overlap")) == \
        P(("data",), ("cp", "model"), None)


def test_train_step_routes_cp():
    """make_train_step raises loudly when plan.cp has no cp mesh axis."""
    from repro.models import build_model
    from repro.train import Hyper, make_train_step
    cfg = ModelConfig("t", Family.DENSE, 2, 64, 4, 2, 128, 128)
    plan = ParallelPlan(cp=2, compute_dtype="float32")
    model = build_model(cfg, plan)
    with pytest.raises(ValueError, match="cp"):
        make_train_step(model, plan, Hyper(), mesh=None)


def test_chunk_attention_lse_entries():
    """The lse-merging chunk entries: pallas (interpret) == XLA twins, and
    two merged chunks == one full-KV call (the chunked-softmax identity)."""
    from repro.kernels.dispatch import (dispatch_attention,
                                        dispatch_attention_chunk_bwd,
                                        dispatch_attention_lse)
    from repro.train.executor import _merge_lse
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    b, s, hq, hkv, hd = 1, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)

    o_x, lse_x = dispatch_attention_lse(q, k, v, impl="xla", causal=True)
    o_p, lse_p = dispatch_attention_lse(q, k, v, impl="pallas", causal=True,
                                        block_q=16, block_k=16,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_x), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_x),
                               rtol=1e-5, atol=1e-5)

    # chunked-softmax merge: [full(K0) ; diag(K1)] partials == full attention
    half = s // 2
    o0, l0 = dispatch_attention_lse(q[:, half:], k[:, :half], v[:, :half],
                                    impl="xla", causal=False)
    o1, l1 = dispatch_attention_lse(q[:, half:], k[:, half:], v[:, half:],
                                    impl="xla", causal=True)
    om, lm = _merge_lse(jnp.zeros_like(o0, dtype=jnp.float32),
                        jnp.full(l0.shape, -1e30, jnp.float32), o0, l0)
    om, lm = _merge_lse(om, lm, o1, l1)
    ref = dispatch_attention(q, k, v, impl="xla", causal=True)
    np.testing.assert_allclose(np.asarray(om), np.asarray(ref[:, half:]),
                               rtol=1e-5, atol=1e-6)

    # chunk backward vs autodiff of the full call, summed over chunks
    do = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    full_o, full_lse = dispatch_attention_lse(q, k, v, impl="xla",
                                              causal=True)
    delta = jnp.sum(do * full_o.astype(jnp.float32), axis=-1)
    ref_dq, ref_dk, ref_dv = jax.vjp(
        lambda q_, k_, v_: dispatch_attention(q_, k_, v_, impl="xla",
                                              causal=True), q, k, v)[1](do)
    for impl, kw in [("xla", {}), ("pallas", dict(block_q=16, block_k=16,
                                                  interpret=True))]:
        dq = np.zeros(q.shape, np.float32)
        dk = np.zeros(k.shape, np.float32)
        dv = np.zeros(v.shape, np.float32)
        # chunk 0 (diag for q0, full-past for q1) + chunk 1 (diag for q1)
        g = dispatch_attention_chunk_bwd(
            q[:, :half], k[:, :half], v[:, :half], do[:, :half],
            full_lse[:, :half], delta[:, :half], impl=impl, causal=True, **kw)
        dq[:, :half] += g[0]; dk[:, :half] += g[1]; dv[:, :half] += g[2]
        g = dispatch_attention_chunk_bwd(
            q[:, half:], k[:, :half], v[:, :half], do[:, half:],
            full_lse[:, half:], delta[:, half:], impl=impl, causal=False,
            **kw)
        dq[:, half:] += g[0]; dk[:, :half] += g[1]; dv[:, :half] += g[2]
        g = dispatch_attention_chunk_bwd(
            q[:, half:], k[:, half:], v[:, half:], do[:, half:],
            full_lse[:, half:], delta[:, half:], impl=impl, causal=True, **kw)
        dq[:, half:] += g[0]; dk[:, half:] += g[1]; dv[:, half:] += g[2]
        np.testing.assert_allclose(dq, np.asarray(ref_dq), rtol=1e-4,
                                   atol=1e-5, err_msg=impl)
        np.testing.assert_allclose(dk, np.asarray(ref_dk), rtol=1e-4,
                                   atol=1e-5, err_msg=impl)
        np.testing.assert_allclose(dv, np.asarray(ref_dv), rtol=1e-4,
                                   atol=1e-5, err_msg=impl)


# ---------------------------------------------------------------------------
# ring == gather == single-device, per family


_FAMILY_EQUIV_TEMPLATE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import (Family, InputShape, ModelConfig, MoEConfig, SSMConfig,
                        ParallelPlan)
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.executor import make_executor_loss_fn

cfg = {cfg}
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {{k: jnp.asarray(v) for k, v in ds.batch(0).items()}}
Z = 1e-4   # nonzero: z_loss must thread through the sharded nll reduction

plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
lf = make_loss_fn(model, Hyper(z_loss=Z))
ref_loss, ref_g = jax.jit(
    jax.value_and_grad(lambda p, b: lf(p, b)[0]))(params, batch)

for mesh_shape in [(1, 2), (2, 2)]:
    mesh = jax.make_mesh(mesh_shape, ("data", "cp"))
    for impl in ("gather", "ring"):
        plan = ParallelPlan(remat="none", compute_dtype="float32", cp=2,
                            cp_impl=impl)
        clf = make_executor_loss_fn(cfg, plan, mesh, ("data",), z_loss=Z)
        cl, cg = jax.jit(
            jax.value_and_grad(lambda p, b: clf(p, b)[0]))(params, batch)
        assert abs(float(ref_loss) - float(cl)) < 2e-6, (
            mesh_shape, impl, float(ref_loss), float(cl))
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(ref_g),
                jax.tree_util.tree_leaves_with_path(cg)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6,
                err_msg=f"{{mesh_shape}} {{impl}} "
                        f"{{jax.tree_util.keystr(path)}}")
        print(mesh_shape, impl, "== single-device, loss", float(cl))
"""

_DENSE_CFG = """ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab=128)"""
# capacity_factor >= E/top_k -> no drops: cp routes per sequence shard while
# the baseline routes globally, so drop *decisions* could differ; with no
# drops the per-token math is identical (and the dropping case warns at
# validation — see test_cp_token_dropping_divergence_is_flagged)
_MOE_CFG = """ModelConfig("tmoe", Family.MOE, n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=0, vocab=128,
                 moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                               num_shared_experts=1, capacity_factor=2.0))"""
_SSM_CFG = """ModelConfig("tssm", Family.SSM, n_layers=2, d_model=64,
                 n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                 ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8))"""


def test_cp_matches_single_device_dense(multidevice):
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_DENSE_CFG))


def test_cp_matches_single_device_moe(multidevice):
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_MOE_CFG))


def test_cp_matches_single_device_mamba2(multidevice):
    """The SSD entering-state chain + conv halo across cp shards."""
    multidevice(_FAMILY_EQUIV_TEMPLATE.format(cfg=_SSM_CFG))


def test_cp_tp_composition(multidevice):
    """CP × TP: cp ring attention inside tp-ring-gathered blocks (dense),
    loss/grads vs the single-device oracle on a (data, cp, model) mesh."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.executor import make_executor_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
Z = 1e-4
plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
lf = make_loss_fn(model, Hyper(z_loss=Z))
ref_loss, ref_g = jax.jit(
    jax.value_and_grad(lambda p, b: lf(p, b)[0]))(params, batch)

for mesh_shape in [(1, 2, 2), (2, 2, 2)]:
    mesh = jax.make_mesh(mesh_shape, ("data", "cp", "model"))
    plan = ParallelPlan(remat="none", compute_dtype="float32", cp=2, tp=2,
                        tp_impl="overlap", cp_impl="ring")
    clf = make_executor_loss_fn(cfg, plan, mesh, ("data",), z_loss=Z)
    cl, cg = jax.jit(
        jax.value_and_grad(lambda p, b: clf(p, b)[0]))(params, batch)
    assert abs(float(ref_loss) - float(cl)) < 2e-6, (
        mesh_shape, float(ref_loss), float(cl))
    for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(ref_g),
                                 jax.tree_util.tree_leaves_with_path(cg)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=3e-6,
            err_msg=f"{mesh_shape} {jax.tree_util.keystr(path)}")
    print(mesh_shape, "cp x tp == single-device, loss", float(cl))
""")


# ---------------------------------------------------------------------------
# CP × PP composition + remat + train-step routing


def test_cp_pp_composition(multidevice):
    """CP inside each pipeline tick, under both schedules, vs single-device
    (the 1F1B backward splits its replicated-loss seed across cp ranks)."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
Z = 1e-4
plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
ref_loss, _ = make_loss_fn(model, Hyper(z_loss=Z))(params, batch)
ref_g = jax.grad(lambda p, b: make_loss_fn(model, Hyper(z_loss=Z))(p, b)[0])(
    params, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "cp"))
for sched in ("gpipe", "1f1b"):
    plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2, cp=2,
                        microbatches=4, pp_schedule=sched, cp_impl="ring")
    lf = pipelined_loss_fn(cfg, plan, mesh, ("data",), z_loss=Z)
    loss, grads = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))(
        params, batch)
    assert abs(float(loss) - float(ref_loss)) < 2e-6, (sched, float(loss))
    for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(ref_g),
                                 jax.tree_util.tree_leaves_with_path(grads)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6,
            err_msg=f"{sched} {jax.tree_util.keystr(path)}")
    print(sched, "CP x PP == single-device OK")

# CP x TP x PP: all three explicit axes in one 1F1B tick
mesh = jax.make_mesh((2, 2, 2), ("pod", "cp", "model"))
plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2, cp=2, tp=2,
                    microbatches=4, tp_impl="overlap", cp_impl="ring")
lf = pipelined_loss_fn(cfg, plan, mesh, (), z_loss=Z)
loss, grads = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))(
    params, batch)
assert abs(float(loss) - float(ref_loss)) < 2e-6, float(loss)
for (path, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(ref_g),
                             jax.tree_util.tree_leaves_with_path(grads)):
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=3e-6,
        err_msg=f"cp x tp x pp {jax.tree_util.keystr(path)}")
print("CP x TP x PP (1f1b) == single-device OK")
""")


def test_cp_remat_and_train_step(multidevice):
    """Remat policies compose with the cp ring custom-VJPs, and
    make_train_step(mesh=...) with plan.cp routes the executor loss."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.optim import adamw_init
from repro.train import Hyper, TrainState, make_loss_fn, make_train_step
from repro.train.executor import make_executor_loss_fn

cfg = ModelConfig("tiny", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
shape = InputShape("t", 16, 8, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
mesh = jax.make_mesh((2, 2), ("data", "cp"))

g0 = None
for remat in ("none", "selective", "full"):
    plan = ParallelPlan(remat=remat, compute_dtype="float32", cp=2,
                        cp_impl="ring")
    lf = make_executor_loss_fn(cfg, plan, mesh, ("data",), z_loss=0.0)
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    g = jax.jit(jax.grad(lambda p, b: lf(p, b)[0]))(params, batch)
    if g0 is None:
        g0 = g
    else:
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6, err_msg=remat)
print("remat none == selective == full under cp OK")

# one train step through make_train_step's cp routing == the GSPMD step
hyper = Hyper(peak_lr=1e-3, total_steps=10, z_loss=1e-4)
plan_c = ParallelPlan(remat="none", compute_dtype="float32", cp=2,
                      cp_impl="ring", zero_stage=0)
plan_r = ParallelPlan(remat="none", compute_dtype="float32", zero_stage=0)
model = build_model(cfg, plan_r)
params = model.init(jax.random.PRNGKey(0))
s_ref, _ = jax.jit(make_train_step(model, plan_r, hyper))(
    TrainState(params, adamw_init(params)), batch)
model_c = build_model(cfg, plan_c, mesh, ("data",))
s_cp, met = jax.jit(make_train_step(model_c, plan_c, hyper, mesh=mesh))(
    TrainState(params, adamw_init(params)), batch)
for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_cp.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)
print("cp train step == replicated train step, loss", float(met["loss"]))
""")


def test_cp_sharded_checkpoint_roundtrip(multidevice):
    """Shard-aware checkpointing under a cp mesh: save writes per-device
    shards (no host gather), the manifest records the ParallelPlan axes, and
    restore reassembles + re-places bit-identically; a mismatched plan is
    refused (ft replay safety)."""
    multidevice("""
import jax, jax.numpy as jnp, numpy as np, json, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.core import Family, ModelConfig, ParallelPlan

mesh = jax.make_mesh((2, 2), ("data", "cp"))
plan = ParallelPlan(cp=2, cp_impl="ring")
cfg = ModelConfig("t", Family.DENSE, 2, 64, 4, 2, 128, 128)
rng = np.random.default_rng(0)
tree = {
    "w": jax.device_put(jnp.asarray(rng.standard_normal((8, 64)), jnp.float32),
                        NamedSharding(mesh, P("data", None))),
    "x": jax.device_put(jnp.asarray(rng.standard_normal((4, 16)), jnp.float32),
                        NamedSharding(mesh, P("data", "cp"))),
    "r": jnp.asarray(rng.standard_normal((6,)), jnp.float32),   # replicated
    "s": jnp.float32(3.0),                                       # scalar
}
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, async_persist=False)
    path = mgr.save(3, tree, blocking=True, plan=plan, mesh=mesh)
    man = json.loads(path.with_suffix(".json").read_text())
    assert man["plan"]["cp"] == 2 and man["plan"]["cp_impl"] == "ring"
    assert man["mesh_axes"] == {"data": 2, "cp": 2}
    # the sharded leaf persisted as per-device shards, not one full array
    xi = man["names"].index("x")
    assert len(man["shards"][xi]) == 4, man["shards"][xi]
    data = np.load(str(path) + ".npz")
    x_keys = [m["key"] for m in man["shards"][xi]]
    assert all(data[k].shape == (2, 8) for k in x_keys), \
        {k: data[k].shape for k in x_keys}
    step, back = mgr.restore(tree)
    assert step == 3
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]), np.asarray(back[k]))
    # restored leaves keep their shardings (shard-to-shard restore)
    assert back["x"].sharding == tree["x"].sharding
    # a different layout is refused for replay
    mgr.check_plan(plan)                      # same plan: fine
    try:
        mgr.check_plan(ParallelPlan(cp=1))
        raise SystemExit("expected layout mismatch to raise")
    except ValueError as e:
        assert "layout mismatch" in str(e)
print("CP_CKPT_OK")
""")
