#!/usr/bin/env bash
# Tier-1 verification — the one entry point for CI and fresh clones.
# Mirrors ROADMAP.md: PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
