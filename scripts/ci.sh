#!/usr/bin/env bash
# Tier-1 verification — the one entry point for CI and fresh clones.
# Mirrors ROADMAP.md: PYTHONPATH=src python -m pytest -x -q
# then smokes every fused Pallas kernel fwd+bwd under pallas_call (interpret
# mode, one shape per op) plus a selective-remat train step, and records the
# remat-policy peak-memory/step-time trade-off to BENCH_trainstep.json.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
python -m benchmarks.run --quick
python -m benchmarks.run --only trainstep --json BENCH_trainstep.json
