#!/usr/bin/env bash
# Tier-1 verification — the one entry point for CI and fresh clones.
# Mirrors ROADMAP.md: PYTHONPATH=src python -m pytest -x -q
# then smokes every fused Pallas kernel fwd+bwd under pallas_call (interpret
# mode, one shape per op), the overlap-TP ring path vs gspmd on a 2-way model
# mesh (quick.tp.overlap), the zigzag ring context-parallel path vs the
# single-device oracle on a 2-way cp mesh (quick.cp.ring), the overlapped
# expert-parallel dispatch/combine ring vs dense dispatch on a 2-way expert
# mesh (quick.ep.overlap), and a
# selective-remat train step, the elastic recovery path — hang on a 2x2
# ZeRO-1 run, remesh to 1x2, reshard-restore, bit-matching losses
# (quick.ft.elastic) — and the chaos recovery path — a dropped shard write
# silently corrupting the newest checkpoint plus an injected NaN payload,
# recovered via CRC-verified fallback to the previous intact checkpoint with
# bit-matching params (quick.ft.chaos) — and the preemption path — a
# SIGTERM-style notice mid-run answered with a just-in-time snapshot, a
# PREEMPTED marker, and a bit-identical resume (quick.ft.preempt) — and the
# fail-slow path — a seeded slow fault on one pipeline stage attributed to
# (rank, compute) and rebalanced to an uneven pp_layout through an elastic
# reshard restore (quick.ft.straggler); records the remat-policy
# peak-memory/step-time trade-off to BENCH_trainstep.json, the
# gspmd-vs-overlap tokens/sec + bytes-transferred sweep to BENCH_tp.json, the
# gather-vs-ring context-parallel sweep (incl. the S=16k attention-block
# peak-memory assertion) to BENCH_cp.json, the blocking-vs-overlap
# expert-parallel sweep (exposed a2a bytes asserted fully converted to
# compute-interleaved ppermute ticks, both impls equal to the dense loss) to
# BENCH_ep.json, the checkpoint sweep — blocking vs
# double-buffered snapshot stall plus cross-mesh reshard-restore latency —
# to BENCH_ckpt.json, the fast-recovery sweep — RAM-tier restore asserted
# >= 10x faster than the verified disk restore, peer rebuild after a lost
# host-group bit-matching disk, just-in-time snapshot vs grace — to
# BENCH_recover.json, and the SDC integrity-audit overhead sweep (audit-vs-off
# step time per family, asserted < 2x) to BENCH_integrity.json, and the
# fail-slow economics sweep — detection latency in steps plus tokens/s
# baseline/degraded/rebalanced, rebalanced asserted strictly above degraded
# with >= 25% of the step-time overhead recovered — to BENCH_straggler.json
# (run.py prints
# a one-line delta vs the previous JSON so the perf trajectory is visible in
# CI logs; a missing previous JSON is reported as a first run, not an error).
#
# `-o pipefail` matters: the benchmark steps are tee'd into logs, and without
# it a crashing benchmark smoke would exit 0 through the pipe and pass
# silently.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
python -m benchmarks.run --quick | tee bench_quick.log
python -m benchmarks.run --only trainstep --json BENCH_trainstep.json | tee bench_trainstep.log
python -m benchmarks.run --only tp --json BENCH_tp.json | tee bench_tp.log
python -m benchmarks.run --only cp --json BENCH_cp.json | tee bench_cp.log
python -m benchmarks.run --only ep --json BENCH_ep.json | tee bench_ep.log
python -m benchmarks.run --only ckpt --json BENCH_ckpt.json | tee bench_ckpt.log
python -m benchmarks.run --only recover --json BENCH_recover.json | tee bench_recover.log
python -m benchmarks.run --only integrity --json BENCH_integrity.json | tee bench_integrity.log
python -m benchmarks.run --only straggler --json BENCH_straggler.json | tee bench_straggler.log
