"""Batched long-context serving with a sequence-sharded KV cache.

Demonstrates the survey-§4.1.4-adapted decode path: prefill a prompt, then
decode with the KV cache sharded (batch @ data, seq @ model) across an 8-device
host mesh, using the logsumexp-combine distributed attention. Greedy decoding
from the mamba2 (O(1)-state) and gemma2 (sliding-window) reduced configs shows
both long_500k-eligible cache disciplines.

    PYTHONPATH=src python examples/serve_longcontext.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses                                      # noqa: E402

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import ParallelPlan, get_smoke_config, sharding  # noqa: E402
from repro.models import build_model                    # noqa: E402


def serve(arch: str, max_ctx: int = 256, gen: int = 32):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=64, long_context=True)
    plan = ParallelPlan(remat="none", compute_dtype="float32",
                        seq_shard_decode=True)
    model = build_model(cfg, plan, mesh, ("data",))
    params = model.init(jax.random.PRNGKey(0))

    b = 4
    cache = model.init_cache(b, max_ctx)
    cspecs = sharding.cache_specs(cache, plan, mesh, ("data",))
    cache = jax.device_put(cache, jax.tree.map(
        lambda s: NamedSharding(mesh, s), cspecs,
        is_leaf=lambda x: isinstance(x, P)))
    kv_like = [k for k in ("k", "attn_k") if isinstance(cache, dict) and k in cache]
    for k in kv_like:
        print(f"{arch}: cache[{k}] {cache[k].shape} sharded "
              f"{cache[k].sharding.spec}")

    step = jax.jit(model.decode_step, donate_argnums=(1,))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (b, 16)).astype(np.int32)

    out_tokens = []
    if "prefill" in model.extras:
        # production path: parallel prefill emits the KV cache in one pass,
        # then the cache is laid out (batch@data, seq@model) for decode
        logits_all, cache = model.extras["prefill"](
            params, {"tokens": jnp.asarray(prompt)}, max_seq=max_ctx)
        cache = jax.device_put(cache, jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            sharding.cache_specs(cache, plan, mesh, ("data",)),
            is_leaf=lambda x: isinstance(x, P)))
        logits = logits_all[:, -1]
        pos = prompt.shape[1]
    else:
        # SSM state has no parallel-prefill shortcut here: run the recurrence
        pos = 0
        for t in range(prompt.shape[1]):
            logits, cache = step(params, cache, jnp.asarray(prompt[:, t]),
                                 jnp.int32(pos))
            pos += 1
    for _ in range(gen):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        pos += 1
    gen_arr = np.stack(out_tokens, 1)
    assert np.isfinite(np.asarray(logits)).all()
    print(f"{arch}: generated {gen_arr.shape} tokens, "
          f"first row: {gen_arr[0][:10]}...")


def main():
    serve("mamba2-370m")        # O(1) recurrent state decode
    serve("gemma2-9b")          # sliding-window seq-sharded KV decode
    print("long-context serving OK")


if __name__ == "__main__":
    main()
