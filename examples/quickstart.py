"""Quickstart: build a model, train it for a few hundred steps, watch it learn.

    PYTHONPATH=src python examples/quickstart.py

Uses the reduced qwen1.5-4b-family config on CPU; the identical code drives the
full config on a TPU pod (swap the mesh + config).
"""

import time

import jax
import jax.numpy as jnp

from repro.core import InputShape, ParallelPlan, get_smoke_config
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step


def main():
    cfg = get_smoke_config("qwen1.5-4b")
    plan = ParallelPlan(remat="selective", compute_dtype="float32")
    shape = InputShape("quickstart", seq_len=64, global_batch=8, kind="train")

    model = build_model(cfg, plan)
    state = init_train_state(model, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.arch_id} (reduced) params={n_params/1e6:.1f}M")

    hyper = Hyper(peak_lr=5e-3, warmup_steps=20, total_steps=200)
    step_fn = jax.jit(make_train_step(model, plan, hyper), donate_argnums=(0,))
    ds = SyntheticDataset(cfg, shape)

    t0 = time.time()
    for i in range(200):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        state, metrics = step_fn(state, batch)
        if i % 20 == 0 or i == 199:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}")
    toks = 200 * shape.global_batch * shape.seq_len
    print(f"done: {toks/(time.time()-t0):.0f} tokens/s on "
          f"{len(jax.devices())} device(s)")


if __name__ == "__main__":
    main()
