"""Pipeline parallelism across pods (survey §4.1.3) on a host-device mesh.

Builds the (pod=2, data=2, model=2) mesh, pipelines a 4-layer dense model as
2 stages over the ``pod`` axis under both schedules — GPipe fill-drain and the
memory-lean 1F1B custom-VJP schedule (``plan.pp_schedule``) — verifies both
against the non-pipelined loss, compares their compiled peak live memory, and
trains with the 1F1B schedule. Then composes TP x PP (survey §4.1.2 x
§4.1.3): ``plan.tp_impl = "overlap"`` runs the collective-matmul ring steps of
``train/tensor_parallel.py`` *inside* each 1F1B tick, with sequence-sharded
(mb, s/tp, d) activations rotating between stages and a vocab-parallel loss
on the last stage. Finally CP x TP x PP (§4.1.4, the long-context recipe):
``plan.cp`` shards the sequence itself over a "cp" mesh axis and zigzag ring
attention runs inside each tick, so no device ever holds full-context K/V.

    PYTHONPATH=src python examples/pipeline_multipod.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses                                      # noqa: E402

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.core import Family, InputShape, ModelConfig, ParallelPlan  # noqa: E402
from repro.data import SyntheticDataset                 # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.optim import adamw_init, adamw_update, clip_by_global_norm  # noqa: E402
from repro.train import Hyper, make_loss_fn             # noqa: E402
from repro.train.pipeline import pipelined_loss_fn      # noqa: E402


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ModelConfig("pipe-demo", Family.DENSE, n_layers=4, d_model=128,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab=512)
    # tp_impl pinned so the baseline stays the GSPMD pipeline even on TPU
    # backends (where "auto" resolves to overlap) — the TP x PP section below
    # flips it explicitly and compares against this
    plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                        microbatches=4, tp_impl="gspmd")
    shape = InputShape("pipe", seq_len=64, global_batch=8, kind="train")
    ds = SyntheticDataset(cfg, shape)

    model = build_model(cfg, ParallelPlan(remat="none",
                                          compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

    hyper = Hyper(z_loss=0.0)
    ref_loss, _ = make_loss_fn(model, hyper)(params, batch)
    print(f"non-pipelined loss {float(ref_loss):.6f}  "
          f"(bubble fraction {(plan.pp-1)/(plan.microbatches+plan.pp-1):.0%})")

    mems = {}
    for sched in ("gpipe", "1f1b"):
        pl = dataclasses.replace(plan, pp_schedule=sched)
        lf = pipelined_loss_fn(cfg, pl, mesh, ("data",))
        loss, _ = jax.jit(lf)(params, batch)
        assert abs(float(ref_loss) - float(loss)) < 2e-4
        gf = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))
        ma = gf.lower(params, batch).compile().memory_analysis()
        mems[sched] = getattr(ma, "temp_size_in_bytes", None) if ma else None
        print(f"{sched:>6} loss {float(loss):.6f}  "
              f"peak temp bytes {mems[sched]}")
    if all(mems.values()):
        print(f"1f1b keeps {mems['1f1b']/mems['gpipe']:.0%} of gpipe's "
              f"in-flight activation memory")

    # a few pipelined training steps under the 1F1B schedule (plan default)
    pipe_loss_fn = pipelined_loss_fn(cfg, plan, mesh, ("data",))
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: pipe_loss_fn(p, b)[0]))
    opt = adamw_init(params)
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        loss, grads = grad_fn(params, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(grads, opt, params, 1e-3)
        if i % 3 == 0:
            print(f"pipelined step {i}: loss {float(loss):.4f}")
    print("multi-pod pipeline training OK")

    # TP x PP: the same 1F1B pipeline with overlap tensor parallelism on the
    # model axis — ring-decomposed collective matmuls inside each stage tick,
    # (mb, s/tp, d) sequence shards on the stage-to-stage ppermute, and the
    # vocab-parallel cross-entropy on the last stage. Same loss, tp x smaller
    # inter-stage transfers and between-block activations.
    tp_plan = dataclasses.replace(plan, tp=2, tp_impl="overlap")
    tp_loss_fn = pipelined_loss_fn(cfg, tp_plan, mesh, ("data",))
    tp_loss, _ = jax.jit(tp_loss_fn)(params, batch)
    base_loss, _ = jax.jit(pipe_loss_fn)(params, batch)
    assert abs(float(tp_loss) - float(base_loss)) < 2e-5
    print(f"TP x PP (1f1b + overlap rings) loss {float(tp_loss):.6f} == "
          f"pp-only loss {float(base_loss):.6f}")

    # CP x TP x PP — the long-context recipe (survey §4.1.4): the sequence
    # itself is sharded over a "cp" mesh axis end to end, so each device
    # holds (mb, s/(cp·tp), d) activations between blocks and zigzag ring
    # attention ppermutes KV chunks *inside* each 1F1B tick — no device ever
    # materializes full-context K/V or scores. At real long-context sizes
    # (train/executor.py: plan.cp=8, S=512k) this is what keeps attention
    # activation memory, the long-S bottleneck, flat per device.
    cp_mesh = jax.make_mesh((2, 2, 2), ("pod", "cp", "model"))
    cp_plan = dataclasses.replace(plan, tp=2, tp_impl="overlap",
                                  cp=2, cp_impl="ring")
    cp_loss_fn = pipelined_loss_fn(cfg, cp_plan, cp_mesh, ())
    cp_loss, _ = jax.jit(cp_loss_fn)(params, batch)
    assert abs(float(cp_loss) - float(base_loss)) < 2e-5
    print(f"CP x TP x PP (zigzag ring attention in each 1F1B tick) loss "
          f"{float(cp_loss):.6f} == pp-only loss {float(base_loss):.6f}")

    # EP x TP x CP x PP — MoE parallel folding inside each tick (survey
    # §4.1.5): a MoE twin of the demo config re-reads each stage's cp x model
    # devices as one flat ep=4 expert ring; the dispatch/combine all-to-all
    # runs as overlapped ppermute ticks interleaved with expert-GEMM chunks
    # (``plan.ep_impl``), all inside the same 1F1B schedule. The overlapped
    # ring and the blocking all-to-all are the same math.
    from repro.core import MoEConfig
    moe_cfg = dataclasses.replace(
        cfg, family=Family.MOE, d_ff=0,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                      num_shared_experts=1, capacity_factor=2.0))
    moe_params = build_model(moe_cfg, ParallelPlan(
        remat="none", compute_dtype="float32")).init(jax.random.PRNGKey(1))
    ep_losses = {}
    for impl in ("blocking", "overlap"):
        ep_plan = dataclasses.replace(cp_plan, ep=4, ep_impl=impl)
        ep_loss_fn = pipelined_loss_fn(moe_cfg, ep_plan, cp_mesh, ())
        ep_losses[impl], _ = jax.jit(ep_loss_fn)(moe_params, batch)
        print(f"EP x TP x CP x PP ({impl:>8} a2a) loss "
              f"{float(ep_losses[impl]):.6f}")
    assert abs(float(ep_losses["overlap"]) - float(ep_losses["blocking"])) \
        < 1e-6
    print("MoE parallel folding in the pipeline OK: overlapped ring == "
          "blocking all-to-all")


if __name__ == "__main__":
    main()
