"""Fail-slow defense walkthrough (survey §8.1): detect, attribute, rebalance.

A degraded device drags the whole pipeline down to its pace — the classic
fail-slow failure mode (Malleus, Falcon): nothing crashes, MFU just quietly
halves. This demo runs the full defense ladder on a 2-stage pipeline:

1. a deterministic ``slow`` fault (``ft/inject``) pins a per-layer host
   delay to pipeline stage 1 from step 6 onward;
2. the :class:`~repro.ft.straggler.StragglerTimer` telemetry feeds the
   sliding-window detector, which attributes the slowdown to
   ``(rank=1, pp.stage, compute)`` after ``confirm`` consecutive slow steps
   — work-share-normalized, so an *intentionally* uneven layout would not
   false-positive;
3. ``RecoveryPolicy.straggler = "rebalance"`` invokes
   :func:`~repro.ft.straggler.choose_pp_layout` on the *measured* per-stage
   times: the degraded stage sheds a layer, (2, 2) -> (3, 1);
4. the driver restores the latest checkpoint through the **elastic reshard
   path** (``pp_layout`` is a layout axis in the manifest) and continues on
   the uneven layout — degraded, but no longer paced by the slow stage.

    PYTHONPATH=src python examples/straggler_rebalance.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses                                      # noqa: E402
import tempfile                                         # noqa: E402
import time                                             # noqa: E402

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.checkpoint import CheckpointManager          # noqa: E402
from repro.core import (Family, InputShape, ModelConfig,  # noqa: E402
                        ParallelPlan, RecoveryPolicy)
from repro.data import SyntheticDataset                 # noqa: E402
from repro.ft import (Monitor, RemeshSpec, StragglerDetector,  # noqa: E402
                      StragglerTimer, run_with_recovery)
from repro.ft.inject import FaultSpec, armed            # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.train.pipeline import pipelined_loss_fn      # noqa: E402


def main():
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    cfg = ModelConfig("slow-demo", Family.DENSE, n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
    plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                        microbatches=4)
    ds = SyntheticDataset(cfg, InputShape("demo", 32, 8, "train"))
    get_batch = lambda s: {k: jnp.asarray(v)                # noqa: E731
                           for k, v in ds.batch(s).items()}

    model = build_model(cfg, ParallelPlan(remat="none",
                                          compute_dtype="float32"))
    state0 = {"params": model.init(jax.random.PRNGKey(0))}

    def make_step(pl):
        """SGD over the pipelined loss under layout ``pl.pp_layout``."""
        lf = pipelined_loss_fn(cfg, pl, mesh, ("data",))

        def step(state, batch):
            loss, grads = jax.value_and_grad(
                lambda p, b: lf(p, b)[0])(state["params"], batch)
            params = jax.tree.map(lambda p, g: p - 1e-3 * g,
                                  state["params"], grads)
            gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                              for g in jax.tree.leaves(grads)))
            return {"params": params}, {"loss": loss, "grad_norm": gn}
        return jax.jit(step)

    # the defense stack: telemetry -> detector -> policy -> rebalance hook
    detector = StragglerDetector(window=8, factor=2.0, confirm=3,
                                 min_seconds=1e-3)
    timer = StragglerTimer(cfg=cfg, plan=plan, detector=detector)
    policy = RecoveryPolicy(straggler="rebalance", max_restores=4)
    monitor = Monitor(hang_min_seconds=60.0)  # straggler ladder owns this

    def rebalance(layout):
        print(f"[demo] rebalance hook: measured stage times "
              f"{ {r: f'{t * 1e3:.1f}ms' for r, t in timer.stage_times().items()} } "
              f"-> pp_layout {layout}")
        pl2 = dataclasses.replace(plan, pp_layout=tuple(layout))
        return RemeshSpec(train_step=make_step(pl2), state_template=state0,
                          plan=pl2, mesh=mesh)

    ckpt = CheckpointManager(tempfile.mkdtemp(), keep=4, async_persist=False)

    # the fault: stage 1 pays 40ms of extra host time per layer it holds,
    # every step from 6 on — a condition, not an event (span covers the run)
    fault = FaultSpec("pp.stage.tick", "slow", step=6, span=999, rank=1,
                      sleep_s=0.04)
    print("[demo] injecting fail-slow on pipeline stage 1 from step 6; "
          "policy.straggler = rebalance")
    t0 = time.time()
    with armed([fault]):
        final, report = run_with_recovery(
            state0, make_step(plan), get_batch, 18, ckpt, monitor,
            ckpt_every=3, plan=plan, mesh=mesh, policy=policy,
            straggler=timer, rebalance=rebalance)
    dt = time.time() - t0

    strag = [a for a in report.anomalies if a.kind == "straggler"]
    assert strag and report.rebalances == 1, (strag, report)
    print(f"[demo] first attribution at step {strag[0].step}: "
          f"{strag[0].detail}")
    for s, kind, action in report.actions:
        print(f"[demo]   step {s}: {kind} -> {action}")
    print(f"[demo] {report.steps_done} steps in {dt:.1f}s, "
          f"rebalances={report.rebalances}, restores={report.restores}, "
          f"final loss {report.losses[-1]:.4f}")
    print("[demo] straggler rebalance walkthrough OK")


if __name__ == "__main__":
    main()
