"""Expert-parallel MoE training (survey §4.1.5) on a multi-device host mesh.

Re-executes itself with 8 forced host devices, builds a (2 data × 4 model)
mesh, and trains an OLMoE-family reduced config with experts sharded over the
``model`` axis and tokens exchanged via all_to_all — the GShard execution
model, end to end with sharded AdamW.

    PYTHONPATH=src python examples/train_moe_ep.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import InputShape, ParallelPlan, get_smoke_config, sharding  # noqa: E402
from repro.data import SyntheticDataset                 # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.optim import adamw_init                      # noqa: E402
from repro.train import Hyper, TrainState, make_train_step  # noqa: E402


def main():
    assert len(jax.devices()) == 8, "expected 8 forced host devices"
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke_config("olmoe-1b-7b")
    plan = ParallelPlan(ep=True, zero_stage=1, remat="selective",
                        compute_dtype="float32")
    shape = InputShape("moe-ep", seq_len=64, global_batch=8, kind="train")

    model = build_model(cfg, plan, mesh, ("data",))
    params = model.init(jax.random.PRNGKey(0))
    pspecs = sharding.param_specs(params, cfg, plan, mesh)
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P)))
    state = TrainState(params, adamw_init(params))

    expert_leaf = params["layers"]["moe"]["experts"]["gate"]
    print(f"experts tensor {expert_leaf.shape} sharded as "
          f"{expert_leaf.sharding.spec} over mesh {dict(mesh.shape)}")

    step_fn = jax.jit(make_train_step(model, plan, Hyper(
        peak_lr=5e-3, warmup_steps=10, total_steps=100)), donate_argnums=(0,))
    ds = SyntheticDataset(cfg, shape)
    for i in range(100):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        state, m = step_fn(state, batch)
        if i % 20 == 0 or i == 99:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"moe_aux {float(m['moe_aux']):.4f}")
    print("expert-parallel MoE training OK")


if __name__ == "__main__":
    main()
