"""Expert-parallel MoE training (survey §4.1.5) on a multi-device host mesh.

Re-executes itself with 8 forced host devices and trains an OLMoE-family
reduced config through the block executor's expert-parallel route:
``plan.ep`` shards the routed experts over the mesh's ``model`` axis and the
dispatch/combine token exchange runs as the overlapped ``ppermute`` ring of
``kernels/dispatch.dispatch_ep_a2a`` — each ring tick computes the expert
chunk it already holds while the next chunk is in flight (``ep_impl =
"overlap"``; ``"blocking"`` is the exposed GShard-style ``all_to_all`` pair).

Two placements are shown:

- **ep-only** on a (data=2, model=4) mesh: experts ride the model axis and
  attention runs sequence-sharded as a cp ring over those same devices;
- **MoE parallel folding** on a (data=1, cp=2, model=2) mesh: attention keeps
  its cp × tp mapping while the MoE sublayer re-reads the same four devices
  as one flat ep=4 expert ring — parallelism is remapped per sublayer, not
  added.

    PYTHONPATH=src python examples/train_moe_ep.py
"""

import dataclasses
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.core import InputShape, ParallelPlan, get_smoke_config  # noqa: E402
from repro.core.sharding import ep_spec_for_param       # noqa: E402
from repro.data import SyntheticDataset                 # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.train import Hyper, init_train_state, make_train_step  # noqa: E402
from repro.train.executor import make_executor_loss_fn  # noqa: E402


def main():
    assert len(jax.devices()) == 8, "expected 8 forced host devices"
    cfg = get_smoke_config("olmoe-1b-7b")
    # no-drop capacity (>= E/top_k): shard-local routing is then exactly the
    # dense-dispatch math — the regime the equivalence tests pin down
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=2.0))
    e = cfg.moe.num_experts

    # --- ep-only: experts over the model axis, overlapped a2a ring ---------
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = ParallelPlan(ep=4, ep_impl="overlap", zero_stage=1,
                        remat="selective", compute_dtype="float32")
    shape = InputShape("moe-ep", seq_len=64, global_batch=8, kind="train")

    model = build_model(cfg, plan)
    state = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh,
                             plan=plan)
    spec = ep_spec_for_param(("layers", "moe", "experts", "gate"),
                             (cfg.n_layers, e, cfg.d_model,
                              cfg.moe.d_expert), plan)
    print(f"{e} experts sharded {spec} over mesh {dict(mesh.shape)}: "
          f"{e // 4} expert(s) per ring rank, ep_impl={plan.ep_impl}")

    step_fn = jax.jit(make_train_step(model, plan, Hyper(
        peak_lr=5e-3, warmup_steps=10, total_steps=100), mesh=mesh),
        donate_argnums=(0,))
    ds = SyntheticDataset(cfg, shape)
    for i in range(100):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        state, m = step_fn(state, batch)
        if i % 20 == 0 or i == 99:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"moe_aux {float(m['moe_aux']):.4f}")
    print("expert-parallel MoE training OK")

    # --- MoE parallel folding: ep == cp x tp on a (2, 2, 2) mesh -----------
    # Attention runs as a zigzag cp ring over "cp" with overlap-TP rings over
    # "model"; the MoE sublayer re-reads those same cp x model devices as one
    # flat expert axis. Overlap and blocking a2a are the same math.
    fold_mesh = jax.make_mesh((2, 2, 2), ("data", "cp", "model"))
    # host copies: the trained params are committed to the ep-only mesh
    params = jax.device_get(state.params)
    losses = {}
    for impl in ("blocking", "overlap"):
        fplan = ParallelPlan(ep=4, ep_impl=impl, cp=2, cp_impl="ring",
                             tp=2, tp_impl="overlap", remat="selective",
                             compute_dtype="float32")
        lf = make_executor_loss_fn(cfg, fplan, fold_mesh, ("data",))
        losses[impl], _ = jax.jit(lf)(params, batch)
        print(f"folded ep=4 (cp=2 x tp=2) {impl:>8} a2a  "
              f"loss {float(losses[impl]):.6f}")
    assert abs(float(losses["overlap"]) - float(losses["blocking"])) < 1e-6
    print("MoE parallel folding OK: overlapped ring == blocking all-to-all")


if __name__ == "__main__":
    main()
