"""Surviving preemption: graceful SIGTERM exit + bit-identical resume.

    PYTHONPATH=src python examples/preempt_resume.py

Spot/preemptible capacity delivers a SIGTERM with a grace window before the
host is reclaimed (survey §8, cloud-native training). This example runs the
recovery driver three times over the same schedule:

1. an *uninterrupted* reference run (the ground truth);
2. a run that receives a preemption notice mid-training — the driver
   flushes the in-flight checkpoint, takes a just-in-time snapshot within
   the grace budget, writes a ``PREEMPTED`` marker, and returns cleanly;
3. a ``resume=True`` run that consumes the marker, restores from the JIT
   snapshot, and finishes the schedule — landing on params bit-identical
   to the reference (the deterministic data pipeline makes replay exact).

Along the way a hot in-memory checkpoint tier (peer-redundant RAM ring)
serves any rollback without disk I/O, and a flight recorder keeps the
black-box event log a post-mortem would read.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, MemoryCheckpointTier
from repro.core import InputShape, ParallelPlan, get_smoke_config
from repro.data import SyntheticDataset
from repro.ft import FlightRecorder, Monitor, run_with_recovery
from repro.ft.preempt import PreemptionGuard, read_marker
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step

N_STEPS = 40
PREEMPT_AT = 23      # the "cloud" sends SIGTERM before this step


def main():
    cfg = get_smoke_config("qwen1.5-4b")
    plan = ParallelPlan(remat="selective", compute_dtype="float32")
    shape = InputShape("preempt", seq_len=32, global_batch=4, kind="train")

    model = build_model(cfg, plan)
    hyper = Hyper(peak_lr=5e-3, warmup_steps=5, total_steps=N_STEPS)
    step_fn = jax.jit(make_train_step(model, plan, hyper))
    ds = SyntheticDataset(cfg, shape)

    def get_batch(i):
        return {k: jnp.asarray(v) for k, v in ds.batch(i).items()}

    def fresh_state():
        return init_train_state(model, jax.random.PRNGKey(0))

    # quiet monitor: tiny CPU steps jitter enough to trip the hang watchdog
    def quiet():
        return Monitor(min_history=1000, hang_min_seconds=60.0)

    # 1) uninterrupted reference
    ref_dir = tempfile.mkdtemp(prefix="preempt_ref_")
    ref_state, _ = run_with_recovery(
        fresh_state(), step_fn, get_batch, N_STEPS,
        CheckpointManager(ref_dir, keep=3), quiet(), ckpt_every=10)
    print(f"reference run: {N_STEPS} steps, no interruptions")

    # 2) preempted run — guard.trigger() stands in for the cloud's SIGTERM
    #    (a real deployment uses `with PreemptionGuard(grace=30.0) as guard`
    #    and the signal arrives from outside; see repro.launch.train)
    run_dir = tempfile.mkdtemp(prefix="preempt_run_")
    flight = FlightRecorder(maxlen=256, path=f"{run_dir}/flight.json")
    guard = PreemptionGuard(grace=30.0, signals=())
    mem = MemoryCheckpointTier(keep=2, peer_redundancy=True, groups=2,
                               flight=flight)

    def notice(step, state):
        if step == PREEMPT_AT:
            guard.trigger()          # the preemption notice lands
        return state

    _, report = run_with_recovery(
        fresh_state(), step_fn, get_batch, N_STEPS,
        CheckpointManager(run_dir, keep=3, flight=flight), quiet(),
        ckpt_every=10, fault_injector=notice,
        mem_ckpt=mem, preempt=guard, flight=flight)
    marker = read_marker(run_dir)
    print(f"preempted at step {report.preempt_step}: marker={marker['tier']} "
          f"snapshot, flight log -> {report.flight_path}")

    # 3) resume: consumes the marker, restores the JIT snapshot, finishes
    resumed, report2 = run_with_recovery(
        fresh_state(), step_fn, get_batch, N_STEPS,
        CheckpointManager(run_dir, keep=3), quiet(),
        ckpt_every=10, resume=True)
    assert read_marker(run_dir) is None    # consumed on resume

    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref_state.params),
                        jax.tree.leaves(resumed.params)))
    print(f"resumed {report2.steps_done - report.preempt_step} remaining "
          f"steps; params bit-identical to uninterrupted run: {same}")
    assert same


if __name__ == "__main__":
    main()
