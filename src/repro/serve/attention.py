"""Distributed decode attention (survey §4.1.4, TPU adaptation — DESIGN.md §2).

KV cache layout: ``(batch@data, seq@model, kv_heads, head_dim)``. Sequence
sharding is the only dimension that scales for every assigned arch (GQA kv_heads
of 8–32 < model axis 16) and every context length (long_500k: 512k × model16 =
32k rows/device).

The GPU-survey approach is ring attention (P2P chunk rotation). On a TPU torus
XLA strongly prefers whole-axis collectives, so we adapt: each ``model`` rank
computes exact attention over its local KV chunk, then one logsumexp-combine
``psum`` merges (max, denominator, weighted output). Exact result, O(S/N)
memory, one small all-reduce of (B, H, hd)-sized tensors per layer instead of N
ring steps.

The cache *write* needs no communication: the rank owning position ``pos``
applies a masked dynamic_update_slice; everyone else no-ops.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map

from repro.models.layers import NEG_INF, _softcap


def _local_decode_attn(q, k, v, *, valid_mask, softcap, scale):
    """q: (B, Hkv, G, hd); k/v: (B, T_loc, Hkv, hd); valid_mask: (B?, T_loc) bool.

    Returns un-normalized (o (B,Hkv,G,hd) fp32, m (B,Hkv,G), l (B,Hkv,G)).
    """
    s = jnp.einsum("bkgd,btkd->bkgt", q, k, preferred_element_type=jnp.float32)
    s = _softcap(s * scale, softcap)
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None]) * valid_mask[:, None, None, :]
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def combine_lse(parts):
    """Merge [(o, m, l), ...] partial attention results exactly."""
    ms = jnp.stack([m for _, m, _ in parts])
    m = ms.max(axis=0)
    o = sum(op * jnp.exp(mp - m)[..., None] for op, mp, _ in parts)
    l = sum(lp * jnp.exp(mp - m) for _, mp, lp in parts)
    return o, m, l


def decode_attention(
    q: jax.Array,            # (B, 1, Hq, hd) — replicated over model axis
    k_cache: jax.Array,      # (B, T, Hkv, hd) — seq sharded over model axis
    v_cache: jax.Array,
    k_new: jax.Array,        # (B, 1, Hkv, hd) current token's K/V
    v_new: jax.Array,
    pos,                     # scalar int: index of the current token
    *,
    window: int = 0,
    softcap: float = 0.0,
    mesh: Optional[Mesh] = None,
    batch_axes: Tuple[str, ...] = ("data",),
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (attn_out (B, 1, Hq, hd), new_k_cache, new_v_cache).

    Positions 0..pos-1 of the cache are valid history; the current token's K/V
    are written at ``pos`` and attended to (self-attention includes self).
    With ``window > 0`` only keys with pos - j < window participate.
    """
    b, _, hq, hd = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = hd ** -0.5
    qg = q.reshape(b, hkv, g, hd)

    def _window_mask(valid, jpos):
        """Apply sliding-window constraint; ``window`` may be a traced scalar
        (per-layer metadata scanned through the decode loop)."""
        if isinstance(window, int) and window == 0:
            return valid
        w = jnp.asarray(window)
        return valid & jnp.where(w > 0, (pos - jpos) < w, True)

    if mesh is None or "model" not in mesh.shape:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, axis=1)
        t = k_cache.shape[1]
        jpos = jnp.arange(t)
        valid = _window_mask(jpos <= pos, jpos)
        valid = jnp.broadcast_to(valid, (b, t))
        o, m, l = _local_decode_attn(qg, k_cache, v_cache, valid_mask=valid,
                                     softcap=softcap, scale=scale)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return out.reshape(b, 1, hq, hd), k_cache, v_cache

    tp = mesh.shape["model"]
    t_loc = k_cache.shape[1] // tp

    def local(qg_, kc, vc, kn, vn, pos_):
        rank = jax.lax.axis_index("model")
        start = rank * t_loc
        # masked cache write: only the owner rank applies the DUS
        local_idx = jnp.clip(pos_ - start, 0, t_loc - 1)
        own = (pos_ >= start) & (pos_ < start + t_loc)
        kc2 = jax.lax.dynamic_update_slice_in_dim(kc, kn, local_idx, axis=1)
        vc2 = jax.lax.dynamic_update_slice_in_dim(vc, vn, local_idx, axis=1)
        kc = jnp.where(own, kc2, kc)
        vc = jnp.where(own, vc2, vc)

        jpos = start + jnp.arange(t_loc)
        valid = jpos <= pos_
        if not (isinstance(window, int) and window == 0):
            w = jnp.asarray(window)
            valid &= jnp.where(w > 0, (pos_ - jpos) < w, True)
        valid = jnp.broadcast_to(valid, (kc.shape[0], t_loc))
        o, m, l = _local_decode_attn(qg_, kc, vc, valid_mask=valid,
                                     softcap=softcap, scale=scale)
        # exact logsumexp combine across the model axis
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        o = jax.lax.psum(o * corr[..., None], "model")
        l = jax.lax.psum(l * corr, "model")
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(qg_.dtype)
        return out, kc, vc

    baxes = batch_axes if batch_axes else None   # () -> replicated batch
    cache_spec = P(baxes, "model", None, None)
    rep_spec = P(baxes, None, None, None)
    out, k_cache, v_cache = shard_map(
        local, mesh=mesh,
        in_specs=(P(baxes, None, None, None), cache_spec, cache_spec,
                  rep_spec, rep_spec, P()),
        out_specs=(P(baxes, None, None, None), cache_spec, cache_spec),
    )(qg, k_cache, v_cache, k_new, v_new, pos)
    return out.reshape(b, 1, hq, hd), k_cache, v_cache
