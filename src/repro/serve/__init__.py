from .attention import decode_attention

__all__ = ["decode_attention"]
