"""Build (step_fn, arg ShapeDtypeStructs, shardings) for any
(arch × input-shape × mesh × plan) combination — the single entry point used by
the dry-run, the trainer and the benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ModelConfig, ParallelPlan, SHAPES_BY_NAME, get_config, sharding
from repro.core.config import InputShape
from repro.configs import input_specs
from repro.models import build_model
from repro.train import Hyper, make_train_step, TrainState
from repro.optim import adamw_init
from .mesh import batch_axes_for


def resolve_config(arch: str, shape_name: str, smoke: bool = False) -> ModelConfig:
    from repro.core import get_smoke_config
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if shape_name == "long_500k" and cfg.arch_id == "gemma2-9b":
        cfg = dataclasses.replace(cfg, long_context=True)   # sliding-window variant
    return cfg


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: long_500k skipped per DESIGN.md §4"
    return None


def _ns(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def jit_step(fn, in_shardings, meta):
    """jit a build_step result with its input shardings and buffer donation
    (train steps donate the TrainState so params/moments alias in place)."""
    return jax.jit(fn, in_shardings=in_shardings,
                   donate_argnums=meta.get("donate_argnums", ()))


def build_step(arch: str, shape_name: str, mesh: Mesh, plan: ParallelPlan,
               smoke: bool = False):
    """Returns (fn, args_sds tuple, in_shardings tuple, meta dict).

    - train:   fn(state, batch) -> (state, metrics)
    - prefill: fn(params, batch) -> logits
    - decode:  fn(params, cache, tokens, pos) -> (logits, cache)
    """
    shape = SHAPES_BY_NAME[shape_name]
    cfg = resolve_config(arch, shape_name, smoke)
    reason = skip_reason(cfg, shape)
    if reason:
        raise ValueError(reason)

    baxes = batch_axes_for(mesh, shape.global_batch, plan.pp,
                           plan.dp_over_model)
    model = build_model(cfg, plan, mesh, baxes)
    rng = jax.random.PRNGKey(0)

    params_sds = jax.eval_shape(model.init, rng)
    pspecs = sharding.param_specs(params_sds, cfg, plan, mesh)
    pshard = _ns(mesh, pspecs)
    bspec = P(baxes if baxes else None)

    meta = {"cfg": cfg, "shape": shape, "batch_axes": baxes, "model": model}

    if shape.kind == "train":
        hyper = Hyper()
        step = make_train_step(model, plan, hyper, mesh=mesh)
        state_sds = jax.eval_shape(
            lambda r: TrainState(model.init(r), adamw_init(model.init(r))), rng)
        ospecs = sharding.opt_state_specs(pspecs, params_sds, plan, mesh)
        state_specs = TrainState(
            params=pspecs,
            opt=type(state_sds.opt)(step=P(), mu=ospecs, nu=ospecs))
        state_shard = _ns(mesh, state_specs)
        batch_sds = input_specs(cfg, shape)
        batch_shard = {k: NamedSharding(mesh, P(baxes if baxes else None,
                                                *([None] * (len(v.shape) - 1))))
                       for k, v in batch_sds.items()}
        # donate the TrainState: params + fp32 moments update in place under
        # jit instead of doubling peak memory for the step's duration
        meta["donate_argnums"] = (0,)
        return step, (state_sds, batch_sds), (state_shard, batch_shard), meta

    if shape.kind == "prefill":
        def fn(params, batch):
            logits, _ = model.forward(params, batch)
            return logits
        batch_sds = input_specs(cfg, shape)
        batch_shard = {k: NamedSharding(mesh, P(baxes if baxes else None,
                                                *([None] * (len(v.shape) - 1))))
                       for k, v in batch_sds.items()}
        return fn, (params_sds, batch_sds), (pshard, batch_shard), meta

    # decode
    specs = input_specs(cfg, shape, model)
    cache_sds, tokens_sds, pos_sds = specs["cache"], specs["tokens"], specs["pos"]
    cspecs = sharding.cache_specs(cache_sds, plan, mesh, baxes)
    cshard = _ns(mesh, cspecs)

    def fn(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    args = (params_sds, cache_sds, tokens_sds, pos_sds)
    shardings = (pshard, cshard, NamedSharding(mesh, bspec),
                 NamedSharding(mesh, P()))
    return fn, args, shardings, meta
