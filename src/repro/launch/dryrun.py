import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked at 512) -----

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402

from repro.core import ARCH_IDS, INPUT_SHAPES, ParallelPlan, SHAPES_BY_NAME  # noqa: E402
from repro.core.config import Family  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.stepbuilder import build_step, jit_step, resolve_config, skip_reason  # noqa: E402
from repro.perf import Roofline, model_flops_for  # noqa: E402
from repro.perf.hlo_cost import analyze_hlo  # noqa: E402

"""Multi-pod dry-run (assignment deliverable (e)).

For every (architecture × input shape × mesh) combination: lower + compile the
step function against ShapeDtypeStruct inputs on the production mesh (no
allocation), print memory/cost analysis, and persist a JSON record with the
roofline terms (deliverable (g) reads these).

`XLA_FLAGS=--xla_force_host_platform_device_count=512` is set in the FIRST TWO
LINES of this file, before any other import — jax locks the device count on
first init, and ONLY the dry-run may see 512 placeholder devices.
"""


def default_plan(arch: str) -> ParallelPlan:
    """The paper-faithful baseline recipe (DESIGN.md §0): TP over ``model``,
    DP + ZeRO-1 over ``data``, full remat, EP for MoE archs (folded onto the
    16-wide tp ring — ``ep`` is a degree now, pinned to cp×tp)."""
    cfg = resolve_config(arch, "train_4k")
    return ParallelPlan(
        tp=16,
        dp_shard=1,
        zero_stage=1,
        ep=16 if cfg.family == Family.MOE else 1,
        remat="full",
    )


def plan_from_args(arch: str, args) -> ParallelPlan:
    plan = default_plan(arch)
    overrides = {}
    if args.dp_shard is not None:
        overrides["dp_shard"] = args.dp_shard
    if args.remat is not None:
        overrides["remat"] = args.remat
    if args.zero is not None:
        overrides["zero_stage"] = args.zero
    if args.no_ep:
        overrides["ep"] = 1
    if args.no_seq_shard:
        overrides["seq_shard_decode"] = False
        overrides["seq_shard_attn"] = False
    if args.pad_vocab:
        overrides["pad_vocab_to_multiple"] = args.pad_vocab
    if args.microbatches:
        overrides["microbatches"] = args.microbatches
    if args.dp_over_model:
        overrides["dp_over_model"] = True
        overrides["ep"] = 1
    if args.moe_dispatch:
        overrides["moe_dispatch"] = args.moe_dispatch
    return dataclasses.replace(plan, **overrides) if overrides else plan


def run_one(arch: str, shape_name: str, multi_pod: bool, plan: ParallelPlan,
            out_dir: Path, tag: str = "baseline") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    shape = SHAPES_BY_NAME[shape_name]
    cfg = resolve_config(arch, shape_name)
    reason = skip_reason(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "plan": dataclasses.asdict(plan)}
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: SKIP ({reason})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    fn, args, shardings, meta = build_step(arch, shape_name, mesh, plan)

    with mesh:
        jitted = jit_step(fn, shardings, meta)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover - backend specific
        mem, mem_rec = None, {"error": str(e)}

    # trip-count-aware HLO walk (cost_analysis counts scan bodies once; our
    # layer stacks are scans — see repro/perf/hlo_cost.py)
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo, chips)
    roof = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=hc.flops, hlo_bytes=hc.bytes,
        collective_bytes=hc.collective_link_bytes,
        model_flops=model_flops_for(meta["cfg"], shape),
        collectives={"counts": hc.collective_counts,
                     "link_bytes": hc.collective_bytes_by_kind},
    )

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "transcendentals")},
        "roofline": roof.row(),
    })
    print(f"[dryrun] {arch} × {shape_name} × {mesh_name} [{tag}]: OK "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s) "
          f"t_comp={roof.t_compute:.3e}s t_mem={roof.t_memory:.3e}s "
          f"t_coll={roof.t_collective:.3e}s -> {roof.bottleneck}-bound")
    if mem_rec.get("temp_size_in_bytes") is not None:
        print(f"         memory: args={mem_rec['argument_size_in_bytes']} "
              f"out={mem_rec['output_size_in_bytes']} "
              f"temp={mem_rec['temp_size_in_bytes']} (per device)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in INPUT_SHAPES] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    # plan overrides (hillclimbing knobs)
    ap.add_argument("--dp-shard", type=int, default=None)
    ap.add_argument("--remat", default=None,
                    choices=["none", "selective", "full", None])
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--no-ep", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--pad-vocab", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--dp-over-model", action="store_true")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["einsum", "scatter", None])
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run requires 512 placeholder devices"

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else [s.name for s in INPUT_SHAPES]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        plan = plan_from_args(arch, args)
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = out_dir / f"{args.tag}__{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    print(f"[dryrun] exists: {path.name}")
                    n_ok += 1
                    continue
                try:
                    rec = run_one(arch, shape, mp, plan, out_dir, args.tag)
                    if rec["status"] == "ok":
                        n_ok += 1
                    else:
                        n_skip += 1
                except Exception as e:
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "failed", "error": repr(e)}
                    print(f"[dryrun] {arch} × {shape} × {mesh_name}: FAILED {e!r}")
                path.write_text(json.dumps(rec, indent=2, default=str))
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
