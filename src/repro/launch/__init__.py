from .mesh import batch_axes_for, make_local_mesh, make_production_mesh, shrink_mesh

__all__ = ["batch_axes_for", "make_local_mesh", "make_production_mesh",
           "shrink_mesh"]
