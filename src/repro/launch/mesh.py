"""Production mesh definition (as a function — importing this module must not
touch jax device state).

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

Axis semantics (DESIGN.md §3): ``model`` is the innermost/highest-locality axis
(TP/EP/sequence), ``data`` is DP/FSDP, ``pod`` crosses the inter-pod DCN and
carries either DP (default) or pipeline stages. ``cp`` (context parallelism,
survey §4.1.4) splits off the data axis when requested: it carves the
*sequence* dimension, so it wants locality between ``data`` and ``model`` —
ring-attention ppermutes are nearest-neighbour transfers, heavier than DP's
once-per-step grad reduction but lighter than TP's per-GEMM rings.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False, cp: int = 1):
    """``cp > 1`` splits the data axis into (data/cp, cp): same chip count,
    sequence sharded over the new "cp" axis (``ParallelPlan.cp``)."""
    if cp > 1:
        if 16 % cp:
            raise ValueError(f"cp={cp} must divide the 16-wide data axis")
        shape = (2, 16 // cp, cp, 16) if multi_pod else (16 // cp, cp, 16)
        axes = (("pod", "data", "cp", "model") if multi_pod
                else ("data", "cp", "model"))
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(shape: Tuple[int, ...] = None, axes: Tuple[str, ...] = None):
    """Small mesh over whatever devices exist (tests: 8 host devices)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n) if n > 1 else (1, 1)
        axes = ("data", "model")
    return jax.make_mesh(shape, axes)


def shrink_mesh(mesh, axis: str, lost: int = 1):
    """Rebuild ``mesh`` after simulated host loss: drop ``lost`` slices of
    ``axis`` (survey §8.3.2 elastic recovery — resume on fewer hosts).

    Keeps the surviving devices and every other axis intact, e.g. a 2×2
    ("data", "model") mesh losing one data slice becomes 1×2. The caller
    re-jits its step and reshard-restores onto the result.
    """
    from jax.sharding import Mesh  # noqa: PLC0415
    if axis not in mesh.shape:
        raise ValueError(f"mesh has no axis {axis!r}: {dict(mesh.shape)}")
    size = mesh.shape[axis]
    if lost >= size:
        raise ValueError(f"cannot drop {lost} of {size} {axis!r} slices")
    dim = mesh.axis_names.index(axis)
    keep = [slice(None)] * mesh.devices.ndim
    keep[dim] = slice(0, size - lost)
    return Mesh(mesh.devices[tuple(keep)], mesh.axis_names)


def batch_axes_for(mesh, global_batch: int, pp: int = 1,
                   dp_over_model: bool = False) -> Tuple[str, ...]:
    """Mesh axes to shard the batch over, largest-first, divisibility-checked.

    long_500k has global_batch=1 — the batch stays replicated and parallelism
    comes entirely from the model/sequence dimensions. With ``dp_over_model``
    (mesh remap for small models) the model axis also carries batch.
    """
    axes = []
    div = 1
    wanted = ("pod", "data", "model") if dp_over_model else ("pod", "data")
    candidates = [a for a in wanted if a in mesh.shape]
    if pp > 1 and "pod" in candidates:
        candidates.remove("pod")          # pod axis carries pipeline stages
    for a in candidates:
        if global_batch % (div * mesh.shape[a]) == 0:
            axes.append(a)
            div *= mesh.shape[a]
    return tuple(axes)
