"""End-to-end training driver.

Runs real steps on the available devices (CPU in this container; the same code
path drives a TPU slice — the mesh is the only difference):

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \\
        --steps 50 --batch 8 --seq 128

Integrates the full substrate: synthetic data pipeline, sharded AdamW + ZeRO-1,
remat, checkpointing (async persist, optional double-buffered snapshots), and
anomaly-driven recovery (survey §8): NaN/spike -> rollback-and-replay,
repeated spike -> LR-rescue, hang -> advisory or elastic remesh. ``--resume``
continues from the latest checkpoint in ``--ckpt-dir`` — including one
written on a *different* mesh layout (elastic reshard-restore, §8.3.2).

Fast-recovery layer (§8.3.1): ``--ckpt-memory-keep K`` keeps a hot RAM ring
of the last K snapshots (peer-mirrored unless ``--no-peer-redundancy``) that
every rollback restores from before touching disk. A SIGTERM/SIGUSR1
(spot-instance preemption notice) is caught between steps: the driver takes
a just-in-time snapshot within ``--preempt-grace`` seconds, writes a
``PREEMPTED`` marker, and exits 0 — rerun with ``--resume`` to continue
bit-identically. ``--flight-path`` arms the crash flight recorder: a
bounded ring of per-step events dumped to JSON on preemption, crash, or
recovery exhaustion for post-mortem attribution.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import ARCH_IDS, InputShape, ParallelPlan, RecoveryPolicy
from repro.core.config import RECOVERY_ACTIONS, Family
from repro.checkpoint import CheckpointManager, MemoryCheckpointTier
from repro.data import Prefetcher, SyntheticDataset
from repro.ft import (FlightRecorder, Monitor, StragglerTimer,
                      run_with_recovery)
from repro.ft.preempt import PreemptionGuard
from repro.launch.mesh import batch_axes_for, make_local_mesh
from repro.launch.stepbuilder import resolve_config
from repro.models import build_model
from repro.train import Hyper, TrainState, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (full configs need a real pod)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="selective",
                    choices=["none", "selective", "full"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in --ckpt-dir "
                         "instead of starting fresh; a checkpoint written on "
                         "a different mesh layout is reshard-restored onto "
                         "the current one (elastic recovery, survey §8.3.2)")
    ap.add_argument("--async-snapshot", action="store_true",
                    help="double-buffer the device->host checkpoint snapshot "
                         "(survey §8.3.1): save() only dispatches a device-"
                         "side clone and the copy+write overlap later steps, "
                         "at the cost of transiently one extra state copy in "
                         "device memory")
    ap.add_argument("--on-nan", default="rollback", choices=RECOVERY_ACTIONS,
                    help="recovery action for a non-finite loss/grad-norm")
    ap.add_argument("--on-spike", default="rollback", choices=RECOVERY_ACTIONS,
                    help="recovery action for a first loss spike at a step")
    ap.add_argument("--on-repeated-spike", default="lr_rescue",
                    choices=RECOVERY_ACTIONS,
                    help="action when the same step spikes again after a "
                         "rollback (replay alone would loop): lr_rescue "
                         "replays it with LR x --rescue-lr-scale")
    ap.add_argument("--on-hang", default="ignore", choices=RECOVERY_ACTIONS,
                    help="action for a hung/straggling step (wall-time >> "
                         "trailing median); 'ignore' logs only")
    ap.add_argument("--on-straggler", default="ignore",
                    choices=RECOVERY_ACTIONS,
                    help="action for a confirmed fail-slow attribution "
                         "(survey §8.1): 'ignore' logs the (rank, component, "
                         "class) triple; 'rebalance' re-partitions "
                         "layers-per-stage (Malleus-style pp_layout) when a "
                         "pipeline stage is the straggler")
    ap.add_argument("--straggler-factor", type=float, default=2.0,
                    help="relative slowdown (work-normalized, vs peer median "
                         "or trailing window) that counts as slow")
    ap.add_argument("--straggler-window", type=int, default=16,
                    help="sliding-window length of the straggler detector")
    ap.add_argument("--straggler-confirm", type=int, default=3,
                    help="consecutive slow observations before an attribution "
                         "is emitted (detection latency in steps)")
    ap.add_argument("--prefetch", action="store_true",
                    help="synthesize the next batch on a background thread "
                         "while the device step runs (pure host work; batch "
                         "contents are unchanged)")
    ap.add_argument("--rescue-lr-scale", type=float, default=0.1,
                    help="LR multiplier used by the lr_rescue policy while "
                         "replaying the offending step")
    ap.add_argument("--max-restores", type=int, default=3,
                    help="give up after this many checkpoint restores")
    ap.add_argument("--simulate-hang-at", type=int, default=-1,
                    help="fault injection for demos/tests: sleep 2s before "
                         "this step so the hang watchdog fires (-1 = off)")
    ap.add_argument("--integrity", default="off", choices=["off", "audit"],
                    help="silent-data-corruption audit (survey §8.2): 'audit' "
                         "adds an exact param/grad checksum to every step, "
                         "cross-checked across replicas; any divergence "
                         "raises an 'sdc' anomaly routed through --on-sdc")
    ap.add_argument("--on-sdc", default="rollback", choices=RECOVERY_ACTIONS,
                    help="recovery action when the integrity audit detects "
                         "replica checksum divergence")
    ap.add_argument("--ckpt-memory-keep", type=int, default=2,
                    help="hot in-memory checkpoint tier (survey §8.3.1): RAM "
                         "ring of the last K snapshots restored before any "
                         "disk walk; 0 disables the tier")
    ap.add_argument("--no-peer-redundancy", dest="peer_redundancy",
                    action="store_false", default=True,
                    help="skip mirroring each host-group's RAM shards onto "
                         "its ring neighbor (halves hot-tier RAM, loses "
                         "tolerance to a lost host-group)")
    ap.add_argument("--preempt-grace", type=float, default=30.0,
                    help="seconds of grace between a preemption notice "
                         "(SIGTERM/SIGUSR1) and the kill; the just-in-time "
                         "snapshot tier is chosen so it fits this budget")
    ap.add_argument("--flight-len", type=int, default=256,
                    help="crash flight recorder ring capacity (events)")
    ap.add_argument("--flight-path", default=None,
                    help="where the flight recorder dumps its JSON on "
                         "preemption/crash/exhaustion (default: "
                         "<ckpt-dir>/flight.json)")
    args = ap.parse_args()

    cfg = resolve_config(args.arch, "train_4k", smoke=args.smoke)
    shape = InputShape("cli", args.seq, args.batch, "train")

    n_dev = len(jax.devices())
    mesh = make_local_mesh() if n_dev > 1 else None
    baxes = batch_axes_for(mesh, args.batch) if mesh else ()
    # MoE archs ride the local mesh's model axis as an expert ring (ep-only
    # folding) when the expert count divides it; otherwise dense dispatch
    ep = (mesh.shape.get("model", 1)
          if cfg.family == Family.MOE and mesh is not None
          and cfg.moe.num_experts % mesh.shape.get("model", 1) == 0 else 1)
    plan = ParallelPlan(remat=args.remat, microbatches=args.microbatches,
                        compute_dtype="float32" if args.smoke else "bfloat16",
                        ep=ep, integrity=args.integrity)
    model = build_model(cfg, plan, mesh, baxes)

    hyper = Hyper(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                  total_steps=args.steps)
    state = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train] arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"devices={n_dev} batch={args.batch} seq={args.seq}")

    step_fn = jax.jit(make_train_step(model, plan, hyper, mesh=mesh),
                      donate_argnums=(0,))
    ds = SyntheticDataset(cfg, shape)
    flight = FlightRecorder(
        maxlen=args.flight_len,
        path=args.flight_path or f"{args.ckpt_dir}/flight.json")
    ckpt = CheckpointManager(args.ckpt_dir, keep=2,
                             async_snapshot=args.async_snapshot,
                             flight=flight)
    monitor = Monitor(flight=flight)
    policy = RecoveryPolicy(
        nan=args.on_nan, spike=args.on_spike,
        repeated_spike=args.on_repeated_spike, hang=args.on_hang,
        sdc=args.on_sdc, straggler=args.on_straggler,
        max_restores=args.max_restores,
        rescue_lr_scale=args.rescue_lr_scale,
        ckpt_memory_keep=args.ckpt_memory_keep,
        peer_redundancy=args.peer_redundancy,
        preempt_grace=args.preempt_grace, flight_len=args.flight_len,
        straggler_factor=args.straggler_factor,
        straggler_window=args.straggler_window,
        straggler_confirm=args.straggler_confirm)
    mem_ckpt = None
    if policy.ckpt_memory_keep > 0:
        mem_ckpt = MemoryCheckpointTier(
            keep=policy.ckpt_memory_keep,
            peer_redundancy=policy.peer_redundancy,
            groups=max(2, n_dev), flight=flight)
    rescue_fn = None
    if "lr_rescue" in (policy.spike, policy.repeated_spike,
                       policy.nan, policy.hang):
        rescue_hyper = hyper._replace(peak_lr=args.lr * args.rescue_lr_scale)
        rescue_fn = jax.jit(make_train_step(model, plan, rescue_hyper,
                                            mesh=mesh))

    straggler = StragglerTimer(cfg=cfg, plan=plan, policy=policy,
                               flight=flight)

    t_start = time.time()
    prefetch = Prefetcher(ds) if args.prefetch else None
    source = prefetch.batch if prefetch is not None else ds.batch

    def get_batch(step: int):
        return {k: jnp.asarray(v) for k, v in source(step).items()}

    def injector(step, st):
        if step == args.simulate_hang_at:
            time.sleep(2.0)
        return st

    try:
        with PreemptionGuard(grace=policy.preempt_grace) as guard:
            state, report = run_with_recovery(
                state, step_fn, get_batch, args.steps, ckpt, monitor,
                ckpt_every=args.ckpt_every, plan=plan, mesh=mesh,
                policy=policy, rescue_step=rescue_fn, resume=args.resume,
                fault_injector=(injector if args.simulate_hang_at >= 0
                                else None),
                mem_ckpt=mem_ckpt, preempt=guard, flight=flight,
                straggler=straggler)
    except KeyboardInterrupt as e:
        # Ctrl-C is an exit, not a crash — but it still leaves a black box:
        # the driver dumped the ring on the way out (any BaseException does)
        fp = getattr(e, "flight_path", None) or flight.dump("KeyboardInterrupt")
        print(f"[train] interrupted; flight log at {fp}")
        raise SystemExit(130)
    finally:
        if prefetch is not None:
            prefetch.close()

    dt = time.time() - t_start
    if report.preempted:
        print(f"[train] preempted at step {report.preempt_step} "
              f"(signal {guard.signum}): just-in-time snapshot taken, "
              f"PREEMPTED marker written, flight log at "
              f"{report.flight_path}; rerun with --resume to continue")
        return
    tokens = args.steps * args.batch * args.seq
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({tokens/dt:.0f} tok/s), loss {report.losses[0]:.4f} -> "
          f"{report.losses[-1]:.4f}, anomalies={len(report.anomalies)}, "
          f"restores={report.restores} (memory-tier {report.mem_restores}), "
          f"remeshes={report.remeshes}, rebalances={report.rebalances}")
    for step, kind, action in report.actions:
        print(f"[train]   step {step}: {kind} -> {action}")
    print(f"[train] ckpt snapshot {ckpt.snapshot_seconds*1e3:.1f}ms "
          f"persist {ckpt.persist_seconds*1e3:.1f}ms "
          f"({'double-buffered' if args.async_snapshot else 'blocking'} "
          f"snapshot, async persist)")


if __name__ == "__main__":
    main()
