"""End-to-end training driver.

Runs real steps on the available devices (CPU in this container; the same code
path drives a TPU slice — the mesh is the only difference):

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \\
        --steps 50 --batch 8 --seq 128

Integrates the full substrate: synthetic data pipeline, sharded AdamW + ZeRO-1,
remat, checkpointing with snapshot-stall persist, and anomaly monitoring with
rollback recovery (survey §8).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import ARCH_IDS, InputShape, ParallelPlan
from repro.core.config import Family
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticDataset
from repro.ft import Monitor, run_with_recovery
from repro.launch.mesh import batch_axes_for, make_local_mesh
from repro.launch.stepbuilder import resolve_config
from repro.models import build_model
from repro.train import Hyper, TrainState, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (full configs need a real pod)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="selective",
                    choices=["none", "selective", "full"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = resolve_config(args.arch, "train_4k", smoke=args.smoke)
    plan = ParallelPlan(remat=args.remat, microbatches=args.microbatches,
                        compute_dtype="float32" if args.smoke else "bfloat16",
                        ep=cfg.family == Family.MOE)
    shape = InputShape("cli", args.seq, args.batch, "train")

    n_dev = len(jax.devices())
    mesh = make_local_mesh() if n_dev > 1 else None
    baxes = batch_axes_for(mesh, args.batch) if mesh else ()
    model = build_model(cfg, plan, mesh, baxes)

    hyper = Hyper(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                  total_steps=args.steps)
    state = init_train_state(model, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train] arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"devices={n_dev} batch={args.batch} seq={args.seq}")

    step_fn = jax.jit(make_train_step(model, plan, hyper, mesh=mesh),
                      donate_argnums=(0,))
    ds = SyntheticDataset(cfg, shape)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    monitor = Monitor()

    t_start = time.time()
    last = t_start

    def get_batch(step: int):
        return {k: jnp.asarray(v) for k, v in ds.batch(step).items()}

    def logged_step(state, batch):
        nonlocal last
        state, metrics = step_fn(state, batch)
        return state, metrics

    state, report = run_with_recovery(
        state, logged_step, get_batch, args.steps, ckpt, monitor,
        ckpt_every=args.ckpt_every)

    dt = time.time() - t_start
    tokens = args.steps * args.batch * args.seq
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({tokens/dt:.0f} tok/s), loss {report.losses[0]:.4f} -> "
          f"{report.losses[-1]:.4f}, anomalies={len(report.anomalies)}, "
          f"restores={report.restores}")
    print(f"[train] ckpt snapshot {ckpt.snapshot_seconds*1e3:.1f}ms "
          f"persist {ckpt.persist_seconds*1e3:.1f}ms (async)")


if __name__ == "__main__":
    main()
