"""Overlap-aware tensor parallelism: collective matmuls + sequence sharding.

The GSPMD tensor-parallel path (``core/sharding.py``) is pure layout
annotation: XLA inserts a blocking all-reduce after every row GEMM and keeps
full-size ``(B, S, d)`` activations replicated between blocks — the exposed-
communication regime the survey's communication-optimization chapter (§4.1.2,
§5.2) identifies as the dominant TP scaling tax. This module is the explicit
``shard_map`` alternative, selected by ``ParallelPlan.tp_impl = "overlap"``:

- **Collective matmuls** (ring decomposition). The column GEMM's sequence
  all-gather and the row GEMM's reduce-scatter are decomposed into
  ``ppermute`` ring steps interleaved with partial GEMM tiles:

  * :func:`all_gather_matmul` — input ``x`` is sequence-sharded
    ``(B, S/tp, d)``; each tick multiplies the sequence chunk the rank
    already holds against its column shard of the weight(s) while the chunk
    is simultaneously ``ppermute``-d to the next rank. After ``tp`` ticks
    every rank has the full-sequence output of *its* feature shard — the
    all-gather that re-materializes the full sequence is fused into the
    first QKV/gate GEMM tick instead of blocking in front of it.
  * :func:`matmul_reduce_scatter` — each tick multiplies the sequence chunk
    destined for the rank ``tp-1-k`` hops away and adds it into an
    accumulator that rides the ring; the tile GEMM for one chunk overlaps
    the in-flight transfer of the previous partial sum.

  Both are ``jax.custom_vjp``: the forward saves only its inputs and the
  backward runs the mirrored ring in the reversed direction (an all-gather
  matmul's gradient is a matmul reduce-scatter and vice versa; weight
  gradients contract against the ring-re-gathered activations in a single
  GEMM so they stay bitwise-comparable to the GSPMD twins). Every partial
  tile funnels through :func:`repro.kernels.dispatch.dispatch_tp_matmul`.

- **Sequence-sharded activations** (Megatron-SP, survey §4.1.4). Between
  blocks, activations stay ``(batch, seq/tp, d)``: RMSNorm, residual adds and
  the embedding lookup run on sequence shards; only the gathered interior of
  each block (attention heads / expert FFN / SSD heads — all model-sharded)
  ever sees the full sequence.

- **Vocab-parallel loss**: the LM head GEMM keeps logits ``(B, S, V/tp)`` and
  :func:`repro.train.loss.cross_entropy_vp` reduces with per-shard
  max/logsumexp/target-logit plus scalar-sized ``psum`` — the ``(B, S, V)``
  logits tensor is never materialized or all-gathered.

This module owns the ring *primitives* (collective matmuls, ring
gather/scatter, the vocab-parallel embedding and head). The family block
bodies that consume them live in the unified block executor
(``repro.train.executor``: ``attn_block`` / ``mlp_block_ex`` /
``moe_block_ex`` / ``ssm_block_ex``, parameterized by a ``ParallelContext``)
— one wiring shared by the TP loss, the context-parallel (cp) loss and the
pipeline stage ticks, still routing attention / expert GEMMs / SSD scans
through ``repro.kernels.dispatch`` so ``tp_impl="overlap"`` composes with the
fused Pallas kernels. :func:`make_tp_loss_fn` is kept as the stable entry
point and delegates to ``executor.make_executor_loss_fn``. Numerical
contract, tested in tests/test_tensor_parallel.py: overlap loss/grads match
the GSPMD path on a 2-way model mesh for the dense, MoE and Mamba2 families.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.config import Family, ModelConfig, ParallelPlan
from repro.ft.inject import taint
from repro.kernels.dispatch import dispatch_tp_matmul
from repro.train.loss import cross_entropy_vp


@dataclasses.dataclass(frozen=True)
class RingCtx:
    """Static ring parameters (hashable: rides custom_vjp nondiff_argnums)."""
    axis: str = "model"
    size: int = 2

    @property
    def perm_fwd(self):
        return [(i, (i + 1) % self.size) for i in range(self.size)]

    @property
    def perm_bwd(self):
        return [(i, (i - 1) % self.size) for i in range(self.size)]


def _index(ctx: RingCtx):
    return jax.lax.axis_index(ctx.axis) if ctx.size > 1 else 0


# ---------------------------------------------------------------------------
# collective matmuls (ring-decomposed, custom-VJP)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def all_gather_matmul(ctx: RingCtx, x, ws):
    """Column GEMM with the sequence all-gather fused into the ring ticks.

    ``x``: (B, S/tp, d) sequence shard; ``ws``: tuple of (d, f_loc) column
    shards. Returns ``(outs, x_full)`` where ``outs[i]`` is (B, S, f_loc) —
    the full-sequence product against this rank's feature shard — and
    ``x_full`` is (B, S, d), the gathered input (a free by-product of the
    ring; callers that project against replicated weights, e.g. Mamba2's
    B/C, reuse it). Tick ``k`` multiplies the chunk the rank already holds
    while ``ppermute`` moves it one rank forward.
    """
    outs, xg = _ag_matmul_impl(ctx, x, ws)
    return outs, xg


def _ag_matmul_impl(ctx: RingCtx, x, ws):
    t, s_loc = ctx.size, x.shape[1]
    idx = _index(ctx)
    outs = [jnp.zeros(x.shape[:1] + (t * s_loc, w.shape[-1]),
                      jnp.result_type(x.dtype, w.dtype)) for w in ws]
    xg = jnp.zeros(x.shape[:1] + (t * s_loc,) + x.shape[2:], x.dtype)
    cur = x
    for k in range(t):
        start = ((idx - k) % t) * s_loc
        for i, w in enumerate(ws):
            part = dispatch_tp_matmul(cur, w).astype(outs[i].dtype)
            outs[i] = jax.lax.dynamic_update_slice_in_dim(
                outs[i], part, start, axis=1)
        xg = jax.lax.dynamic_update_slice_in_dim(xg, cur, start, axis=1)
        if k < t - 1:
            # fault seam: the ring payload as it lands from the ppermute —
            # where a link-level bit flip would corrupt it (ft/inject)
            cur = taint("tp.ring.tick", jax.lax.ppermute(
                cur, ctx.axis, ctx.perm_fwd))
    return tuple(outs), xg


def _ag_matmul_fwd(ctx, x, ws):
    return all_gather_matmul(ctx, x, ws), (x, ws)


def _ag_matmul_bwd(ctx, res, cts):
    """Mirrored reversed ring: dx is a reduce-scatter of Σ_w dout_w · w_wᵀ
    (plus the gathered-copy cotangent), dw_w contracts the re-gathered x
    against dout_w in one GEMM (bitwise twin of the GSPMD transpose)."""
    x, ws = res
    douts, dxg = cts
    t, s_loc = ctx.size, x.shape[1]
    idx = _index(ctx)
    cur, acc = x, None
    xg = jnp.zeros(x.shape[:1] + (t * s_loc,) + x.shape[2:], x.dtype)
    for k in range(t):
        # re-gather x (for the dw GEMMs): reversed ring holds chunk idx+k
        xg = jax.lax.dynamic_update_slice_in_dim(
            xg, cur, ((idx + k) % t) * s_loc, axis=1)
        # reduce-scatter dx: this tick's tile is for the chunk whose
        # accumulator currently sits on this rank (dest (idx + k + 1) % t)
        start = ((idx + k + 1) % t) * s_loc
        tile = jax.lax.dynamic_slice_in_dim(dxg, start, s_loc, axis=1)
        tile = tile.astype(jnp.result_type(x.dtype, *(w.dtype for w in ws))
                           if ws else tile.dtype)
        for w, dout in zip(ws, douts):
            d_chunk = jax.lax.dynamic_slice_in_dim(dout, start, s_loc, axis=1)
            tile = tile + dispatch_tp_matmul(d_chunk, w.T).astype(tile.dtype)
        acc = tile if k == 0 else acc + tile
        if k < t - 1:
            cur = jax.lax.ppermute(cur, ctx.axis, ctx.perm_bwd)
            acc = jax.lax.ppermute(acc, ctx.axis, ctx.perm_bwd)
    dws = tuple(
        jnp.einsum("bsd,bsf->df", xg.astype(jnp.float32),
                   dout.astype(jnp.float32)).astype(w.dtype)
        for w, dout in zip(ws, douts))
    return acc.astype(x.dtype), dws


all_gather_matmul.defvjp(_ag_matmul_fwd, _ag_matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def matmul_reduce_scatter(ctx: RingCtx, h, w):
    """Row GEMM with the reduce-scatter fused into the ring ticks.

    ``h``: (B, S, f_loc) full-sequence activation on this rank's feature
    shard; ``w``: (f_loc, d) row shard. Returns (B, S/tp, d) — this rank's
    sequence chunk of the summed product. Tick ``k`` multiplies the chunk
    whose partial-sum accumulator currently sits on this rank, then the
    accumulator rides the ring one rank forward; the last tick adds the
    rank's own chunk and keeps it.
    """
    return _rs_matmul_impl(ctx, h, w)


def _rs_matmul_impl(ctx: RingCtx, h, w):
    t = ctx.size
    s_loc = h.shape[1] // t
    idx = _index(ctx)
    acc = None
    for k in range(t):
        start = ((idx - k - 1) % t) * s_loc
        tile = dispatch_tp_matmul(
            jax.lax.dynamic_slice_in_dim(h, start, s_loc, axis=1), w)
        acc = tile if k == 0 else acc + tile
        if k < t - 1:
            acc = jax.lax.ppermute(acc, ctx.axis, ctx.perm_fwd)
    return acc


def _rs_matmul_fwd(ctx, h, w):
    return matmul_reduce_scatter(ctx, h, w), (h, w)


def _rs_matmul_bwd(ctx, res, dout):
    """Mirrored reversed ring: dh re-gathers the output cotangent (one ring)
    and multiplies each landing chunk by wᵀ; dw contracts h against the
    gathered cotangent in one GEMM."""
    h, w = res
    t, s_loc = ctx.size, dout.shape[1]
    idx = _index(ctx)
    cur = dout
    dg = jnp.zeros(dout.shape[:1] + (t * s_loc,) + dout.shape[2:], dout.dtype)
    dh = jnp.zeros_like(h)
    for k in range(t):
        start = ((idx + k) % t) * s_loc
        dg = jax.lax.dynamic_update_slice_in_dim(dg, cur, start, axis=1)
        dh = jax.lax.dynamic_update_slice_in_dim(
            dh, dispatch_tp_matmul(cur, w.T).astype(h.dtype), start, axis=1)
        if k < t - 1:
            cur = jax.lax.ppermute(cur, ctx.axis, ctx.perm_bwd)
    dw = jnp.einsum("bsf,bsd->fd", h.astype(jnp.float32),
                    dg.astype(jnp.float32)).astype(w.dtype)
    return dh, dw


matmul_reduce_scatter.defvjp(_rs_matmul_fwd, _rs_matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def ring_all_gather(ctx: RingCtx, x):
    """(B, S/tp, ...) sequence shard -> (B, S, ...) via the ppermute ring.

    Dedicated VJP (rather than ``all_gather_matmul`` with no weights): the
    gather's transpose is exactly the mirrored reduce-scatter, with no dead
    re-gather ring in the backward."""
    return _ag_matmul_impl(ctx, x, ())[1]


ring_all_gather.defvjp(
    lambda ctx, x: (ring_all_gather(ctx, x), None),
    lambda ctx, _, dxg: (_ring_rs_impl(ctx, dxg),))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def ring_reduce_scatter(ctx: RingCtx, x):
    """(B, S, ...) per-rank partial -> (B, S/tp, ...) summed sequence chunk.

    Same accumulator-rides-the-ring schedule as :func:`matmul_reduce_scatter`
    but without the GEMM (used e.g. by the vocab-parallel embedding, whose
    per-rank partials are masked row lookups). Backward is the mirrored
    all-gather: the sum's transpose replicates the chunk cotangents."""
    return _ring_rs_impl(ctx, x)


def _ring_rs_impl(ctx: RingCtx, x):
    t = ctx.size
    s_loc = x.shape[1] // t
    idx = _index(ctx)
    acc = None
    for k in range(t):
        start = ((idx - k - 1) % t) * s_loc
        tile = jax.lax.dynamic_slice_in_dim(x, start, s_loc, axis=1)
        acc = tile if k == 0 else acc + tile
        if k < t - 1:
            acc = jax.lax.ppermute(acc, ctx.axis, ctx.perm_fwd)
    return acc


def _ring_rs_fwd(ctx, x):
    return ring_reduce_scatter(ctx, x), None


def _ring_rs_bwd(ctx, _, dout):
    return (_ag_matmul_impl(ctx, dout, ())[1],)


ring_reduce_scatter.defvjp(_ring_rs_fwd, _ring_rs_bwd)


# ---------------------------------------------------------------------------
# sequence-sharded embedding / head


def tp_embed(params, tokens, cfg: ModelConfig, dtype, ctx: RingCtx):
    """Vocab-parallel embedding producing a sequence-sharded residual stream.

    ``tokens``: (B, S) — the full (replicated-over-model) ids. The table is
    vocab-sharded (V/tp, d); each rank looks up every position from *its*
    shard (zeros where the id lives elsewhere) and a ring reduce-scatter sums
    the partials straight into (B, S/tp, d) sequence chunks — exact, since
    every row has exactly one non-zero contributor."""
    tab = params["embed"]["tok"]
    v_loc = tab.shape[0]
    local = tokens.astype(jnp.int32) - _index(ctx) * v_loc
    ok = (local >= 0) & (local < v_loc)
    # cast to the compute dtype *before* the ring: each row has exactly one
    # non-zero contributor, so no cross-rank accumulation happens and the
    # ppermute ticks move half the bytes under bf16
    rows = jnp.take(tab, jnp.clip(local, 0, v_loc - 1), axis=0).astype(dtype)
    rows = jnp.where(ok[..., None], rows, jnp.zeros((), dtype))
    x = ring_reduce_scatter(ctx, rows)
    if cfg.scale_embed:
        import numpy as np
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def tp_head_nll(params, x, labels, cfg: ModelConfig, ctx: RingCtx, dtype,
                z_loss: float = 0.0):
    """LM head + vocab-parallel cross-entropy on a (B, S/tp, d) shard.

    The sequence all-gather is fused into the head GEMM ticks; logits stay
    vocab-sharded (B, S, V/tp) and reduce via per-shard + scalar-psum
    (:func:`repro.train.loss.cross_entropy_vp`). Returns per-position nll
    (B, S), replicated over the model axis."""
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(dtype).T
    else:
        w = params["lm_head"]["w"].astype(dtype)
    (logits,), _ = all_gather_matmul(ctx, x, (w,))
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    logits = logits.astype(jnp.float32)
    v_loc = logits.shape[-1]
    idx = _index(ctx)
    if v_loc * ctx.size != cfg.vocab:
        # Megatron-style padded vocab: mask this shard's padded tail
        gid = idx * v_loc + jnp.arange(v_loc)
        logits = jnp.where(gid >= cfg.vocab, -1e9, logits)
    return cross_entropy_vp(logits, labels, axis_name=ctx.axis,
                            shard_index=idx, z_loss=z_loss)


# ---------------------------------------------------------------------------
# whole-model loss


def decoder_only_support_errors(cfg: ModelConfig):
    """Shared static preconditions of the explicit shard_map paths (overlap
    TP and context parallelism): decoder-only dense/moe/ssm families with
    rope positions. Returns a list of problems (empty = supported)."""
    bad = []
    if cfg.family not in (Family.DENSE, Family.MOE, Family.SSM) \
            or cfg.is_enc_dec or cfg.vision_tokens:
        bad.append(f"family {cfg.family!r} (dense/moe/ssm decoder-only)")
    elif cfg.family in (Family.DENSE, Family.MOE) and cfg.pos_emb != "rope":
        bad.append(f"pos_emb {cfg.pos_emb!r}")
    return bad


def check_overlap_support(cfg: ModelConfig, plan: ParallelPlan, tp: int):
    """Static preconditions for the ring path. Raises ValueError otherwise."""
    bad = decoder_only_support_errors(cfg)
    vocab = cfg.vocab
    if plan.pad_vocab_to_multiple:
        vocab = -(-vocab // plan.pad_vocab_to_multiple) * plan.pad_vocab_to_multiple
    if vocab % tp:
        bad.append(f"vocab {vocab} % tp {tp} != 0 (set pad_vocab_to_multiple)")
    if cfg.family in (Family.DENSE, Family.MOE):
        if cfg.n_heads % tp or cfg.n_kv_heads % tp:
            bad.append(f"heads ({cfg.n_heads}, {cfg.n_kv_heads}) % tp != 0")
    if cfg.family == Family.DENSE and cfg.d_ff % tp:
        bad.append(f"d_ff {cfg.d_ff} % tp != 0")
    if cfg.family == Family.MOE:
        if cfg.moe.d_expert % tp:
            bad.append(f"d_expert {cfg.moe.d_expert} % tp != 0")
        if cfg.moe.num_shared_experts and \
                (cfg.moe.d_expert * cfg.moe.num_shared_experts) % tp:
            bad.append("shared-expert width % tp != 0")
    if cfg.family == Family.SSM:
        di = cfg.ssm.expand * cfg.d_model
        if di % tp or (di // cfg.ssm.head_dim) % tp:
            bad.append(f"d_inner {di} or heads % tp != 0")
        if cfg.ssm.n_groups != 1:
            bad.append(f"n_groups {cfg.ssm.n_groups} != 1")
    if bad:
        raise ValueError("tp_impl='overlap' unsupported here: " + "; ".join(bad))


def make_tp_loss_fn(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh,
                    batch_axes: Tuple[str, ...] = ("data",),
                    z_loss: float = 0.0):
    """Overlap-TP loss_fn(params, batch): the shard_map twin of
    ``train.step.make_loss_fn`` with sequence-sharded activations.

    Requires a ``model`` mesh axis of size >= 2, seq % tp == 0, and the
    family/width divisibilities of :func:`check_overlap_support`. Numerics
    match the GSPMD path: same per-token math, loss reduced as
    psum-of-sums / global-count. MoE note: routing runs on the ring-gathered
    token set of each data shard, so with the default capacity factor the
    dropping policy is per-data-shard (exactly GSPMD's when dp == 1).

    Kept as the stable name; the wiring lives in the unified block executor
    (``repro.train.executor.make_executor_loss_fn``), which also composes
    the context-parallel axis when ``plan.cp > 1``.
    """
    from repro.train.executor import make_executor_loss_fn  # noqa: PLC0415
    if plan.cp <= 1 and mesh.shape.get("model", 1) < 2:
        raise ValueError("tp_impl='overlap' needs a 'model' mesh axis >= 2")
    return make_executor_loss_fn(cfg, plan, mesh, batch_axes, z_loss=z_loss)
