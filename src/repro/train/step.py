"""Train-step factory: loss + grad + clip + AdamW, with microbatch accumulation.

The returned function is pure and jit/pjit-friendly:

    state = TrainState(params, opt)
    state, metrics = train_step(state, batch)

Gradient accumulation (``plan.microbatches``) runs as a ``lax.scan`` over
microbatch slices — constant HLO size, and under pipeline parallelism the same
slicing provides the pipeline's microbatches.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, ParallelPlan
from repro.models.families import Model
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from .loss import cross_entropy


class TrainState(NamedTuple):
    params: Any
    opt: Any          # AdamWState


class Hyper(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    z_loss: float = 1e-4


def init_train_state(model: Model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params, adamw_init(params))


def make_loss_fn(model: Model, hyper: Hyper) -> Callable:
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = cross_entropy(logits, batch["labels"], z_loss=hyper.z_loss)
        return loss + aux, {"xent": loss, "moe_aux": aux}
    return loss_fn


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(model: Model, plan: ParallelPlan,
                    hyper: Hyper = Hyper()) -> Callable:
    loss_fn = make_loss_fn(model, hyper)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        params, opt = state

        if plan.microbatches > 1:
            mb = _split_microbatches(batch, plan.microbatches)

            def acc(carry, mbatch):
                g_acc, l_acc, a_acc = carry
                (loss, aux), grads = grad_fn(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss, a_acc + aux["moe_aux"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux_sum), _ = jax.lax.scan(
                acc, (g0, jnp.float32(0.0), jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / plan.microbatches, grads)
            loss = loss / plan.microbatches
            aux = {"moe_aux": aux_sum / plan.microbatches}
        else:
            (loss, aux), grads = grad_fn(params, batch)

        grads, gnorm = clip_by_global_norm(grads, hyper.grad_clip)
        lr = cosine_schedule(opt.step, hyper.peak_lr, hyper.warmup_steps,
                             hyper.total_steps)
        new_params, new_opt = adamw_update(
            grads, opt, params, lr, weight_decay=hyper.weight_decay)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr,
            "moe_aux": aux["moe_aux"],
        }
        return TrainState(new_params, new_opt), metrics

    return train_step
