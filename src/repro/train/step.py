"""Train-step factory: loss + grad + clip + AdamW, with microbatch accumulation.

The returned function is pure and jit/pjit-friendly:

    state = TrainState(params, opt)
    state, metrics = train_step(state, batch)

Gradient accumulation (``plan.microbatches``) runs as a ``lax.scan`` over
microbatch slices — constant HLO size, and under pipeline parallelism the same
slicing provides the pipeline's microbatches.

Tensor parallelism (survey §4.1.2): with a ``mesh`` whose ``model`` axis is
>= 2 and ``plan.tp_impl`` resolving to ``"overlap"``, the step swaps its loss
for the explicit ring path (``train.tensor_parallel.make_tp_loss_fn``) —
collective matmuls + sequence-sharded activations instead of GSPMD's blocking
all-reduces. ``tp_impl="auto"`` only picks it on TPU backends; an unsupported
family under ``"auto"`` silently keeps the GSPMD loss, while an explicit
``"overlap"`` raises.

ZeRO-1 (survey §6.2.1): pass ``mesh`` and the step shards the optimizer work
over the ``data`` axis. The fp32 microbatch accumulator is *born scattered*
(constrained to ``core.sharding.opt_state_specs``), so each microbatch's grads
reduce-scatter straight into the shard and a fully-replicated fp32 grad copy
never exists; the AdamW math then runs on each device's slice of the moments
(``optim.adamw_update_sharded``) and only the updated params all-gather back.
Without ``mesh`` (or with ``plan.zero_stage == 0``) the step is the plain
replicated update — same math either way.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import sharding as shardlib
from repro.core.config import ModelConfig, ParallelPlan
from repro.models.families import Model
from repro.optim import (adamw_init, adamw_update, adamw_update_sharded,
                         clip_by_global_norm, constrain_tree, cosine_schedule)
from .loss import cross_entropy


class TrainState(NamedTuple):
    params: Any
    opt: Any          # AdamWState


class Hyper(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    z_loss: float = 1e-4


def init_train_state(model: Model, rng, mesh: Optional[Mesh] = None,
                     plan: Optional[ParallelPlan] = None) -> TrainState:
    """Fresh state; with ``mesh`` + ``plan`` the params are placed on their
    plan layout and the AdamW moments are born on the ZeRO-1 data-scattered
    layout (``core.sharding.opt_state_specs``) — the layouts the jitted step
    would otherwise impose on first use, needed up front when the state
    serves as an elastic-restore template."""
    params = model.init(rng)
    if mesh is None or plan is None:
        return TrainState(params, adamw_init(params))
    pspecs = shardlib.param_specs(params, model.cfg, plan, mesh)
    params = jax.tree.map(
        lambda p, s: jax.device_put(p, jax.sharding.NamedSharding(mesh, s)),
        params, pspecs)
    ospecs = shardlib.opt_state_specs(pspecs, params, plan, mesh)
    return TrainState(params, adamw_init(params, mesh=mesh, specs=ospecs))


def make_loss_fn(model: Model, hyper: Hyper) -> Callable:
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = cross_entropy(logits, batch["labels"], z_loss=hyper.z_loss)
        return loss + aux, {"xent": loss, "moe_aux": aux}
    return loss_fn


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def _overlap_loss_fn(model: Model, plan: ParallelPlan, hyper: Hyper,
                     mesh: Mesh) -> Optional[Callable]:
    """The executor (overlap-TP, context-parallel and/or expert-parallel)
    loss when the plan/mesh select it, else None (GSPMD loss)."""
    from repro.kernels.dispatch import select_tp_impl  # noqa: PLC0415
    use_cp = plan.cp > 1
    use_ep = plan.ep > 1
    if use_cp and (mesh is None or mesh.shape.get("cp", 1) < plan.cp):
        raise ValueError(
            f"plan.cp={plan.cp} was requested but the step has no 'cp' mesh "
            f"axis of size {plan.cp} to shard the sequence over")
    if use_ep and mesh is None:
        raise ValueError(
            f"plan.ep={plan.ep} was requested but the step has no mesh to "
            "fold the expert ring onto")
    if mesh is None or (not use_cp and not use_ep
                        and mesh.shape.get("model", 1) < 2):
        if plan.tp_impl == "overlap":
            raise ValueError(
                "tp_impl='overlap' was requested explicitly but the step has "
                "no 'model' mesh axis of size >= 2 to run the rings on")
        return None
    if not use_cp and not use_ep and select_tp_impl(plan.tp_impl) != "overlap":
        return None
    from repro.train.executor import make_executor_loss_fn  # noqa: PLC0415
    baxes = tuple(a for a in ("pod", "data")
                  if a in mesh.shape and (a != "pod" or plan.pp == 1))
    try:
        return make_executor_loss_fn(model.cfg, plan, mesh, baxes,
                                     z_loss=hyper.z_loss)
    except ValueError:
        if plan.tp_impl == "overlap" or use_cp or use_ep:
            raise                     # explicit request: surface the reason
        return None                   # auto: fall back to the GSPMD loss


def make_train_step(model: Model, plan: ParallelPlan,
                    hyper: Hyper = Hyper(),
                    mesh: Optional[Mesh] = None) -> Callable:
    loss_fn = (_overlap_loss_fn(model, plan, hyper, mesh)
               or make_loss_fn(model, hyper))
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    use_zero = (mesh is not None and plan.zero_stage >= 1
                and "data" in mesh.shape and mesh.shape["data"] > 1)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        params, opt = state

        if use_zero:
            pspecs = shardlib.param_specs(params, model.cfg, plan, mesh)
            ospecs = shardlib.opt_state_specs(pspecs, params, plan, mesh)
            scatter = lambda tree: constrain_tree(tree, ospecs, mesh)
        else:
            scatter = lambda tree: tree

        if plan.microbatches > 1:
            mb = _split_microbatches(batch, plan.microbatches)

            def acc(carry, mbatch):
                g_acc, l_acc, a_acc = carry
                (loss, aux), grads = grad_fn(params, mbatch)
                # accumulate into the scattered shard: under ZeRO-1 each
                # microbatch's grads reduce-scatter here instead of
                # all-reducing into a replicated fp32 copy (g_acc's layout is
                # already pinned by the scattered g0 carry)
                g_acc = jax.tree.map(jnp.add, g_acc, scatter(grads))
                return (g_acc, l_acc + loss, a_acc + aux["moe_aux"]), None

            g0 = scatter(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss, aux_sum), _ = jax.lax.scan(
                acc, (g0, jnp.float32(0.0), jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / plan.microbatches, grads)
            loss = loss / plan.microbatches
            aux = {"moe_aux": aux_sum / plan.microbatches}
        else:
            (loss, aux), grads = grad_fn(params, batch)
            grads = scatter(grads)

        grads, gnorm = clip_by_global_norm(grads, hyper.grad_clip)
        lr = cosine_schedule(opt.step, hyper.peak_lr, hyper.warmup_steps,
                             hyper.total_steps)
        if use_zero:
            new_params, new_opt = adamw_update_sharded(
                grads, opt, params, lr, mesh=mesh, param_specs=pspecs,
                opt_specs=ospecs, weight_decay=hyper.weight_decay)
        else:
            new_params, new_opt = adamw_update(
                grads, opt, params, lr, weight_decay=hyper.weight_decay)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr,
            "moe_aux": aux["moe_aux"],
        }
        if plan.integrity == "audit":
            # SDC audit (survey §8.2): exact bitwise checksum of the updated
            # params + this step's grads, cross-checked across replicas.
            # Any nonzero divergence means some device computed different
            # bits — the recovery driver routes it through policy.sdc.
            from repro.ft.integrity import replica_divergence  # noqa: PLC0415
            cs, div = replica_divergence(
                {"params": new_params, "grads": grads}, mesh=mesh)
            metrics["integrity_checksum"] = cs
            metrics["integrity_div"] = div
        return TrainState(new_params, new_opt), metrics

    return train_step
