"""Pipeline parallelism over the ``pod`` axis (survey §4.1.3).

SPMD formulation (the JAX-native equivalent of MPMD pipeline schedules —
DESIGN.md §2): inside a ``shard_map`` over ``pod``, every pod executes the same
program; pod ``i`` holds layers [i·L/P, (i+1)·L/P) (the layer-stacked params
are sharded on their leading dim), and activations rotate stage-to-stage with
``ppermute``. Embedding runs on every pod (cheap, replicated weights) but only
stage 0's output enters the pipeline; the LM head + loss run on the last stage
(behind a ``lax.cond`` so the other stages skip the dead logits/xent compute)
and the scalar loss is broadcast back with a ``psum`` mask.

Two schedules, selected by ``plan.pp_schedule``:

- ``"gpipe"`` — fill-drain: the forward scan runs M+P-1 ticks and reverse-mode
  AD differentiates straight through the ``ppermute``s, generating the mirrored
  backward pipeline automatically. Simple, but the autodiff keeps every tick's
  stage activations live between the forward and backward scans: peak in-flight
  activation memory is O(M) microbatches.

- ``"1f1b"`` (default) — one-forward-one-backward: the loss is a
  ``jax.custom_vjp`` whose forward saves nothing but (params, batch), and whose
  backward runs ONE scan in which every tick advances the forward pipeline by
  one stage-tick (recompute) AND retires one backward stage-tick for the
  microbatch that just drained — the mirrored drain interleaved with forward
  ticks. Stage inputs wait in a ring buffer of 2P-1 slots between their
  recompute tick and their backward tick, so peak in-flight activations drop
  from O(M) microbatches to O(P) stages. Loss and gradients are bit-compatible
  with GPipe (same per-microbatch math, same f32 accumulation order up to
  reassociation).

Backward schedule bookkeeping (P stages, M microbatches, tick t):
the forward recompute of microbatch ``m`` reaches stage ``p`` at tick
``m + p``; its backward runs at stage ``p`` at tick ``m + 2(P-1) - p``
(the cotangent enters at the last stage the tick its recompute finishes and
``ppermute``s backward one stage per tick). A stage therefore holds a saved
stage input for at most ``2(P-1)`` ticks — the ring of ``2P-1`` slots is
exactly enough, and the scan runs ``M + 2(P-1)`` ticks total.

TP x PP composition (survey §4.1.2 x §4.1.3): when ``plan.tp_impl`` resolves
to ``"overlap"`` and the mesh has a ``model`` axis >= 2, each stage tick runs
the overlap tensor-parallel layer bodies (``train/tensor_parallel.py``) —
collective-matmul ring steps *inside* each 1F1B tick, with the inter-stage
``ppermute`` moving (microbatch, seq/tp, d) sequence shards instead of
full-sequence activations (so the stage-to-stage transfer shrinks by tp too).
The last stage's head keeps logits vocab-parallel and reduces with
``cross_entropy_vp``; because its ring/psum collectives must execute
uniformly across pods (the head predicate is per-stage, and per-recompute-
tick in the 1F1B backward), it runs masked on every tick instead of behind
the ``lax.cond`` — the V/tp vocab shard keeps that dead compute tp× smaller
than a full-vocab head.

CP x TP x PP (survey §4.1.4): with ``plan.cp > 1`` and a "cp" mesh axis, the
sequence itself is sharded end to end — the stage-to-stage ``ppermute``
moves (mb, s/(cp·tp), d) shards and the zigzag ring-attention / KV-gather
collectives of the block executor (``train/executor.py``) run inside each
tick, next to the TP rings. Inputs are zigzag-permuted outside the
shard_map for the ring layout; each rank's per-microbatch loss is the mean
over its own chunk, completed by a cp ``pmean`` (forward) and a 1/cp seed
split plus all-leaf cp ``psum`` (1F1B backward — params are cp-replicated
but each rank's backward saw only its chunk).

EP x TP x CP x PP (survey §4.1.5): with ``plan.ep > 1`` the expert ring
folds onto the cp × model axes inside each stage (MoE parallel folding —
same devices, different mapping for the MoE sublayer): routed experts shard
expert-dim over the fold, and the dispatch/combine all-to-alls
(``kernels.dispatch.dispatch_ep_a2a``, blocking or overlapped per
``plan.ep_impl``) run inside each tick next to the TP/CP rings. Routed
expert grads complete locally through the a2a backward (no fold psum);
shared-expert/router grads psum over the fold. ep-only × pp is rejected —
there is no spare axis to fold onto.

Uneven stages (survey §8.1, Malleus-style fail-slow mitigation): with
``plan.pp_layout = (l_0, ..., l_{P-1})`` (summing to ``n_layers``) stage
``i`` holds ``l_i`` layers instead of the even split — the rebalancing
answer to a degraded stage, which is slow *per unit of work* and so should
hold fewer layers. Canonical params keep the (n_layers, ...) stacked layout
(so checkpoints are layout-independent and a ``pp_layout`` change restores
as a plain reshard); the loss fn gathers them into padded
``(pp * max(layout), ...)`` stacks — padding slots replicate each stage's
first layer and still shard evenly ``P("pod")`` — and an ``active`` mask
kills padded slots: via ``lax.cond`` (true compute skip) on the plain path,
or masked uniform execution when TP/CP rings run inside the tick (the
collectives must execute on every pod regardless). Padded-slot gradients
are zero, and the backward scatter-adds packed grads onto the canonical
stacks, so uneven layouts are loss- and grad-equivalent to the even split
and to the single-device model.

Supported for decoder-only families (dense / vlm backbones); the hybrid/
enc-dec archs pipeline equally in principle but are out of scope for this
feature (EXPERIMENTS.md notes which configs exercise it).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.config import ModelConfig, ParallelPlan
from repro.models.families import (_decoder_layer_fwd, _embed, _layer_windows,
                                   _logits, _remat)
from repro.models.layers import rms_norm
from repro.train.loss import cross_entropy


def _names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", p))) for p in path)


def pipelined_loss_fn(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh,
                      batch_axes: Tuple[str, ...] = ("data",),
                      z_loss: float = 0.0):
    """Returns loss_fn(params, batch) with layers pipelined over ``pod``.

    Requires: mesh has a ``pod`` axis, plan.pp == mesh.shape["pod"],
    plan.microbatches >= plan.pp, and either cfg.n_layers % pp == 0 or an
    explicit ``plan.pp_layout`` (uneven layers-per-stage, summing to
    n_layers with every stage >= 1). ``z_loss`` is threaded into the
    per-microbatch cross-entropy so pipelined and single-stage losses agree
    bit-for-bit.
    """
    pp = mesh.shape["pod"]
    assert plan.pp == pp
    layout = plan.pp_layout
    if layout is None:
        assert cfg.n_layers % pp == 0, \
            f"n_layers={cfg.n_layers} must divide pp={pp} (or set pp_layout)"
        layout = (cfg.n_layers // pp,) * pp
    else:
        layout = tuple(int(x) for x in layout)
        assert len(layout) == pp and min(layout) >= 1 \
            and sum(layout) == cfg.n_layers, (layout, cfg.n_layers, pp)
    n_micro = plan.microbatches
    assert n_micro >= pp, "need microbatches >= stages for pipelining"
    schedule = plan.pp_schedule
    max_l = max(layout)
    uneven = len(set(layout)) > 1
    # Uneven (Malleus) layouts pack each stage's layers into max_l slots so
    # the stack still shards evenly P("pod") on dim 0 (NamedSharding cannot
    # shard unevenly): pack_idx gathers the canonical (n_layers, ...) stacks
    # into (pp * max_l, ...) — padding slots replicate the stage's first
    # layer (any valid index: their outputs and gradients are masked to zero
    # by `active`, and the backward scatter-add returns grads to the
    # canonical stacks, so checkpoints stay layout-independent).
    offsets = np.concatenate([[0], np.cumsum(layout)[:-1]]).astype(np.int64)
    pack_idx = np.concatenate([
        np.concatenate([np.arange(off, off + n_l),
                        np.full(max_l - n_l, off, np.int64)])
        for off, n_l in zip(offsets, layout)])
    active_np = np.zeros((pp, max_l), bool)
    for _s, _n in enumerate(layout):
        active_np[_s, :_n] = True
    dtype = jnp.dtype(plan.compute_dtype)
    windows_np = np.asarray(_layer_windows(cfg))
    windows_host = (windows_np[pack_idx] if uneven
                    else windows_np).reshape(pp, max_l)
    baxes = batch_axes if batch_axes else None
    n_dp = 1
    for a in (batch_axes or ()):
        n_dp *= mesh.shape[a]
    perm_fwd = [(i, (i + 1) % pp) for i in range(pp)]
    perm_bwd = [(i, (i - 1) % pp) for i in range(pp)]

    # TP x PP: overlap tensor parallelism runs its ring steps inside each
    # stage tick; activations rotate stage-to-stage as (mb, s/tp, d) shards.
    # Same fallback contract as train.step: "auto" quietly keeps GSPMD when
    # the ring path's preconditions fail; an explicit "overlap" raises.
    from repro.kernels.dispatch import select_cp_impl, select_tp_impl
    from repro.train import executor as exlib
    from repro.train import tensor_parallel as tplib
    tp = mesh.shape.get("model", 1)
    if tp <= 1 and plan.tp_impl == "overlap":
        raise ValueError(
            "tp_impl='overlap' was requested explicitly but the pipeline mesh "
            "has no 'model' axis of size >= 2 to run the rings on")
    # under cp or ep the explicit rings are the ONLY tp execution (validate()
    # rejects cp/ep x gspmd-tp), so a cp or ep plan with tp > 1 engages them
    # on every backend — matching executor.resolve_context; without them,
    # "auto" keeps its backend resolution (overlap on TPU, gspmd elsewhere)
    tp_overlap = tp > 1 and (
        select_tp_impl(plan.tp_impl) == "overlap"
        or ((plan.cp > 1 or plan.ep > 1) and plan.tp > 1))
    if tp_overlap:
        try:
            tplib.check_overlap_support(cfg, plan, tp)
        except ValueError:
            if plan.tp_impl == "overlap" or (plan.ep > 1 and plan.tp > 1):
                raise
            tp_overlap = False
    # CP x PP (x TP): context parallelism shards the sequence over the "cp"
    # mesh axis; the ring-attention / KV-gather collectives run inside each
    # 1F1B tick like the TP rings do, and the stage-to-stage ppermute moves
    # (mb, s/(cp·tp), d) shards — the inter-stage transfer shrinks by cp too.
    cp = mesh.shape.get("cp", 1) if plan.cp > 1 else 1
    if plan.cp > 1 and cp < plan.cp:
        raise ValueError(
            f"plan.cp={plan.cp} needs a 'cp' mesh axis of size {plan.cp} on "
            f"the pipeline mesh, got {mesh.shape}")
    if cp > 1:
        exlib.check_cp_support(cfg, plan, cp)
    cp_impl = select_cp_impl(
        plan.cp_impl, family=cfg.family, window=cfg.sliding_window,
        local_global_alternating=bool(cfg.local_global_alternating
                                      and cfg.sliding_window)) if cp > 1 \
        else "ring"
    zigzag = cp > 1 and cp_impl == "ring"
    # EP x PP (x TP x CP): the expert ring folds onto the cp x model axes of
    # the pipeline mesh exactly as in the flat executor — experts shard over
    # the fold, the dispatch/combine all-to-alls of dispatch_ep_a2a run
    # inside each stage tick next to the TP/CP rings. ep-only has no axis to
    # fold onto here (the executor's ep-only trick repurposes "model" as a
    # cp ring, which the pipeline's stage buffers don't model), so it is
    # rejected rather than silently mislaid.
    ep = plan.ep if plan.ep > 1 else 1
    if ep > 1:
        from repro.kernels.dispatch import select_ep_impl
        from repro.core.sharding import ep_fold_axes, ep_spec_for_param
        if not (tp_overlap or cp > 1):
            raise ValueError(
                f"plan.ep={ep} under pipeline parallelism needs cp > 1 "
                "and/or the overlap tp rings to fold the expert axis onto; "
                "ep-only x pp is not supported")
        fold = (cp if cp > 1 else 1) * (tp if tp_overlap else 1)
        if ep != fold:
            raise ValueError(
                f"plan.ep={ep} must equal the folded cp×model ring size "
                f"{fold} on the pipeline mesh {dict(mesh.shape)}")
    tp_ctx = tplib.RingCtx("model", tp) if tp_overlap else None
    if tp_overlap or cp > 1:
        if ep > 1:
            fold_axes = ep_fold_axes(plan)
            ep_ctx = tplib.RingCtx(
                fold_axes if len(fold_axes) > 1 else fold_axes[0], ep)
            ep_impl = select_ep_impl(plan.ep_impl)
        else:
            ep_ctx, ep_impl = None, "overlap"
        ctx = exlib.ParallelContext(
            tp=tp_ctx, cp=tplib.RingCtx("cp", cp) if cp > 1 else None,
            cp_impl=cp_impl, ep=ep_ctx, ep_impl=ep_impl,
            batch_axes=tuple(batch_axes or ()), n_dp=n_dp)
        layer_fwd = exlib.decoder_layer(ctx, cfg, plan, dtype)
    else:
        ctx = exlib.local_context(batch_axes=tuple(batch_axes or ()))
        layer_fwd = _decoder_layer_fwd(cfg, dtype, None, plan, batch_axes)

    # param specs: layer stack sharded over pod on dim 0; the rest replicated
    # over pod (embed/lm_head/final_norm are small relative to the stack).
    # Under overlap TP the model-axis column/row/vocab shards compose in.
    def param_specs(params):
        def one(path, leaf):
            names = _names(path)
            if ep > 1:
                # MoE leaves override to the folded expert layout: routed
                # experts expert-dim-sharded over cp x model, shared experts
                # and router replicated full-width (attention keeps its
                # tp/replicated classification below)
                ep_spec = ep_spec_for_param(names, tuple(leaf.shape), plan)
                if ep_spec is not None:
                    parts = list(ep_spec)
                    if "layers" in names:
                        parts[0] = "pod"
                    return P(*parts)
            if tp_overlap:
                from repro.core.sharding import overlap_spec_for_param
                spec = overlap_spec_for_param(names, tuple(leaf.shape), cfg)
                if "layers" in names:
                    parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
                    parts[0] = "pod"
                    return P(*parts)
                return spec
            return P("pod") if "layers" in names else P()
        return jax.tree_util.tree_map_with_path(one, params)

    # uneven layouts: how padded layer slots are skipped. With TP/CP rings
    # inside the tick the collectives must execute uniformly on every pod,
    # so padded slots run masked (outputs/aux zeroed via where) — the dead
    # compute is bounded by (max_l - l_i) layers; without rings a lax.cond
    # skips the padded layer body outright.
    ring_collectives = tp_overlap or cp > 1

    def _tick_factory(toks_mb, labs_mb, windows_l, active_l, positions):
        """Build tick(params_local, buf, t) -> (x_out, loss_c, aux_c) — one
        pipeline tick of one stage. ``loss_c``/``aux_c`` are (1,)-shaped
        (scalar scan carries break grad-of-shard_map on jax 0.4.x)."""
        stage = jax.lax.axis_index("pod")

        def tick(params_local, buf, t):
            # stage 0 ingests a fresh microbatch while filling (under overlap
            # TP the embedding is vocab-parallel and lands sequence-sharded,
            # matching the (mb, s/tp, d) stage buffers)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            if tp_overlap:
                from repro.train.tensor_parallel import tp_embed
                fresh = tp_embed(params_local, toks_mb[mb_idx], cfg, dtype,
                                 tp_ctx)
            else:
                fresh = _embed(params_local, toks_mb[mb_idx], cfg, dtype)
            x = jnp.where((stage == 0) & (t < n_micro), fresh, buf)

            def body(carry, xs):
                xc, aux = carry
                lp, w, act = xs
                if not uneven:
                    # even split: every slot is real; `act` is untouched and
                    # DCE'd, keeping this path identical to the classic one
                    xn, a = layer_fwd(xc, lp, w, positions)
                    return (xn, aux + a), None
                if ring_collectives:
                    # masked uniform execution: the TP/CP collectives inside
                    # layer_fwd must run on every pod every slot — compute
                    # the padded slot too, then discard its contribution
                    xn, a = layer_fwd(xc, lp, w, positions)
                    xn = jnp.where(act, xn, xc)
                    return (xn, aux + jnp.where(act, a, 0.0)), None

                def run(op):
                    xc_, lp_, w_ = op
                    xn_, a_ = layer_fwd(xc_, lp_, w_, positions)
                    return xn_, jnp.reshape(a_, (-1,))[:1]

                def skip(op):
                    return op[0], jnp.zeros((1,), jnp.float32)

                xn, a = jax.lax.cond(act, run, skip, (xc, lp, w))
                return (xn, aux + a), None

            (x, aux), _ = jax.lax.scan(
                _remat(body, plan.remat),
                (x, jnp.zeros((1,), jnp.float32)),
                (params_local["layers"], windows_l[0], active_l[0]))

            # LM head + loss only on the last stage, and only once the
            # microbatch that entered at t - (P-1) has drained — lax.cond
            # skips the dead logits/xent compute everywhere else
            out_idx = jnp.clip(t - (pp - 1), 0, n_micro - 1)
            take = (stage == pp - 1) & (t >= pp - 1)
            # MoE aux comes from *this stage's own layers*, so every stage
            # contributes it for every real microbatch it processes (tick t
            # carries microbatch t - stage); gating it on `take` would drop
            # the load-balancing pressure of stages 0..P-2 entirely
            aux_take = (t >= stage) & (t < stage + n_micro)

            if tp_overlap:
                # Vocab-parallel final stage: ring-AG fused into the head
                # GEMM, per-shard + scalar-psum loss reductions — the
                # (mb, s, V) logits tensor never materializes. The head's
                # ring/psum collectives must execute uniformly across pods
                # (the lax.cond predicate is per-stage, and in the 1F1B
                # backward per-recompute-tick), so it runs masked on every
                # tick instead of behind the cond; the V/tp vocab shard keeps
                # the dead compute tp× smaller than a full-vocab head would be.
                from repro.train.tensor_parallel import tp_head_nll
                h = rms_norm(x, params_local["final_norm"]["scale"],
                             cfg.rms_eps)
                nll = tp_head_nll(params_local, h, labs_mb[out_idx], cfg,
                                  tp_ctx, dtype, z_loss).mean()
                mb_loss = jnp.where(take, nll, 0.0)
                return x, mb_loss[None], jnp.where(aux_take, aux, 0.0)

            def head(xh):
                h = rms_norm(xh, params_local["final_norm"]["scale"],
                             cfg.rms_eps)
                logits = _logits(params_local, h, cfg, dtype)
                return cross_entropy(logits, labs_mb[out_idx], z_loss=z_loss)

            mb_loss = jax.lax.cond(take, head, lambda xh: jnp.float32(0.0), x)
            return x, mb_loss[None], jnp.where(aux_take, aux, 0.0)

        return tick

    def _microbatches(tokens_l, labels_l):
        bl, s = tokens_l.shape
        assert bl % n_micro == 0, (bl, n_micro)
        mb = bl // n_micro
        return (tokens_l.reshape(n_micro, mb, s),
                labels_l.reshape(n_micro, mb, s), mb, s)

    def _staged_fwd(params_local, tokens_l, labels_l, windows_l, active_l):
        """Fill-drain forward pipeline (shared by both schedules). Returns the
        replicated (2,) vector [xent, moe_aux]."""
        toks_mb, labs_mb, mb, s = _microbatches(tokens_l, labels_l)
        tick = _tick_factory(toks_mb, labs_mb, windows_l, active_l,
                             exlib.cp_local_positions(ctx, s))

        def fwd_tick(carry, t):
            buf, loss_sum, aux_sum = carry
            x, lc, ac = tick(params_local, buf, t)
            buf = jax.lax.ppermute(x, "pod", perm_fwd)
            return (buf, loss_sum + lc, aux_sum + ac), None

        buf0 = jnp.zeros((mb, s // tp if tp_overlap else s, cfg.d_model),
                         dtype)
        zero = jnp.zeros((1,), jnp.float32)
        (_, loss_sum, aux_sum), _ = jax.lax.scan(
            fwd_tick, (buf0, zero, zero), jnp.arange(n_micro + pp - 1))
        # broadcast the last stage's mean loss to all pods, then average
        # over the data-parallel shards (and the cp sequence shards: each
        # rank's per-microbatch loss is the mean over its own chunk)
        loss = jax.lax.psum(loss_sum[0], "pod") / n_micro
        aux = jax.lax.psum(aux_sum[0], "pod") / n_micro
        if batch_axes:
            loss = jax.lax.pmean(loss, batch_axes)
            aux = jax.lax.pmean(aux, batch_axes)
        if cp > 1:
            loss = jax.lax.pmean(loss, "cp")
            aux = jax.lax.pmean(aux, "cp")
        return jnp.stack([loss, aux])

    def _staged_bwd(params_local, tokens_l, labels_l, windows_l, active_l, g):
        """1F1B backward: one scan whose tick t (a) advances the forward
        recompute pipeline by one stage-tick and (b) retires the backward
        stage-tick for the microbatch this stage owes at t. Saved stage inputs
        wait in a 2P-1 ring between (a) and (b); peak in-flight activations
        are O(P), never O(M)."""
        stage = jax.lax.axis_index("pod")
        toks_mb, labs_mb, mb, s = _microbatches(tokens_l, labels_l)
        tick = _tick_factory(toks_mb, labs_mb, windows_l, active_l,
                             exlib.cp_local_positions(ctx, s))

        ring = 2 * pp - 1
        n_ticks = n_micro + 2 * (pp - 1)
        # loss = pmean_data(psum_pod(Σ_m mb_loss) / M): each microbatch loss
        # carries weight 1/(M · n_dp) toward the global scalar. Under overlap
        # TP, mb_loss is *replicated* over the model axis (every rank computes
        # it cooperatively through the ring/psum collectives), so the weight
        # splits across the tp replicas: the psum transposes inside the vjp
        # re-sum the per-rank seeds, and a full seed per rank would overcount
        # every gradient by exactly tp. The cp pmean splits it across the cp
        # ranks the same way (each chunk's mean carries weight 1/cp).
        w_scale = n_micro * n_dp * (tp if tp_overlap else 1) * cp
        w_loss = g[0] / w_scale
        w_aux = g[1] / w_scale

        def btick(carry, t):
            fbuf, xring, dbuf, gacc = carry

            # (a) forward recompute: stash this tick's stage input, advance
            # the pipe one stage-tick (idle once every microbatch has drained)
            xring = jax.lax.dynamic_update_index_in_dim(
                xring, fbuf, jnp.mod(t, ring), axis=0)
            x_out = jax.lax.cond(
                t < n_micro + pp - 1,
                lambda b: tick(params_local, b, t)[0], lambda b: b, fbuf)
            fbuf_next = jax.lax.ppermute(x_out, "pod", perm_fwd)

            # (b) backward: stage p owes microbatch m = t - 2(P-1) + p, whose
            # stage input was stashed at forward tick t_f = m + p
            m = t - 2 * (pp - 1) + stage
            valid = (m >= 0) & (m < n_micro)
            t_f = m + stage
            x_in = jax.lax.dynamic_index_in_dim(
                xring, jnp.mod(t_f, ring), axis=0, keepdims=False)
            _, vjp_fn = jax.vjp(
                lambda p, b: tick(p, b, t_f), params_local, x_in)
            mask = jnp.where(valid, 1.0, 0.0)
            seeds = (jnp.where(valid, dbuf, 0).astype(dbuf.dtype),
                     (w_loss * mask)[None], (w_aux * mask)[None])
            dp, dx_in = vjp_fn(seeds)
            gacc = jax.tree.map(jnp.add, gacc, dp)
            # the input cotangent belongs to the previous stage's output —
            # rotate it backward one stage (stage 0 emits zeros: its input is
            # the embedding, so the wrap-around to stage P-1 carries nothing)
            dbuf_next = jax.lax.ppermute(dx_in, "pod", perm_bwd)
            return (fbuf_next, xring, dbuf_next, gacc), None

        buf0 = jnp.zeros((mb, s // tp if tp_overlap else s, cfg.d_model),
                         dtype)
        gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params_local)
        init = (buf0, jnp.zeros((ring,) + buf0.shape, dtype),
                jnp.zeros_like(buf0), gacc0)
        (_, _, _, gacc), _ = jax.lax.scan(btick, init, jnp.arange(n_ticks))

        # the 1/(M·n_dp) weight is already in the seeds, so grads just sum
        # across DP shards; embed/head/final_norm live on every pod but only
        # one stage produced their cotangent — psum over pod completes them.
        # Under overlap TP, model-replicated leaves (norm scales) saw only
        # this rank's sequence chunk — psum over model completes those.
        def finish(path, g_leaf):
            names = _names(path)
            if batch_axes:
                g_leaf = jax.lax.psum(g_leaf, batch_axes)
            if "layers" not in names:
                g_leaf = jax.lax.psum(g_leaf, "pod")
            if ep > 1:
                ep_spec = ep_spec_for_param(names, tuple(g_leaf.shape), plan)
                if ep_spec is not None:
                    if any(ax is not None for ax in ep_spec):
                        # routed experts: fold-sharded on the expert dim — the
                        # a2a backward already accumulated every rank's tokens
                        # into this rank's local-expert dW; a fold psum would
                        # sum *different experts'* shards element-wise
                        return g_leaf
                    # shared experts / router: replicated over the fold but
                    # each rank's backward saw only its tokens
                    for a in ep_fold_axes(plan):
                        g_leaf = jax.lax.psum(g_leaf, a)
                    return g_leaf
            if cp > 1:
                # params are replicated over cp but each rank's backward saw
                # only its sequence chunk — psum completes every leaf
                g_leaf = jax.lax.psum(g_leaf, "cp")
            if tp_overlap:
                from repro.core.sharding import overlap_spec_for_param
                spec = overlap_spec_for_param(
                    _names(path), tuple(g_leaf.shape), cfg)
                if all(ax is None for ax in spec):
                    g_leaf = jax.lax.psum(g_leaf, "model")
            return g_leaf

        return jax.tree_util.tree_map_with_path(finish, gacc)

    seq_ax = "cp" if cp > 1 else None
    windows_dev = jnp.asarray(windows_host)
    active_dev = jnp.asarray(active_np)
    pack_arr = jnp.asarray(pack_idx) if uneven else None

    def _pack_params(params):
        """Gather the canonical (n_layers, ...) layer stacks into the padded
        (pp*max_l, ...) pipeline stacks (identity for even layouts, so the
        classic path's trace is untouched)."""
        if not uneven:
            return params
        packed = dict(params)
        packed["layers"] = jax.tree.map(
            lambda x: jnp.take(x, pack_arr, axis=0), params["layers"])
        return packed

    def _unpack_grads(grads, params):
        """Scatter-add padded-stack grads back onto the canonical stacks.
        Padded slots carry exact zeros (their outputs are masked / cond-
        skipped), so the add is a pure inverse of the pack gather."""
        if not uneven:
            return grads
        out = dict(grads)
        out["layers"] = jax.tree.map(
            lambda gp, p: jnp.zeros(p.shape, gp.dtype).at[pack_arr].add(gp),
            grads["layers"], params["layers"])
        return out

    def _run_fwd(params, tokens, labels):
        pk = _pack_params(params)
        return shard_map(
            _staged_fwd, mesh=mesh,
            in_specs=(param_specs(pk),
                      P(baxes, seq_ax), P(baxes, seq_ax), P("pod", None),
                      P("pod", None)),
            out_specs=P(),
        )(pk, tokens, labels, windows_dev, active_dev)

    @jax.custom_vjp
    def f1b(params, tokens, labels):
        return _run_fwd(params, tokens, labels)

    def f1b_fwd(params, tokens, labels):
        # residuals are just (params, batch): unlike reverse-AD through the
        # forward scan, no per-tick activations survive the forward pass
        return f1b(params, tokens, labels), (params, tokens, labels)

    def f1b_bwd(res, g):
        params, tokens, labels = res
        pk = _pack_params(params)
        pspecs = param_specs(pk)
        grads = shard_map(
            _staged_bwd, mesh=mesh,
            in_specs=(pspecs, P(baxes, seq_ax), P(baxes, seq_ax),
                      P("pod", None), P("pod", None), P()),
            out_specs=pspecs,
        )(pk, tokens, labels, windows_dev, active_dev, g)
        grads = _unpack_grads(grads, params)
        zt = np.zeros(tokens.shape, dtype=jax.dtypes.float0)
        zl = np.zeros(labels.shape, dtype=jax.dtypes.float0)
        return grads, zt, zl

    f1b.defvjp(f1b_fwd, f1b_bwd)

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if zigzag:
            # ring-cp layout: zigzag-permute the sequence outside the
            # shard_map so the contiguous P(..., "cp") spec hands each rank
            # its balanced sub-chunk pair (position-wise ops are invariant)
            perm = exlib.zigzag_permutation(tokens.shape[1], cp)
            tokens, labels = tokens[:, perm], labels[:, perm]
        if schedule == "1f1b":
            v = f1b(params, tokens, labels)
        else:
            v = _run_fwd(params, tokens, labels)
        loss, aux = v[0], v[1]
        return loss + aux, {"xent": loss, "moe_aux": aux}

    return loss_fn
