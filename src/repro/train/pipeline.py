"""Pipeline parallelism over the ``pod`` axis (survey §4.1.3).

SPMD formulation (the JAX-native equivalent of MPMD GPipe — DESIGN.md §2):
inside a ``shard_map`` over ``pod``, every pod executes the same program; pod
``i`` holds layers [i·L/P, (i+1)·L/P) (the layer-stacked params are sharded on
their leading dim), and activations rotate stage-to-stage with
``ppermute``. The schedule is GPipe fill-drain: with M microbatches and P
stages the loop runs M+P-1 ticks, bubble fraction (P-1)/(M+P-1). Reverse-mode
AD differentiates straight through the ``ppermute``s, generating the mirrored
backward pipeline automatically.

Embedding runs on every pod (cheap, replicated weights) but only stage 0's
output enters the pipeline; the LM head + loss run on the last stage and the
scalar loss is broadcast back with a ``psum`` mask — standard SPMD-pipeline
bookkeeping.

Supported for decoder-only families (dense / vlm backbones); the hybrid/
enc-dec/MoE archs pipeline equally in principle but are out of scope for this
feature (EXPERIMENTS.md notes which configs exercise it).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.config import ModelConfig, ParallelPlan
from repro.models.families import _decoder_layer_fwd, _embed, _layer_windows, _logits
from repro.models.layers import rms_norm
from repro.train.loss import cross_entropy


def pipelined_loss_fn(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh,
                      batch_axes: Tuple[str, ...] = ("data",)):
    """Returns loss_fn(params, batch) with layers pipelined over ``pod``.

    Requires: mesh has a ``pod`` axis, plan.pp == mesh.shape["pod"],
    plan.microbatches >= plan.pp, cfg.n_layers % pp == 0.
    """
    pp = mesh.shape["pod"]
    assert plan.pp == pp and cfg.n_layers % pp == 0
    n_micro = plan.microbatches
    assert n_micro >= pp, "need microbatches >= stages for pipelining"
    layers_per_stage = cfg.n_layers // pp
    dtype = jnp.dtype(plan.compute_dtype)
    windows_all = jnp.asarray(_layer_windows(cfg))
    layer_fwd = _decoder_layer_fwd(cfg, dtype, None, plan, batch_axes)
    baxes = batch_axes if batch_axes else None

    # param specs: layer stack sharded over pod on dim 0; the rest replicated
    # over pod (embed/lm_head/final_norm are small relative to the stack).
    def param_specs(params):
        def one(path, leaf):
            names = [str(getattr(p, "key", getattr(p, "name", p)))
                     for p in path]
            if "layers" in names:
                return P("pod")
            return P()
        return jax.tree_util.tree_map_with_path(one, params)

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        b, s = tokens.shape

        pspecs = param_specs(params)
        windows = windows_all.reshape(pp, layers_per_stage)

        def staged(params_local, tokens_l, labels_l, windows_l):
            stage = jax.lax.axis_index("pod")
            positions = jnp.arange(s)

            # microbatch queue over the LOCAL (data-sharded) batch;
            # stage 0 feeds the pipe
            bl = tokens_l.shape[0]
            assert bl % n_micro == 0, (bl, n_micro)
            mb = bl // n_micro
            toks_mb = tokens_l.reshape(n_micro, mb, s)
            labs_mb = labels_l.reshape(n_micro, mb, s)

            # scalar scan carries break grad-of-shard_map on jax 0.4.x (the
            # linearization's scalar residuals can't be spec'd per-device) —
            # every accumulator below is carried as shape (1,) instead
            def stage_fn(x):
                def body(carry, xs):
                    xc, aux = carry
                    lp, w = xs
                    xn, a = layer_fwd(xc, lp, w, positions)
                    return (xn, aux + a), None
                (x, aux), _ = jax.lax.scan(
                    body, (x, jnp.zeros((1,), jnp.float32)),
                    (params_local["layers"], windows_l[0]))
                return x, aux

            def tick(carry, t):
                buf, loss_sum, aux_sum, tok_count = carry
                # stage 0 ingests microbatch t (if still filling)
                mb_idx = jnp.clip(t, 0, n_micro - 1)
                fresh = _embed(params_local, toks_mb[mb_idx], cfg, dtype)
                x = jnp.where((stage == 0) & (t < n_micro), fresh, buf)
                x, aux = stage_fn(x)
                # last stage computes loss for the microbatch that entered at
                # t - (pp - 1)
                out_idx = jnp.clip(t - (pp - 1), 0, n_micro - 1)
                h = rms_norm(x, params_local["final_norm"]["scale"], cfg.rms_eps)
                logits = _logits(params_local, h, cfg, dtype)
                mb_loss = cross_entropy(logits, labs_mb[out_idx])
                take = (stage == pp - 1) & (t >= pp - 1)
                loss_sum = loss_sum + jnp.where(take, mb_loss, 0.0)
                aux_sum = aux_sum + jnp.where(take, aux, 0.0)
                tok_count = tok_count + jnp.where(take, 1.0, 0.0)
                # rotate activations forward one stage
                perm = [(i, (i + 1) % pp) for i in range(pp)]
                buf = jax.lax.ppermute(x, "pod", perm)
                return (buf, loss_sum, aux_sum, tok_count), None

            buf0 = jnp.zeros((mb, s, cfg.d_model), dtype)
            zero = jnp.zeros((1,), jnp.float32)
            init = (buf0, zero, zero, zero)
            (buf, loss_sum, aux_sum, cnt), _ = jax.lax.scan(
                tick, init, jnp.arange(n_micro + pp - 1))
            # broadcast the last stage's mean loss to all pods, then average
            # over the data-parallel shards
            loss = jax.lax.psum(loss_sum[0], "pod") / n_micro
            aux = jax.lax.psum(aux_sum[0], "pod") / n_micro
            if batch_axes:
                loss = jax.lax.pmean(loss, batch_axes)
                aux = jax.lax.pmean(aux, batch_axes)
            return loss, aux

        in_specs = (pspecs,
                    P(baxes, None), P(baxes, None),
                    P("pod", None))
        loss, aux = shard_map(
            staged, mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P()),
        )(params, tokens, labels, windows)
        return loss + aux, {"xent": loss, "moe_aux": aux}

    return loss_fn
