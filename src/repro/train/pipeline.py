"""Pipeline parallelism over the ``pod`` axis (survey §4.1.3).

SPMD formulation (the JAX-native equivalent of MPMD pipeline schedules —
DESIGN.md §2): inside a ``shard_map`` over ``pod``, every pod executes the same
program; pod ``i`` holds layers [i·L/P, (i+1)·L/P) (the layer-stacked params
are sharded on their leading dim), and activations rotate stage-to-stage with
``ppermute``. Embedding runs on every pod (cheap, replicated weights) but only
stage 0's output enters the pipeline; the LM head + loss run on the last stage
(behind a ``lax.cond`` so the other stages skip the dead logits/xent compute)
and the scalar loss is broadcast back with a ``psum`` mask.

Two schedules, selected by ``plan.pp_schedule``:

- ``"gpipe"`` — fill-drain: the forward scan runs M+P-1 ticks and reverse-mode
  AD differentiates straight through the ``ppermute``s, generating the mirrored
  backward pipeline automatically. Simple, but the autodiff keeps every tick's
  stage activations live between the forward and backward scans: peak in-flight
  activation memory is O(M) microbatches.

- ``"1f1b"`` (default) — one-forward-one-backward: the loss is a
  ``jax.custom_vjp`` whose forward saves nothing but (params, batch), and whose
  backward runs ONE scan in which every tick advances the forward pipeline by
  one stage-tick (recompute) AND retires one backward stage-tick for the
  microbatch that just drained — the mirrored drain interleaved with forward
  ticks. Stage inputs wait in a ring buffer of 2P-1 slots between their
  recompute tick and their backward tick, so peak in-flight activations drop
  from O(M) microbatches to O(P) stages. Loss and gradients are bit-compatible
  with GPipe (same per-microbatch math, same f32 accumulation order up to
  reassociation).

Backward schedule bookkeeping (P stages, M microbatches, tick t):
the forward recompute of microbatch ``m`` reaches stage ``p`` at tick
``m + p``; its backward runs at stage ``p`` at tick ``m + 2(P-1) - p``
(the cotangent enters at the last stage the tick its recompute finishes and
``ppermute``s backward one stage per tick). A stage therefore holds a saved
stage input for at most ``2(P-1)`` ticks — the ring of ``2P-1`` slots is
exactly enough, and the scan runs ``M + 2(P-1)`` ticks total.

Supported for decoder-only families (dense / vlm backbones); the hybrid/
enc-dec/MoE archs pipeline equally in principle but are out of scope for this
feature (EXPERIMENTS.md notes which configs exercise it).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.config import ModelConfig, ParallelPlan
from repro.models.families import (_decoder_layer_fwd, _embed, _layer_windows,
                                   _logits, _remat)
from repro.models.layers import rms_norm
from repro.train.loss import cross_entropy


def _names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", p))) for p in path)


def pipelined_loss_fn(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh,
                      batch_axes: Tuple[str, ...] = ("data",),
                      z_loss: float = 0.0):
    """Returns loss_fn(params, batch) with layers pipelined over ``pod``.

    Requires: mesh has a ``pod`` axis, plan.pp == mesh.shape["pod"],
    plan.microbatches >= plan.pp, cfg.n_layers % pp == 0. ``z_loss`` is
    threaded into the per-microbatch cross-entropy so pipelined and
    single-stage losses agree bit-for-bit.
    """
    pp = mesh.shape["pod"]
    assert plan.pp == pp and cfg.n_layers % pp == 0
    n_micro = plan.microbatches
    assert n_micro >= pp, "need microbatches >= stages for pipelining"
    schedule = plan.pp_schedule
    layers_per_stage = cfg.n_layers // pp
    dtype = jnp.dtype(plan.compute_dtype)
    windows_all = jnp.asarray(_layer_windows(cfg))
    layer_fwd = _decoder_layer_fwd(cfg, dtype, None, plan, batch_axes)
    baxes = batch_axes if batch_axes else None
    n_dp = 1
    for a in (batch_axes or ()):
        n_dp *= mesh.shape[a]
    perm_fwd = [(i, (i + 1) % pp) for i in range(pp)]
    perm_bwd = [(i, (i - 1) % pp) for i in range(pp)]

    # param specs: layer stack sharded over pod on dim 0; the rest replicated
    # over pod (embed/lm_head/final_norm are small relative to the stack).
    def param_specs(params):
        def one(path, leaf):
            return P("pod") if "layers" in _names(path) else P()
        return jax.tree_util.tree_map_with_path(one, params)

    def _tick_factory(toks_mb, labs_mb, windows_l, positions):
        """Build tick(params_local, buf, t) -> (x_out, loss_c, aux_c) — one
        pipeline tick of one stage. ``loss_c``/``aux_c`` are (1,)-shaped
        (scalar scan carries break grad-of-shard_map on jax 0.4.x)."""
        stage = jax.lax.axis_index("pod")

        def tick(params_local, buf, t):
            # stage 0 ingests a fresh microbatch while filling
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = _embed(params_local, toks_mb[mb_idx], cfg, dtype)
            x = jnp.where((stage == 0) & (t < n_micro), fresh, buf)

            def body(carry, xs):
                xc, aux = carry
                lp, w = xs
                xn, a = layer_fwd(xc, lp, w, positions)
                return (xn, aux + a), None

            (x, aux), _ = jax.lax.scan(
                _remat(body, plan.remat),
                (x, jnp.zeros((1,), jnp.float32)),
                (params_local["layers"], windows_l[0]))

            # LM head + loss only on the last stage, and only once the
            # microbatch that entered at t - (P-1) has drained — lax.cond
            # skips the dead logits/xent compute everywhere else
            out_idx = jnp.clip(t - (pp - 1), 0, n_micro - 1)
            take = (stage == pp - 1) & (t >= pp - 1)

            def head(xh):
                h = rms_norm(xh, params_local["final_norm"]["scale"],
                             cfg.rms_eps)
                logits = _logits(params_local, h, cfg, dtype)
                return cross_entropy(logits, labs_mb[out_idx], z_loss=z_loss)

            mb_loss = jax.lax.cond(take, head, lambda xh: jnp.float32(0.0), x)
            return x, mb_loss[None], jnp.where(take, aux, 0.0)

        return tick

    def _microbatches(tokens_l, labels_l):
        bl, s = tokens_l.shape
        assert bl % n_micro == 0, (bl, n_micro)
        mb = bl // n_micro
        return (tokens_l.reshape(n_micro, mb, s),
                labels_l.reshape(n_micro, mb, s), mb, s)

    def _staged_fwd(params_local, tokens_l, labels_l, windows_l):
        """Fill-drain forward pipeline (shared by both schedules). Returns the
        replicated (2,) vector [xent, moe_aux]."""
        toks_mb, labs_mb, mb, s = _microbatches(tokens_l, labels_l)
        tick = _tick_factory(toks_mb, labs_mb, windows_l, jnp.arange(s))

        def fwd_tick(carry, t):
            buf, loss_sum, aux_sum = carry
            x, lc, ac = tick(params_local, buf, t)
            buf = jax.lax.ppermute(x, "pod", perm_fwd)
            return (buf, loss_sum + lc, aux_sum + ac), None

        buf0 = jnp.zeros((mb, s, cfg.d_model), dtype)
        zero = jnp.zeros((1,), jnp.float32)
        (_, loss_sum, aux_sum), _ = jax.lax.scan(
            fwd_tick, (buf0, zero, zero), jnp.arange(n_micro + pp - 1))
        # broadcast the last stage's mean loss to all pods, then average
        # over the data-parallel shards
        loss = jax.lax.psum(loss_sum[0], "pod") / n_micro
        aux = jax.lax.psum(aux_sum[0], "pod") / n_micro
        if batch_axes:
            loss = jax.lax.pmean(loss, batch_axes)
            aux = jax.lax.pmean(aux, batch_axes)
        return jnp.stack([loss, aux])

    def _staged_bwd(params_local, tokens_l, labels_l, windows_l, g):
        """1F1B backward: one scan whose tick t (a) advances the forward
        recompute pipeline by one stage-tick and (b) retires the backward
        stage-tick for the microbatch this stage owes at t. Saved stage inputs
        wait in a 2P-1 ring between (a) and (b); peak in-flight activations
        are O(P), never O(M)."""
        stage = jax.lax.axis_index("pod")
        toks_mb, labs_mb, mb, s = _microbatches(tokens_l, labels_l)
        tick = _tick_factory(toks_mb, labs_mb, windows_l, jnp.arange(s))

        ring = 2 * pp - 1
        n_ticks = n_micro + 2 * (pp - 1)
        # loss = pmean_data(psum_pod(Σ_m mb_loss) / M): each microbatch loss
        # carries weight 1/(M · n_dp) toward the global scalar
        w_loss = g[0] / (n_micro * n_dp)
        w_aux = g[1] / (n_micro * n_dp)

        def btick(carry, t):
            fbuf, xring, dbuf, gacc = carry

            # (a) forward recompute: stash this tick's stage input, advance
            # the pipe one stage-tick (idle once every microbatch has drained)
            xring = jax.lax.dynamic_update_index_in_dim(
                xring, fbuf, jnp.mod(t, ring), axis=0)
            x_out = jax.lax.cond(
                t < n_micro + pp - 1,
                lambda b: tick(params_local, b, t)[0], lambda b: b, fbuf)
            fbuf_next = jax.lax.ppermute(x_out, "pod", perm_fwd)

            # (b) backward: stage p owes microbatch m = t - 2(P-1) + p, whose
            # stage input was stashed at forward tick t_f = m + p
            m = t - 2 * (pp - 1) + stage
            valid = (m >= 0) & (m < n_micro)
            t_f = m + stage
            x_in = jax.lax.dynamic_index_in_dim(
                xring, jnp.mod(t_f, ring), axis=0, keepdims=False)
            _, vjp_fn = jax.vjp(
                lambda p, b: tick(p, b, t_f), params_local, x_in)
            mask = jnp.where(valid, 1.0, 0.0)
            seeds = (jnp.where(valid, dbuf, 0).astype(dbuf.dtype),
                     (w_loss * mask)[None], (w_aux * mask)[None])
            dp, dx_in = vjp_fn(seeds)
            gacc = jax.tree.map(jnp.add, gacc, dp)
            # the input cotangent belongs to the previous stage's output —
            # rotate it backward one stage (stage 0 emits zeros: its input is
            # the embedding, so the wrap-around to stage P-1 carries nothing)
            dbuf_next = jax.lax.ppermute(dx_in, "pod", perm_bwd)
            return (fbuf_next, xring, dbuf_next, gacc), None

        buf0 = jnp.zeros((mb, s, cfg.d_model), dtype)
        gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params_local)
        init = (buf0, jnp.zeros((ring,) + buf0.shape, dtype),
                jnp.zeros_like(buf0), gacc0)
        (_, _, _, gacc), _ = jax.lax.scan(btick, init, jnp.arange(n_ticks))

        # the 1/(M·n_dp) weight is already in the seeds, so grads just sum
        # across DP shards; embed/head/final_norm live on every pod but only
        # one stage produced their cotangent — psum over pod completes them
        def finish(path, g_leaf):
            if batch_axes:
                g_leaf = jax.lax.psum(g_leaf, batch_axes)
            if "layers" not in _names(path):
                g_leaf = jax.lax.psum(g_leaf, "pod")
            return g_leaf

        return jax.tree_util.tree_map_with_path(finish, gacc)

    def _run_fwd(params, tokens, labels):
        windows = windows_all.reshape(pp, layers_per_stage)
        return shard_map(
            _staged_fwd, mesh=mesh,
            in_specs=(param_specs(params),
                      P(baxes, None), P(baxes, None), P("pod", None)),
            out_specs=P(),
        )(params, tokens, labels, windows)

    @jax.custom_vjp
    def f1b(params, tokens, labels):
        return _run_fwd(params, tokens, labels)

    def f1b_fwd(params, tokens, labels):
        # residuals are just (params, batch): unlike reverse-AD through the
        # forward scan, no per-tick activations survive the forward pass
        return f1b(params, tokens, labels), (params, tokens, labels)

    def f1b_bwd(res, g):
        params, tokens, labels = res
        pspecs = param_specs(params)
        windows = windows_all.reshape(pp, layers_per_stage)
        grads = shard_map(
            _staged_bwd, mesh=mesh,
            in_specs=(pspecs, P(baxes, None), P(baxes, None),
                      P("pod", None), P()),
            out_specs=pspecs,
        )(params, tokens, labels, windows, g)
        zt = np.zeros(tokens.shape, dtype=jax.dtypes.float0)
        zl = np.zeros(labels.shape, dtype=jax.dtypes.float0)
        return grads, zt, zl

    f1b.defvjp(f1b_fwd, f1b_bwd)

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if schedule == "1f1b":
            v = f1b(params, tokens, labels)
        else:
            v = _run_fwd(params, tokens, labels)
        loss, aux = v[0], v[1]
        return loss + aux, {"xent": loss, "moe_aux": aux}

    return loss_fn
