"""Cross-entropy over (possibly vocab-sharded) logits.

Logits arrive fp32 (models upcast at the head). The log-softmax reduction over a
``model``-sharded vocab dim lowers to a reduce + all-reduce pair under GSPMD —
the vocab-parallel pattern from Megatron-LM (survey §4.1.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array, *, z_loss: float = 0.0):
    """logits: (..., V) fp32; labels: (...) int32. Mean over all positions.

    ``z_loss`` (PaLM-style) regularizes the partition function — also keeps the
    softmax numerics healthy in long bf16 runs.
    """
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - label_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    return nll.mean()


def top1_accuracy(logits: jax.Array, labels: jax.Array):
    return (logits.argmax(-1) == labels).mean()
