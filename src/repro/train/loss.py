"""Cross-entropy over (possibly vocab-sharded) logits.

Logits arrive fp32 (models upcast at the head). Two vocab-parallel flavors of
the Megatron-LM pattern (survey §4.1.2):

- :func:`cross_entropy` — written over full-vocab logits; under GSPMD a
  ``model``-sharded vocab dim lowers the log-softmax to a reduce + all-reduce
  pair automatically.
- :func:`cross_entropy_vp` — the explicit ``shard_map`` twin for the overlap-TP
  path (``train/tensor_parallel.py``): takes this rank's (…, V/tp) logits
  shard and reduces with per-shard max/logsumexp/target-logit plus scalar
  ``pmax``/``psum``, so the (B, S, V) logits tensor is never materialized or
  all-gathered (the TODO formerly noted on ``pad_vocab_to_multiple``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _pmax_stopgrad(x, axis_name):
    """pmax with a zero-cotangent VJP: the softmax max-shift is a
    stop_gradient quantity (see :func:`cross_entropy`), and jax has no
    differentiation rule for pmax."""
    return jax.lax.pmax(x, axis_name)


_pmax_stopgrad.defvjp(lambda x, a: (_pmax_stopgrad(x, a), None),
                      lambda a, _, g: (jnp.zeros_like(g),))


def cross_entropy(logits: jax.Array, labels: jax.Array, *, z_loss: float = 0.0,
                  reduction: str = "mean"):
    """logits: (..., V) fp32; labels: (...) int32. Mean over all positions.

    ``z_loss`` (PaLM-style) regularizes the partition function — also keeps the
    softmax numerics healthy in long bf16 runs. ``reduction="none"`` returns
    the per-position nll instead of the mean — the context-parallel executor
    loss owns its own sum/psum reduction over sequence shards.
    """
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - label_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if reduction == "none":
        return nll
    return nll.mean()


def cross_entropy_vp(logits: jax.Array, labels: jax.Array, *, axis_name: str,
                     shard_index=None, z_loss: float = 0.0):
    """Vocab-parallel cross-entropy over a ``shard_map`` vocab axis.

    ``logits``: (..., V/tp) fp32 — this rank's vocab shard; ``labels``: (...)
    global token ids. The softmax statistics reduce per shard first, then a
    scalar-per-position ``pmax``/``psum`` pair completes them across
    ``axis_name``; the target logit is a masked local gather + psum (exact:
    one rank contributes, the rest add zeros). Returns per-position nll,
    replicated over the vocab axis — callers own the mean/sum reduction.
    """
    if shard_index is None:
        shard_index = jax.lax.axis_index(axis_name)
    v_loc = logits.shape[-1]
    m = _pmax_stopgrad(jax.lax.stop_gradient(logits.max(axis=-1)), axis_name)
    shifted = logits - m[..., None]
    se = jax.lax.psum(jnp.sum(jnp.exp(shifted), axis=-1), axis_name)
    lse = jnp.log(se) + m
    local = labels.astype(jnp.int32) - shard_index * v_loc
    ok = (local >= 0) & (local < v_loc)
    ll = jnp.take_along_axis(
        logits, jnp.clip(local, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    label_logit = jax.lax.psum(jnp.where(ok, ll, 0.0), axis_name)
    nll = lse - label_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    return nll


def top1_accuracy(logits: jax.Array, labels: jax.Array):
    return (logits.argmax(-1) == labels).mean()
