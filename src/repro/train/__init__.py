from .loss import cross_entropy, cross_entropy_vp, top1_accuracy
from .step import Hyper, TrainState, init_train_state, make_loss_fn, make_train_step

__all__ = [
    "cross_entropy", "cross_entropy_vp", "top1_accuracy",
    "Hyper", "TrainState", "init_train_state", "make_loss_fn", "make_train_step",
]
