"""Unified parallel block executor + context-parallel (cp) sequence axis.

Before this module, every family's forward wiring existed three times: the
GSPMD/dense bodies (``models/families.py``), the overlap-TP twins
(``attn_sublayer_tp`` / ``mlp_sublayer_tp`` / ``moe_block_tp`` /
``ssm_block_tp``) and the pipeline ``stage_fn`` plumbing — O(families × paths)
surface for every new parallel axis. The executor collapses them: each family
defines its math **once** (``attn_block`` / ``mlp_block_ex`` /
``moe_block_ex`` / ``ssm_block_ex``), parameterized by a
:class:`ParallelContext` that decides gather/ring/shard placement:

- ``ctx.tp`` (model-axis ring, PR 4 conventions): column GEMMs fuse the
  sequence all-gather into ``all_gather_matmul`` ring ticks, row GEMMs
  ring-reduce-scatter, activations stay ``(B, S/tp, d)`` between blocks.
  ``ctx.tp is None`` is the local/GSPMD mode — identity collectives, the
  same ops the annotation-sharded baseline runs.
- ``ctx.cp`` (context-parallel ring, survey §4.1.4): the *sequence* itself is
  sharded over a dedicated ``cp`` mesh axis end to end, so no device ever
  holds the full context — the long-context regime where attention
  activation memory, not weights, dominates. Attention under ``cp`` runs

  * ``cp_impl="gather"`` — all-gather K/V over the cp axis (contiguous
    chunks, exact, O(S) KV per device), or
  * ``cp_impl="ring"`` — ring attention: K/V chunks ``ppermute`` around the
    cp ring while the existing flash kernel runs as the inner tile
    (``dispatch_attention_lse``); per-chunk ``(out, lse)`` partials merge
    exactly via the chunked-softmax identity
    ``lse = log Σ exp(lse_c)``, ``o = Σ exp(lse_c − lse) o_c``. Ownership is
    **zigzag** load-balanced (rank ``i`` holds sub-chunks ``i`` and
    ``2·cp−1−i`` of ``2·cp``), so the causal triangle spreads evenly; each
    (q-sub, k-sub) pair is statically one of {fully-masked, diagonal-causal,
    full-attend}, selected by a collective-free ``lax.switch`` (the
    ``ppermute``s stay outside, uniform across ranks — the PR 4 rule). The
    backward is a ``jax.custom_vjp`` **reversed** ring: dk/dv accumulators
    ride around with their KV chunk and arrive home after a final
    ``ppermute``; each chunk's gradients are computed against the globally
    merged ``(lse, Δ)`` (``dispatch_attention_chunk_bwd``).

  The Mamba2 SSD scan composes by passing **per-chunk entering states**
  around the cp ring: every rank scans its local chunk from a zero state
  through the usual dispatcher (the fused kernel stays eligible), the
  (state, total-decay) pair chains across ranks in ``cp−1`` masked
  ``ppermute`` steps, and the carried-in state's contribution is a closed-
  form rank-local einsum (the recurrence is linear in its initial state).
  Causal convs exchange a (d_conv−1)-token halo with the left neighbour.
  MoE routes on **local** sequence shards with batch-global aux statistics
  (the density/proxy sums ``psum`` over data × cp before the mean).

:func:`make_executor_loss_fn` assembles the whole training-path loss for any
tp × cp combination (``train.tensor_parallel.make_tp_loss_fn`` is now a thin
alias); ``train/pipeline.py`` reuses the same layer bodies inside its 1F1B
ticks, so CP × TP × PP composes. Numerical contract, tested in
tests/test_context_parallel.py: ring == gather == single-device loss/grads to
≤ 1e-6 for dense, MoE (no-drop capacity) and Mamba2.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import sharding as shardlib
from repro.core.compat import shard_map
from repro.core.config import Family, ModelConfig, ParallelPlan
from repro.ft.inject import taint
from repro.kernels.dispatch import (dispatch_attention,
                                    dispatch_attention_chunk_bwd,
                                    dispatch_attention_lse, dispatch_ep_a2a,
                                    dispatch_ssd_scan, select_cp_impl,
                                    select_ep_impl)
from repro.models.layers import NEG_INF, qkv_proj, rms_norm, rope
from repro.train.tensor_parallel import (RingCtx, all_gather_matmul,
                                         matmul_reduce_scatter,
                                         ring_all_gather, ring_reduce_scatter,
                                         tp_embed, tp_head_nll)


def _identity(x):
    return x


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """How a family block executes on the mesh.

    ``tp``/``cp`` are the model-axis and context-axis rings (``None`` = that
    axis is off); ``cp_impl`` is the *resolved* attention mode
    ("gather" | "ring"). ``ep`` is the folded expert ring of MoE parallel
    folding: the same cp × model devices re-read as one flat expert axis
    (``ep.axis`` is an axis *tuple* when both are engaged; in the ep-only
    placement it is "model" and ``cp`` is the attention ring over that same
    axis), with ``ep_impl`` the resolved a2a mode ("blocking" | "overlap").
    ``cx``/``cq``/``ckv`` are the GSPMD activation constrainers of the local
    mode (identity elsewhere); ``mesh``/``batch_axes``/``n_dp`` feed the
    batch-global MoE aux reductions.
    """
    tp: Optional[RingCtx] = None
    cp: Optional[RingCtx] = None
    cp_impl: str = "ring"
    ep: Optional[RingCtx] = None
    ep_impl: str = "overlap"
    batch_axes: Tuple[str, ...] = ()
    n_dp: int = 1
    mesh: Optional[Mesh] = None
    cx: Callable = _identity
    cq: Callable = _identity
    ckv: Callable = _identity

    @property
    def n_tp(self) -> int:
        return self.tp.size if self.tp is not None else 1

    @property
    def n_cp(self) -> int:
        return self.cp.size if self.cp is not None else 1

    @property
    def n_ep(self) -> int:
        return self.ep.size if self.ep is not None else 1

    @property
    def aux_axes(self) -> Tuple[str, ...]:
        """Axes the MoE aux statistics reduce over (batch-global aux).

        Under EP the router runs shard-local on every fold rank's own
        sequence chunk, so the statistics reduce over the whole fold (which
        subsumes the cp axis when engaged); without EP, routing is
        model-replicated (the tp path re-gathers the sequence) and only the
        data × cp token sharding needs completing."""
        axes = tuple(self.batch_axes)
        if self.ep is not None:
            fold = self.ep.axis if isinstance(self.ep.axis, tuple) \
                else (self.ep.axis,)
            return axes + fold
        if self.cp is not None:
            axes = axes + (self.cp.axis,)
        return axes

    @property
    def n_rep(self) -> int:
        """Token-count multiplier completing local counts to global ones."""
        if self.ep is not None:
            return self.n_dp * self.ep.size
        return self.n_dp * self.n_cp


def local_context(mesh=None, batch_axes: Tuple[str, ...] = (),
                  cx=_identity, cq=_identity, ckv=_identity) -> ParallelContext:
    """The GSPMD/single-device mode: identity collectives, XLA owns layout.

    The plan is *not* part of the context — it threads separately into the
    layer builders (``decoder_layer(ctx, cfg, plan, ...)``)."""
    return ParallelContext(tp=None, cp=None, batch_axes=tuple(batch_axes or ()),
                           mesh=mesh, cx=cx, cq=cq, ckv=ckv)


def _tp_index(ctx: ParallelContext):
    return jax.lax.axis_index(ctx.tp.axis) if ctx.tp is not None else 0


def _cp_index(ctx: ParallelContext):
    return jax.lax.axis_index(ctx.cp.axis) if ctx.cp is not None else 0


def _slice_tp(ctx: ParallelContext, p, n_loc: int, axis: int = 0):
    """This rank's chunk of a model-replicated leaf (identity without tp)."""
    if ctx.tp is None:
        return p
    return jax.lax.dynamic_slice_in_dim(p, _tp_index(ctx) * n_loc, n_loc, axis)


def _proj_cols(ctx: ParallelContext, x, ws):
    """Column GEMMs: the executor's gather decision.

    tp: ring all-gather fused into the GEMM ticks — ``x`` (B, S/tp, d) in,
    ``outs[i]`` (B, S, f_loc) out (plus the gathered ``x``, a free ring
    by-product). local: plain matmuls, ``x`` already whole.
    """
    if ctx.tp is not None:
        return all_gather_matmul(ctx.tp, x, ws)
    return tuple(x @ w for w in ws), x


def _proj_rows(ctx: ParallelContext, h, w):
    """Row GEMM: ring reduce-scatter under tp, plain matmul locally."""
    if ctx.tp is not None:
        return matmul_reduce_scatter(ctx.tp, h, w)
    return h @ w


# ---------------------------------------------------------------------------
# context-parallel sequence layout (zigzag)


def zigzag_permutation(seq: int, cp: int) -> np.ndarray:
    """Global-position permutation for the zigzag ring layout.

    The sequence splits into ``2·cp`` contiguous sub-chunks; rank ``r`` owns
    sub-chunks ``r`` and ``2·cp−1−r``, so every rank's causal-attention work
    (the number of attended (q, k) pairs) is identical — the load-balancing
    trick ring attention needs because the causal triangle makes contiguous
    chunks wildly uneven. ``tokens[:, perm]`` reorders a batch so that a
    plain contiguous ``P(..., "cp")`` shard_map spec hands each rank its
    zigzag pair; everything position-wise (embedding, rope with explicit
    positions, per-token loss) is permutation-invariant.
    """
    assert seq % (2 * cp) == 0, (seq, cp)
    lc = seq // (2 * cp)
    parts = []
    for r in range(cp):
        parts.append(np.arange(r * lc, (r + 1) * lc))
        parts.append(np.arange((2 * cp - 1 - r) * lc, (2 * cp - r) * lc))
    return np.concatenate(parts)


def zigzag_pair_counts(seq: int, cp: int) -> np.ndarray:
    """Attended causal (q, k) pairs per rank under the zigzag layout (static
    accounting used by the load-balance unit tests)."""
    perm = zigzag_permutation(seq, cp)
    s_loc = seq // cp
    counts = np.zeros((cp,), np.int64)
    for r in range(cp):
        q_pos = perm[r * s_loc:(r + 1) * s_loc]
        counts[r] = int(np.sum(q_pos + 1))    # each query attends pos+1 keys
    return counts


def cp_local_positions(ctx: ParallelContext, s_loc: int):
    """Global positions of this rank's (cp-local) sequence chunk.

    Contiguous layout (gather / SSM): ``[idx·s_loc, (idx+1)·s_loc)``.
    Zigzag (ring attention): the concatenation of the rank's two sub-chunk
    ranges. Without cp: ``arange(s_loc)``.
    """
    if ctx.cp is None:
        return jnp.arange(s_loc)
    idx = _cp_index(ctx)
    if ctx.cp_impl != "ring":
        return idx * s_loc + jnp.arange(s_loc)
    lc = s_loc // 2
    cp = ctx.cp.size
    return jnp.concatenate([idx * lc + jnp.arange(lc),
                            (2 * cp - 1 - idx) * lc + jnp.arange(lc)])


# ---------------------------------------------------------------------------
# ring attention (zigzag, lse-merging, custom-VJP reversed ring)


@dataclasses.dataclass(frozen=True)
class RingAttnParams:
    """Static ring-attention parameters (hashable: rides nondiff_argnums)."""
    ctx: RingCtx
    softcap: float = 0.0
    scale: Optional[float] = None
    impl: str = "auto"
    block_size: int = 1024
    block_q: int = 128
    block_k: int = 128


def _merge_lse(o, lse, o_c, lse_c):
    """Exact chunked-softmax merge of normalized partials (fp32)."""
    m = jnp.maximum(lse, lse_c)
    w1 = jnp.exp(lse - m)
    w2 = jnp.exp(lse_c - m)
    tot = w1 + w2
    o_new = (o * w1[..., None] + o_c.astype(jnp.float32) * w2[..., None]) / \
        tot[..., None]
    return o_new, m + jnp.log(tot)


def _pair_attention(rp: RingAttnParams, q, k, v, rel):
    """One (q-sub, k-sub) tile of the ring forward.

    ``rel`` (traced) is the q-sub-chunk id minus the k-sub-chunk id; zigzag
    alignment makes the mask statically one of three cases, so the flash
    kernel (compile-time masks) stays eligible inside a collective-free
    ``lax.switch``: rel < 0 → fully masked, rel == 0 → diagonal causal,
    rel > 0 → full attend.
    """
    b, lc, hq, hd = q.shape

    def masked(_q, _k, _v):
        return (jnp.zeros((b, lc, hq, hd), _q.dtype),
                jnp.full((b, lc, hq), NEG_INF, jnp.float32))

    def diag(q_, k_, v_):
        return dispatch_attention_lse(
            q_, k_, v_, impl=rp.impl, causal=True, softcap=rp.softcap,
            scale=rp.scale, block_size=rp.block_size, block_q=rp.block_q,
            block_k=rp.block_k)

    def full(q_, k_, v_):
        return dispatch_attention_lse(
            q_, k_, v_, impl=rp.impl, causal=False, softcap=rp.softcap,
            scale=rp.scale, block_size=rp.block_size, block_q=rp.block_q,
            block_k=rp.block_k)

    case = (jnp.clip(jnp.sign(rel), -1, 1) + 1).astype(jnp.int32)
    return jax.lax.switch(case, [masked, diag, full], q, k, v)


def _pair_grads(rp: RingAttnParams, q, k, v, do, lse, delta, rel):
    """One (q-sub, k-sub) tile of the ring backward, against the merged
    (lse, Δ) — same three static mask cases as the forward."""
    hkv = k.shape[2]

    def masked(*_):
        return (jnp.zeros(q.shape, jnp.float32),
                jnp.zeros(k.shape[:2] + (hkv, q.shape[-1]), jnp.float32),
                jnp.zeros(v.shape[:2] + (hkv, v.shape[-1]), jnp.float32))

    def chunk(causal):
        def f(q_, k_, v_, do_, lse_, delta_):
            dq, dk, dv = dispatch_attention_chunk_bwd(
                q_, k_, v_, do_, lse_, delta_, impl=rp.impl, causal=causal,
                softcap=rp.softcap, scale=rp.scale, block_q=rp.block_q,
                block_k=rp.block_k)
            return (dq.astype(jnp.float32), dk.astype(jnp.float32),
                    dv.astype(jnp.float32))
        return f

    case = (jnp.clip(jnp.sign(rel), -1, 1) + 1).astype(jnp.int32)
    return jax.lax.switch(case, [masked, chunk(True), chunk(False)],
                          q, k, v, do, lse, delta)


def _sub_ids(rp: RingAttnParams, owner):
    cp = rp.ctx.size
    return (owner, 2 * cp - 1 - owner)


def _ring_attn_fwd_impl(rp: RingAttnParams, q, k, v):
    """cp-step ring: per step each rank attends its 2 q-subs against the
    visiting KV chunk's 2 k-subs (4 static-mask tiles), merging (o, lse)
    online; the KV pair ppermutes forward between steps (uniform, outside
    the switches)."""
    cp = rp.ctx.size
    idx = jax.lax.axis_index(rp.ctx.axis)
    b, s_loc, hq, hd = q.shape
    assert s_loc % 2 == 0, \
        f"ring cp needs an even per-rank chunk (2 zigzag sub-chunks), got {s_loc}"
    lc = s_loc // 2
    q_subs = (q[:, :lc], q[:, lc:])
    q_ids = _sub_ids(rp, idx)
    o = [jnp.zeros((b, lc, hq, hd), jnp.float32) for _ in range(2)]
    lse = [jnp.full((b, lc, hq), NEG_INF, jnp.float32) for _ in range(2)]
    k_cur, v_cur = k, v
    for step in range(cp):
        src = (idx - step) % cp
        k_ids = _sub_ids(rp, src)
        for qi in range(2):
            for ki in range(2):
                o_c, lse_c = _pair_attention(
                    rp, q_subs[qi], k_cur[:, ki * lc:(ki + 1) * lc],
                    v_cur[:, ki * lc:(ki + 1) * lc], q_ids[qi] - k_ids[ki])
                o[qi], lse[qi] = _merge_lse(o[qi], lse[qi], o_c, lse_c)
        if step < cp - 1:
            # fault seam: the visiting KV pair as it lands from the ring
            # hop — a corrupted link payload lands here (ft/inject)
            k_cur = taint("cp.ring.kv", jax.lax.ppermute(
                k_cur, rp.ctx.axis, rp.ctx.perm_fwd))
            v_cur = jax.lax.ppermute(v_cur, rp.ctx.axis, rp.ctx.perm_fwd)
    out = jnp.concatenate(o, axis=1).astype(q.dtype)
    return out, jnp.concatenate(lse, axis=1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def ring_attention(rp: RingAttnParams, q, k, v):
    """Zigzag ring attention over the cp axis.

    ``q``/``k``/``v``: (B, S/cp, H, hd) — this rank's zigzag pair of
    sub-chunks, rope already applied with true global positions. Exact
    causal attention over the full sequence; no device ever materializes
    (B, S, ·) K/V or scores. The VJP runs the mirrored **reversed** ring:
    dk/dv accumulators ride with their KV chunk and a final ppermute brings
    them home, each chunk's gradients computed against the globally merged
    (lse, Δ) — so the per-chunk flash backward kernels compose unchanged.
    """
    o, _ = _ring_attn_fwd_impl(rp, q, k, v)
    return o


def _ring_attn_fwd(rp, q, k, v):
    o, lse = _ring_attn_fwd_impl(rp, q, k, v)
    return o, (q, k, v, o, lse)


def _ring_attn_bwd(rp, res, g):
    q, k, v, o, lse = res
    cp = rp.ctx.size
    idx = jax.lax.axis_index(rp.ctx.axis)
    b, s_loc, hq, hd = q.shape
    hkv = k.shape[2]
    lc = s_loc // 2
    do = g.astype(jnp.float32)
    delta = jnp.sum(do * o.astype(jnp.float32), axis=-1)      # (B, S/cp, Hq)
    q_ids = _sub_ids(rp, idx)
    dq = jnp.zeros((b, s_loc, hq, hd), jnp.float32)
    k_cur, v_cur = k, v
    dk_acc = jnp.zeros((b, s_loc, hkv, hd), jnp.float32)
    dv_acc = jnp.zeros((b, s_loc, hkv, hd), jnp.float32)
    for step in range(cp):
        src = (idx + step) % cp           # reversed ring direction
        k_ids = _sub_ids(rp, src)
        for qi in range(2):
            qs = slice(qi * lc, (qi + 1) * lc)
            for ki in range(2):
                ks = slice(ki * lc, (ki + 1) * lc)
                dq_c, dk_c, dv_c = _pair_grads(
                    rp, q[:, qs], k_cur[:, ks], v_cur[:, ks], do[:, qs],
                    lse[:, qs], delta[:, qs], q_ids[qi] - k_ids[ki])
                dq = dq.at[:, qs].add(dq_c)
                dk_acc = dk_acc.at[:, ks].add(dk_c)
                dv_acc = dv_acc.at[:, ks].add(dv_c)
        # the KV chunk and its gradient accumulators ride the reversed ring
        # together; on the last step only the accumulators hop — that final
        # permute brings the summed dk/dv home to the chunk's owner while
        # the (dead) KV buffers stay put
        if step < cp - 1:
            k_cur = jax.lax.ppermute(k_cur, rp.ctx.axis, rp.ctx.perm_bwd)
            v_cur = jax.lax.ppermute(v_cur, rp.ctx.axis, rp.ctx.perm_bwd)
        dk_acc = jax.lax.ppermute(dk_acc, rp.ctx.axis, rp.ctx.perm_bwd)
        dv_acc = jax.lax.ppermute(dv_acc, rp.ctx.axis, rp.ctx.perm_bwd)
    return dq.astype(q.dtype), dk_acc.astype(k.dtype), dv_acc.astype(v.dtype)


ring_attention.defvjp(_ring_attn_fwd, _ring_attn_bwd)


def gather_attention(ctx: ParallelContext, q, k, v, *, window, softcap,
                     impl, block_size: int = 1024):
    """cp_impl="gather": all-gather K/V over the cp axis (contiguous layout)
    and attend local queries against the full context. The traced per-rank
    ``q_offset`` keeps the XLA twins exact (blockwise masks are built from
    jnp position arrays); O(S) KV per device instead of ring's O(S/cp)."""
    s_loc = q.shape[1]
    kf = jax.lax.all_gather(k, ctx.cp.axis, axis=1, tiled=True)
    vf = jax.lax.all_gather(v, ctx.cp.axis, axis=1, tiled=True)
    return dispatch_attention(q, kf, vf, impl=impl, causal=True,
                              window=window, softcap=softcap,
                              q_offset=_cp_index(ctx) * s_loc,
                              block_size=block_size)


# ---------------------------------------------------------------------------
# context-parallel SSD helpers (conv halo + entering-state chain)


def cp_halo_left(ctx: ParallelContext, x, width: int):
    """The left-neighbour halo for a causal op: the previous cp rank's last
    ``width`` positions (zeros on rank 0). One forward ppermute, uniform."""
    tail = x[:, -width:]
    recv = jax.lax.ppermute(tail, ctx.cp.axis, ctx.cp.perm_fwd)
    return jnp.where(_cp_index(ctx) == 0, jnp.zeros_like(recv), recv)


def cp_chain_state(ctx: ParallelContext, state, decay):
    """Entering state per rank of a linear inter-chunk recurrence.

    ``state`` (B, H, P, N): this rank's accumulated state from a **zero**
    initial state; ``decay`` (B, H): the total decay across the rank's
    chunk. Returns E_r = Σ_{j<r} (Π_{j<k<r} A_k) S_j via cp−1 masked
    forward-ppermute steps — rank ``k`` finalizes at step ``k`` from its
    left neighbour's already-final message (collectives uniform, masking by
    ``where``). Plain autodiff differentiates through the ppermutes
    (linear), so the chain composes with the fused local scan's custom VJP.
    """
    cp = ctx.cp.size
    idx = _cp_index(ctx)
    e = jnp.zeros_like(state)
    for k in range(1, cp):
        msg = state + decay[..., None, None] * e
        # fault seam: the chain message as it lands on the next rank
        recv = taint("cp.ring.state", jax.lax.ppermute(
            msg, ctx.cp.axis, ctx.cp.perm_fwd))
        e = jnp.where(idx == k, recv, e)
    return e


# ---------------------------------------------------------------------------
# family blocks (the math, defined once)


def attn_block(ctx: ParallelContext, lp, x, cfg: ModelConfig, *, positions,
               window=0, dtype=jnp.bfloat16, impl="auto", collect_kv=False):
    """Attention sub-block for any placement.

    local: plain qkv projection, dispatcher attention, plain output GEMM
    (plus the GSPMD seq-shard constrainers). tp: the sequence all-gather is
    fused into the QKV GEMM ring ticks, heads are model-sharded, the output
    projection ring-reduce-scatters. cp: attention runs ring/gathered over
    the cp axis (``positions`` carry the true global ids for rope).
    """
    b, s_in = x.shape[:2]
    hd = cfg.head_dim
    if ctx.tp is None:
        q, k, v = qkv_proj(lp, x, cfg, dtype)
    else:
        ws = (lp["wq"].astype(dtype), lp["wk"].astype(dtype),
              lp["wv"].astype(dtype))
        (q, k, v), _ = all_gather_matmul(ctx.tp, x, ws)
        if cfg.qkv_bias:
            q = q + _slice_tp(ctx, lp["bq"].astype(dtype), q.shape[-1])
            k = k + _slice_tp(ctx, lp["bk"].astype(dtype), k.shape[-1])
            v = v + _slice_tp(ctx, lp["bv"].astype(dtype), v.shape[-1])
        s = s_in * ctx.n_tp
        q = q.reshape(b, s, q.shape[-1] // hd, hd)
        k = k.reshape(b, s, k.shape[-1] // hd, hd)
        v = v.reshape(b, s, v.shape[-1] // hd, hd)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q, k, v = ctx.cq(q), ctx.ckv(k), ctx.ckv(v)
    if ctx.cp is None:
        a = dispatch_attention(q, k, v, impl=impl, causal=True, window=window,
                               softcap=cfg.attn_logit_softcap)
    elif ctx.cp_impl == "ring":
        rp = RingAttnParams(ctx.cp, softcap=float(cfg.attn_logit_softcap),
                            impl=impl)
        a = ring_attention(rp, q, k, v)
    else:
        a = gather_attention(ctx, q, k, v, window=window,
                             softcap=cfg.attn_logit_softcap, impl=impl)
    a = ctx.cq(a)
    a = a.reshape(a.shape[0], a.shape[1], -1)
    out = _proj_rows(ctx, a, lp["wo"].astype(dtype))
    if collect_kv:
        return out, (k, v)
    return out


def mlp_block_ex(ctx: ParallelContext, p, x, dtype=jnp.bfloat16):
    """SwiGLU for any placement: one gather decision fused into both the
    gate and up GEMMs, one scatter decision after down."""
    (g, u), _ = _proj_cols(ctx, x, (p["gate"].astype(dtype),
                                    p["up"].astype(dtype)))
    return _proj_rows(ctx, jax.nn.silu(g) * u, p["down"].astype(dtype))


def moe_block_ex(ctx: ParallelContext, p, x, cfg: ModelConfig, dtype,
                 plan: Optional[ParallelPlan] = None):
    """MoE block for any placement. x: (B, S_loc, d) -> (out, aux).

    local: delegates to the dense dispatcher (``moe_lib.moe_block``).
    Sharded: the router sees this (data × cp) shard's token set — under tp a
    ring all-gather re-materializes it once (the GShard cumsum dropping
    policy is order-sensitive, so the model-axis replicas must agree); under
    cp routing is deliberately **local** to the sequence shard (the
    documented shard-local-routing divergence, exact when capacity drops
    nothing) while the aux loss stays batch-global: its density/proxy sums
    psum over data × cp before the mean. The expert SwiGLU is tensor-
    parallel inside each expert when tp is on (d_expert sharded, partials
    psum-completed), full-width otherwise; all three GEMMs keep routing
    through ``dispatch_expert_gemm`` with group_sizes masking.

    ep (``ctx.ep``, MoE parallel folding): the sublayer re-reads the cp ×
    model devices as one flat expert ring — routing is shard-local on this
    fold rank's own sequence chunk (**no** tp re-gather; aux statistics psum
    over the whole fold), each rank owns E/ep complete full-width experts,
    and the dispatch/combine all-to-alls run through ``dispatch_ep_a2a``
    (blocking, or ppermute ticks interleaved with per-peer chunk GEMMs —
    ``ctx.ep_impl``). Post-a2a rows arrive blocked per source peer, so no
    prefix ``group_sizes`` masking applies — padding rows are zero and drop
    out of the GEMMs numerically. Shared experts replicate full-width over
    the fold: every rank routes different tokens, so there is no
    width-partial psum to complete them.
    """
    from repro.models import moe as moe_lib  # noqa: PLC0415 (import cycle)
    if ctx.ep is None and ctx.tp is None and ctx.cp is None:
        return moe_lib.moe_block(p, x, cfg, dtype, ctx.mesh, plan,
                                 ctx.batch_axes)
    e = cfg.moe
    mode = plan.moe_dispatch if plan is not None else "einsum"
    gemm_impl = plan.moe_gemm_impl if plan is not None else "auto"
    b, s_in, d = x.shape
    if ctx.ep is not None:
        n = b * s_in
        xf = x.reshape(n, d)
        capacity = max(int(n * e.top_k / e.num_experts * e.capacity_factor), 1)
        probs, aux = moe_lib.router_probs(p, xf, cfg, dtype, ctx.aux_axes,
                                          ctx.n_rep)
        if mode == "scatter":
            slot, wts = moe_lib.topk_scatter_dispatch(probs, cfg, capacity)
            h = moe_lib._scatter_to_buffers(xf, slot, cfg, capacity)
        else:
            dispatch, combine = moe_lib.topk_dispatch(probs, cfg, capacity)
            h = jnp.einsum("nec,nd->ecd", dispatch.astype(dtype), xf)
        fn = functools.partial(moe_lib.ep_chunk_ffn, dtype=dtype,
                               impl=gemm_impl)
        y = dispatch_ep_a2a(fn, p["experts"], h, axis=ctx.ep.axis,
                            size=ctx.ep.size, impl=ctx.ep_impl)
        if mode == "scatter":
            out = moe_lib._gather_from_buffers(y, slot, wts, dtype)
        else:
            out = jnp.einsum("nec,ecd->nd", combine.astype(dtype), y)
        if e.num_shared_experts:
            sh = jax.nn.silu(xf @ p["shared"]["gate"].astype(dtype)) * (
                xf @ p["shared"]["up"].astype(dtype))
            out = out + sh @ p["shared"]["down"].astype(dtype)
        return out.reshape(b, s_in, d), aux
    if ctx.tp is not None:
        xg = ring_all_gather(ctx.tp, x)            # (B, S_loc·tp, d)
    else:
        xg = x
    s_full = xg.shape[1]
    n = b * s_full
    xf = xg.reshape(n, d)
    capacity = max(int(n * e.top_k / e.num_experts * e.capacity_factor), 1)

    probs, aux = moe_lib.router_probs(p, xf, cfg, dtype, ctx.aux_axes,
                                      ctx.n_rep)

    if mode == "scatter":
        slot, wts = moe_lib.topk_scatter_dispatch(probs, cfg, capacity)
        gs = moe_lib._group_sizes_from_slots(slot, e.num_experts, capacity)
        h = moe_lib._scatter_to_buffers(xf, slot, cfg, capacity)
    else:
        dispatch, combine = moe_lib.topk_dispatch(probs, cfg, capacity)
        gs = moe_lib._group_sizes_from_dispatch(dispatch)
        h = jnp.einsum("nec,nd->ecd", dispatch.astype(dtype), xf)

    part = moe_lib._expert_ffn(p["experts"], h, dtype, gemm_impl, gs)
    if ctx.tp is not None:
        part = jax.lax.psum(part, ctx.tp.axis)   # complete d_expert partials
        # combine only this rank's sequence chunk (token rows independent)
        idx = _tp_index(ctx)

        def chunk_rows(a):
            a = a.reshape((b, s_full) + a.shape[1:])
            a = jax.lax.dynamic_slice_in_dim(a, idx * s_in, s_in, 1)
            return a.reshape((b * s_in,) + a.shape[2:])
    else:
        def chunk_rows(a):
            return a

    if mode == "scatter":
        out = moe_lib._gather_from_buffers(part, chunk_rows(slot),
                                           chunk_rows(wts), dtype)
    else:
        out = jnp.einsum("nec,ecd->nd", chunk_rows(combine).astype(dtype),
                         part)
    if e.num_shared_experts:
        sh = jax.nn.silu(xf @ p["shared"]["gate"].astype(dtype)) * (
            xf @ p["shared"]["up"].astype(dtype))
        sh_part = sh @ p["shared"]["down"].astype(dtype)
        if ctx.tp is not None:
            # shared-expert width is rank-sharded: every rank computes its
            # partial for every token; ring reduce-scatter sums into chunks
            out = out + ring_reduce_scatter(
                ctx.tp, sh_part.reshape(b, s_full, d)).reshape(b * s_in, d)
        else:
            out = out + sh_part
    return out.reshape(b, s_in, d), aux


def ssm_block_ex(ctx: ParallelContext, p, x, cfg: ModelConfig, dtype,
                 plan: Optional[ParallelPlan] = None):
    """Mamba2 block for any placement. x: (B, L_loc, d) -> same shape.

    local: delegates to ``ssm_lib.ssm_block`` (also the decode-side oracle).
    tp: heads carry the model dim (PR 4 layout — in_proj ring-fused, B/C on
    the gathered copy, psum'd gated RMSNorm). cp: contiguous sequence
    chunks; causal convs take a (d_conv−1)-token halo from the left
    neighbour, the local chunk scans from a zero state through the usual
    dispatcher (fused kernel stays eligible), and the inter-rank recurrence
    closes in two rank-local einsums around :func:`cp_chain_state` — the
    carried-in state's contribution is linear, so it never re-runs the scan.
    """
    from repro.models import ssm as ssm_lib  # noqa: PLC0415 (import cycle)
    if ctx.tp is None and ctx.cp is None:
        return ssm_lib.ssm_block(p, x, cfg, dtype, plan=plan)

    s = cfg.ssm
    di, nh, g, n = ssm_lib.ssm_dims(cfg)
    tp = ctx.n_tp
    if tp > 1:
        assert g == 1 and nh % tp == 0 and di % tp == 0, (g, nh, di, tp)
    nh_l, di_l = nh // tp, di // tp
    b = x.shape[0]

    if ctx.tp is not None:
        (z, xin, dtp), xg = all_gather_matmul(
            ctx.tp, x, (p["wz"].astype(dtype), p["wx"].astype(dtype),
                        p["wdt"].astype(dtype)))
        Bv = xg @ p["wB"].astype(dtype)
        Cv = xg @ p["wC"].astype(dtype)
    else:
        z = x @ p["wz"].astype(dtype)
        xin = x @ p["wx"].astype(dtype)
        dtp = x @ p["wdt"].astype(dtype)
        Bv = x @ p["wB"].astype(dtype)
        Cv = x @ p["wC"].astype(dtype)
    l = xin.shape[1]                      # cp-local length (tp re-gathered)
    dt_bias = _slice_tp(ctx, p["dt_bias"], nh_l)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + dt_bias)   # (b, l, nh_l)

    conv_x = _slice_tp(ctx, p["conv_x"], di_l)
    if ctx.cp is not None and s.d_conv > 1:
        # causal convs need the previous rank's last K−1 positions: one halo
        # exchange for all three streams (concatenated channels). d_conv==1
        # needs no left context (and x[:, -0:] would ship the whole chunk).
        width = s.d_conv - 1
        halo = cp_halo_left(ctx, jnp.concatenate([xin, Bv, Cv], axis=-1),
                            width)
        lx, lB, lC = jnp.split(halo, [xin.shape[-1],
                                      xin.shape[-1] + Bv.shape[-1]], axis=-1)
    else:
        lx = lB = lC = None
    xin = jax.nn.silu(ssm_lib._causal_conv(xin, conv_x, dtype, left=lx))
    Bv = jax.nn.silu(ssm_lib._causal_conv(Bv, p["conv_B"], dtype, left=lB))
    Cv = jax.nn.silu(ssm_lib._causal_conv(Cv, p["conv_C"], dtype, left=lC))

    A = -jnp.exp(_slice_tp(ctx, p["A_log"], nh_l))
    xh = xin.reshape(b, l, nh_l, s.head_dim)
    Bm = Bv.reshape(b, l, g, n)
    Cm = Cv.reshape(b, l, g, n)
    y, _ = dispatch_ssd_scan(
        xh, dt, A, Bm, Cm, chunk=s.chunk,
        impl=plan.ssm_impl if plan is not None else "auto")

    if ctx.cp is not None:
        # inter-rank recurrence: local accumulated state + total decay chain
        # around the cp ring; the entering state's contribution to y is the
        # closed form C_t · exp(cumΣdA_t) · E (linear in E)
        hpg = nh_l // g
        dA = (dt * A).astype(jnp.float32)                    # (b, l, h)
        cum = jnp.cumsum(dA, axis=1)
        xd = (xh * dt[..., None]).astype(jnp.float32)
        Bf = Bm.astype(jnp.float32)
        Cf = Cm.astype(jnp.float32)
        tail = jnp.exp(cum[:, -1:, :] - cum)                 # Π_{k>t} decay
        s_loc_state = jnp.einsum(
            "btgn,btgh,btghp->bghpn", Bf,
            tail.reshape(b, l, g, hpg),
            xd.reshape(b, l, g, hpg, s.head_dim)).reshape(
                b, nh_l, s.head_dim, n)
        a_total = jnp.exp(cum[:, -1, :])                     # (b, h)
        e_in = cp_chain_state(ctx, s_loc_state, a_total)
        y = y + jnp.einsum(
            "btgn,bghpn,btgh->btghp", Cf,
            e_in.reshape(b, g, hpg, s.head_dim, n),
            jnp.exp(cum).reshape(b, l, g, hpg)).reshape(
                b, l, nh_l, s.head_dim)

    D = _slice_tp(ctx, p["D"], nh_l)
    y = y + xh.astype(jnp.float32) * D[None, None, :, None]
    y = y.reshape(b, l, di_l).astype(dtype)

    scale = _slice_tp(ctx, p["scale"], di_l)
    if ctx.tp is not None:
        # gated RMSNorm over the full (model-sharded) d_inner: per-rank sum
        # of squares + psum reproduces rms_norm's full-width mean
        yz = (y * jax.nn.silu(z)).astype(jnp.float32)
        ssq = jax.lax.psum(jnp.sum(jnp.square(yz), axis=-1, keepdims=True),
                           ctx.tp.axis)
        yn = ((yz * jax.lax.rsqrt(ssq / di + cfg.rms_eps))
              * (1.0 + scale.astype(jnp.float32))).astype(dtype)
    else:
        yn = rms_norm(y * jax.nn.silu(z), scale, cfg.rms_eps)
    return _proj_rows(ctx, yn, p["out_proj"].astype(dtype))


# ---------------------------------------------------------------------------
# layer builders (shared by loss fns, the pipeline stage_fn and families)


def decoder_layer(ctx: ParallelContext, cfg: ModelConfig, plan: ParallelPlan,
                  dtype, collect_kv: bool = False):
    """The one decoder-layer body (dense / MoE) for every placement."""
    alternating = bool(cfg.local_global_alternating and cfg.sliding_window)
    impl = plan.attn_impl if plan is not None else "auto"

    def layer(x, lp, window, positions):
        x = ctx.cx(x)
        h = rms_norm(x, lp["norm1"]["scale"], cfg.rms_eps)
        a = attn_block(ctx, lp["attn"], h, cfg, positions=positions,
                       window=window if alternating else cfg.sliding_window,
                       dtype=dtype, impl=impl, collect_kv=collect_kv)
        if collect_kv:
            a, kv = a
        a = checkpoint_name(a, "attn_out")
        if cfg.post_norm:
            a = rms_norm(a, lp["norm1_post"]["scale"], cfg.rms_eps)
        x = x + a
        h = rms_norm(x, lp["norm2"]["scale"], cfg.rms_eps)
        if cfg.family == Family.MOE:
            m, aux = moe_block_ex(ctx, lp["moe"], h, cfg, dtype, plan)
        else:
            m, aux = mlp_block_ex(ctx, lp["mlp"], h, dtype), jnp.float32(0.0)
        if cfg.post_norm:
            m = rms_norm(m, lp["norm2_post"]["scale"], cfg.rms_eps)
        if collect_kv:
            return x + m, aux, kv
        return x + m, aux
    return layer


def ssm_layer(ctx: ParallelContext, cfg: ModelConfig, plan: ParallelPlan,
              dtype):
    """The one Mamba2 layer body for every placement."""
    def layer(x, lp, window, positions):
        del window, positions
        x = ctx.cx(x)
        h = rms_norm(x, lp["norm1"]["scale"], cfg.rms_eps)
        y = ssm_block_ex(ctx, lp["ssm"], h, cfg, dtype, plan)
        y = checkpoint_name(y, "block_out")
        return x + y, jnp.float32(0.0)
    return layer


def layer_fn_for(ctx: ParallelContext, cfg: ModelConfig, plan: ParallelPlan,
                 dtype):
    if cfg.family == Family.SSM:
        return ssm_layer(ctx, cfg, plan, dtype)
    return decoder_layer(ctx, cfg, plan, dtype)


# ---------------------------------------------------------------------------
# context construction + whole-model loss


def check_cp_support(cfg: ModelConfig, plan: ParallelPlan, cp: int):
    """Static preconditions of the cp axis. Raises ValueError otherwise.
    (Shared family/pos_emb rules live next to the TP twin —
    ``tensor_parallel.decoder_only_support_errors`` — so the two explicit
    shard_map paths can't drift apart on what they accept.)"""
    from repro.train.tensor_parallel import (  # noqa: PLC0415
        decoder_only_support_errors)
    bad = decoder_only_support_errors(cfg)
    if bad:
        raise ValueError(f"cp={cp} unsupported here: " + "; ".join(bad))


def resolve_context(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh,
                    batch_axes: Tuple[str, ...]) -> ParallelContext:
    """Build the shard_map-interior ParallelContext for this plan/mesh."""
    from repro.train import tensor_parallel as tplib  # noqa: PLC0415
    tp = mesh.shape.get("model", 1)
    cp = mesh.shape.get("cp", 1) if plan.cp > 1 else 1
    # the tp rings need BOTH a 2-wide model axis and a plan that asked for
    # tensor parallelism (tp > 1, or an explicit tp_impl="overlap" — the old
    # make_tp_loss_fn contract). A cp-only plan on a mesh that happens to
    # carry a model axis must NOT grow unrequested 16-way TP (or trip
    # check_overlap_support's divisibility errors for it).
    use_tp = tp >= 2 and (plan.tp > 1 or plan.tp_impl == "overlap")
    if plan.tp_impl == "overlap" and not use_tp:
        raise ValueError(
            "tp_impl='overlap' was requested explicitly but the mesh has no "
            f"'model' axis of size >= 2 to run the rings on (got {mesh.shape})")
    if plan.cp > 1 and cp < plan.cp:
        raise ValueError(
            f"plan.cp={plan.cp} needs a 'cp' mesh axis of size {plan.cp}, "
            f"mesh has {mesh.shape}")
    use_ep = plan.ep > 1
    cp_axis = "cp"
    ep_ctx = None
    if use_ep:
        # MoE parallel folding: the expert ring re-reads the devices of the
        # resolved cp × model placement, so its size is pinned to theirs.
        if use_tp or cp > 1:
            fold_axes = (("cp",) if cp > 1 else ()) \
                + (("model",) if use_tp else ())
            fold = (cp if cp > 1 else 1) * (tp if use_tp else 1)
            if plan.ep != fold:
                raise ValueError(
                    f"plan.ep={plan.ep} must equal the folded cp×model ring "
                    f"size {fold} (mesh {dict(mesh.shape)}): the expert axis "
                    "re-maps those devices, it does not add any")
        else:
            # ep-only placement: experts ride the model axis and attention
            # runs as a cp ring over that same axis (sequence-sharded)
            if tp != plan.ep:
                raise ValueError(
                    f"plan.ep={plan.ep} needs a 'model' mesh axis of exactly "
                    f"that size to ride (mesh has {dict(mesh.shape)})")
            fold_axes = ("model",)
            cp, cp_axis = plan.ep, "model"
        ep_ctx = RingCtx(fold_axes if len(fold_axes) > 1 else fold_axes[0],
                         plan.ep)
    if use_tp:
        tplib.check_overlap_support(cfg, plan, tp)
    if cp > 1:
        check_cp_support(cfg, plan, cp)
    cp_impl = select_cp_impl(
        plan.cp_impl, family=cfg.family, window=cfg.sliding_window,
        local_global_alternating=bool(cfg.local_global_alternating
                                      and cfg.sliding_window)) \
        if cp > 1 else "ring"
    # the validate()-time twin of this warning only sees *explicit* knobs;
    # here the placement is actually resolved (tp_impl="auto" may have
    # landed on the rings), so re-flag the documented shard-local-routing
    # divergence against the real decision
    if use_tp or cp > 1 or use_ep:
        from repro.core.config import warn_shard_local_routing  # noqa: PLC0415
        warn_shard_local_routing(cfg)
    n_dp = 1
    for a in (batch_axes or ()):
        n_dp *= mesh.shape[a]
    return ParallelContext(
        tp=RingCtx("model", tp) if use_tp else None,
        cp=RingCtx(cp_axis, cp) if cp > 1 else None,
        cp_impl=cp_impl, ep=ep_ctx,
        ep_impl=select_ep_impl(plan.ep_impl),
        batch_axes=tuple(batch_axes or ()), n_dp=n_dp,
        mesh=mesh)


def executor_param_specs(params, cfg: ModelConfig, plan: ParallelPlan,
                         mesh: Mesh, ctx: ParallelContext):
    """shard_map in_specs for the executor loss: overlap column/row/vocab
    shards when the tp rings are on, fully replicated otherwise (cp shards
    the sequence, never the weights). Under EP the MoE leaves override to
    the folded layout (:func:`sharding.ep_spec_for_param` — routed experts
    expert-dim-sharded over the fold, shared experts/router replicated
    full-width); non-MoE leaves keep their tp/replicated classification, so
    attention and MoE genuinely use *different* mappings of the same
    devices."""
    if ctx.tp is not None:
        specs = shardlib.overlap_param_specs(params, cfg, plan, mesh)
    else:
        specs = jax.tree_util.tree_map(lambda _: P(), params)
    if ctx.ep is not None:
        def override(path, leaf, spec):
            ep_spec = shardlib.ep_spec_for_param(
                shardlib._path_names(path), tuple(leaf.shape), plan)
            return spec if ep_spec is None else ep_spec
        specs = jax.tree_util.tree_map_with_path(override, params, specs)
    return specs


def make_executor_loss_fn(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh,
                          batch_axes: Tuple[str, ...] = ("data",),
                          z_loss: float = 0.0):
    """loss_fn(params, batch) through the unified executor, for any tp × cp.

    The shard_map interior embeds, scans the one layer body per family,
    norms, and reduces the head: vocab-parallel (ring head GEMM +
    ``cross_entropy_vp``) when the tp rings are on, a local full-vocab head
    on sequence shards otherwise — per-position nll sums ``psum`` over
    data × cp and divide by the global token count either way. Ring-cp
    inputs are zigzag-permuted **outside** the shard_map (static
    permutation; every position-wise op is permutation-invariant).
    """
    from repro.models.families import (_embed, _layer_windows,  # noqa: PLC0415
                                       _logits, _remat)
    from repro.train.loss import cross_entropy  # noqa: PLC0415
    ctx = resolve_context(cfg, plan, mesh, batch_axes)
    if ctx.tp is None and ctx.cp is None:
        raise ValueError(
            "executor loss needs a 'model' mesh axis >= 2 (overlap TP) "
            "and/or plan.cp > 1 with a 'cp' mesh axis")
    if plan.dp_shard > 1:
        raise ValueError(
            "the executor loss (overlap TP / cp) expects dp_shard == 1: "
            "params enter the shard_map replicated over data, so FSDP-style "
            "param sharding would silently vanish instead of composing")
    cp, n_tp = ctx.n_cp, ctx.n_tp
    zigzag = ctx.cp is not None and ctx.cp_impl == "ring" \
        and cfg.family != Family.SSM
    dtype = jnp.dtype(plan.compute_dtype)
    windows_all = jnp.asarray(_layer_windows(cfg))
    baxes = batch_axes if batch_axes else None
    n_dp = ctx.n_dp
    layer = layer_fn_for(ctx, cfg, plan, dtype)

    def local_fn(params_l, tokens, labels):
        # tokens/labels: (B_loc, S/cp) — this cp rank's chunk, replicated
        # over model (the vocab-parallel embedding needs every position)
        b, s_loc = tokens.shape
        if n_tp > 1:
            assert s_loc % n_tp == 0, (s_loc, n_tp)
            x = tp_embed(params_l, tokens, cfg, dtype, ctx.tp)
        else:
            x = _embed(params_l, tokens, cfg, dtype)
        positions = cp_local_positions(ctx, s_loc)

        def body(carry, xs):
            xc, aux = carry
            lp, w = xs
            xn, a = layer(xc, lp, w, positions)
            return (xn, aux + a), None

        body = _remat(body, plan.remat)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((1,), jnp.float32)),
            (params_l["layers"], windows_all))
        x = rms_norm(x, params_l["final_norm"]["scale"], cfg.rms_eps)
        if n_tp > 1:
            nll = tp_head_nll(params_l, x, labels, cfg, ctx.tp, dtype, z_loss)
        else:
            logits = _logits(params_l, x, cfg, dtype)
            nll = cross_entropy(logits, labels, z_loss=z_loss,
                                reduction="none")
        tot = nll.sum()
        red_axes = tuple(batch_axes or ())
        if ctx.cp is not None:
            red_axes = red_axes + (ctx.cp.axis,)
        if red_axes:
            tot = jax.lax.psum(tot, red_axes)
        loss = tot / (b * n_dp * s_loc * cp)
        return jnp.stack([loss, aux[0]])

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if zigzag:
            perm = zigzag_permutation(tokens.shape[1], cp)
            tokens, labels = tokens[:, perm], labels[:, perm]
        if ctx.cp is not None:
            assert tokens.shape[1] % (2 * cp if zigzag else cp) == 0, \
                (tokens.shape, cp)
        pspecs = executor_param_specs(params, cfg, plan, mesh, ctx)
        seq_ax = ctx.cp.axis if ctx.cp is not None else None
        v = shard_map(
            local_fn, mesh=mesh,
            in_specs=(pspecs, P(baxes, seq_ax), P(baxes, seq_ax)),
            out_specs=P(),
        )(params, tokens, labels)
        loss, aux = v[0], v[1]
        return loss + aux, {"xent": loss, "moe_aux": aux}

    return loss_fn
