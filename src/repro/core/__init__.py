"""Core library: the survey's technique taxonomy as composable JAX features.

- config.py    ModelConfig / ParallelPlan / assigned input shapes
- sharding.py  GSPMD sharding-rule engine (TP / FSDP-factor / EP / vocab / ZeRO)
- registry.py  ``--arch <id>`` resolution for the 10 assigned architectures
"""

from .config import (
    Family,
    InputShape,
    INPUT_SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    MoEConfig,
    ParallelPlan,
    RecoveryPolicy,
    SSMConfig,
)
from .registry import ARCH_IDS, all_configs, get_config, get_smoke_config, register
from . import sharding

__all__ = [
    "Family",
    "InputShape",
    "INPUT_SHAPES",
    "SHAPES_BY_NAME",
    "ModelConfig",
    "MoEConfig",
    "ParallelPlan",
    "RecoveryPolicy",
    "SSMConfig",
    "ARCH_IDS",
    "all_configs",
    "get_config",
    "get_smoke_config",
    "register",
    "sharding",
]
