"""Sharding-rule engine: maps parameter pytree paths -> PartitionSpec.

GSPMD-style (survey §4.2.1): parameters get explicit layout annotations; activation
layouts are propagated by XLA from a handful of strategic constraints. The rules
implement the survey's hybrid-parallelism taxonomy:

- Tensor parallelism (§4.1.2, Megatron 1-D): "column" params shard their output dim
  on the ``model`` axis, "row" params their input dim.
- Data-parallel parameter sharding factor F (§4.1.1): F=1 replication,
  F=data-axis-size full sharding (ZeRO-3/FSDP); an extra ``data`` annotation is
  placed on the largest un-sharded dim.
- Expert parallelism (§4.1.5): expert-stacked params shard the expert dim
  over the *folded* expert ring (:func:`ep_fold_axes` — the cp × model axes
  the MoE sublayer re-reads as one flat ring of ``plan.ep`` slots, MoE
  parallel folding) instead of the hidden dim; shared experts and the router
  replicate over those axes because each fold rank routes its own sequence
  shard (:func:`ep_spec_for_param` is the executor/pipeline override).
- Vocab parallelism: embedding/LM head shard the vocab dim on ``model`` when
  divisible, else fall back to hidden-dim sharding (e.g. whisper's 51865 vocab).

All rules check divisibility: GSPMD would pad uneven shards, but padded layouts
waste FLOPs and skew the roofline, so non-divisible dims stay replicated and the
hillclimb loop (§Perf) reconsiders them explicitly.

Two tensor-parallel execution modes consume these rules
(``ParallelPlan.tp_impl``):

- ``"gspmd"`` (annotation-only): :func:`param_specs` layouts + a handful of
  activation constraints; XLA's partitioner inserts a blocking all-reduce
  after every row GEMM and keeps (B, S, d) activations replicated between
  blocks.
- ``"overlap"`` (``train/tensor_parallel.py``): the same column/row/vocab
  classification feeds :func:`overlap_param_specs`, the in_specs of an
  explicit ``shard_map``. There the all-gather/reduce-scatter pair of each
  column/row GEMM is decomposed into ``ppermute`` ring steps interleaved with
  partial GEMM tiles, and activations stay **sequence-sharded**
  ``(batch, seq/tp, d)`` between blocks (Megatron-SP, survey §4.1.4) — see
  :func:`seq_activation_spec`. RMSNorm, residual adds and the embedding
  lookup run on sequence shards; the full sequence is only re-materialized
  inside a block, fused into the first GEMM's ring ticks.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig, ParallelPlan

AxisName = Optional[str]


# Leaf-name classification (see models/* for the naming convention).
# wB/wC (SSM state projections) are deliberately NOT column-sharded: sharding
# the tiny state dim would force psum-per-contraction inside the SSD scan;
# heads (via wz/wx/wdt) carry the model-parallel dim instead.
_COL_KEYS = {"wq", "wk", "wv", "gate", "up", "wz", "wx", "wdt"}
_ROW_KEYS = {"wo", "down", "out_proj"}
_REPLICATED_KEYS = {"scale", "bias", "A_log", "D", "dt_bias", "bq", "bk", "bv",
                    "wB", "wC"}
_CONV_KEYS = {"conv_x", "conv_B", "conv_C"}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for entry in path:
        if isinstance(entry, jax.tree_util.DictKey):
            names.append(str(entry.key))
        elif isinstance(entry, jax.tree_util.GetAttrKey):
            names.append(entry.name)
        else:
            names.append(str(entry))
    return tuple(names)


def _divisible(size: int, mesh: Mesh, axis: str) -> bool:
    return (axis in mesh.shape and mesh.shape[axis] > 1
            and size % mesh.shape[axis] == 0)


def _tp_ok(size: int, mesh: Mesh, plan: ParallelPlan) -> bool:
    """Model-axis (TP) sharding is available unless the dp_over_model remap
    reassigned that axis to data parallelism."""
    return (not plan.dp_over_model) and _divisible(size, mesh, "model")


def _dp_axes(mesh: Mesh, plan: ParallelPlan):
    """Axes carrying data parallelism for parameter/optimizer sharding."""
    axes = ["data"] if "data" in mesh.shape else []
    if plan.dp_over_model and "model" in mesh.shape:
        axes.append("model")
    return tuple(axes)


def _add_fsdp(spec: list, shape: Tuple[int, ...], mesh: Mesh, plan: ParallelPlan) -> None:
    """Annotate the largest still-replicated dim with the DP axes (ZeRO-3/FSDP).
    Under the dp_over_model remap the DP domain is ("data", "model")."""
    if plan.dp_shard <= 1:
        return
    axes = _dp_axes(mesh, plan)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if n <= 1:
        return
    candidates = [
        (shape[i], i) for i in range(len(shape))
        if spec[i] is None and shape[i] % n == 0 and shape[i] > 1
    ]
    if candidates:
        _, idx = max(candidates)
        spec[idx] = axes if len(axes) > 1 else axes[0]


def spec_for_param(
    path_names: Tuple[str, ...],
    shape: Tuple[int, ...],
    cfg: ModelConfig,
    plan: ParallelPlan,
    mesh: Mesh,
) -> P:
    name = path_names[-1]
    stacked = "layers" in path_names            # leading layer-stack dim
    is_expert = "experts" in path_names         # (L, E, ...) expert-stacked

    spec: list = [None] * len(shape)

    if name in _REPLICATED_KEYS or name in _CONV_KEYS:
        # Small tensors: replicate over model axis; FSDP may still slice them.
        _add_fsdp(spec, shape, mesh, plan)
        return P(*spec)

    if name == "tok" or (name == "w" and "lm_head" in path_names):
        # Embedding (V, d) / LM head (d, V): vocab-parallel when divisible.
        vdim = 0 if name == "tok" else 1
        ddim = 1 - vdim
        if _tp_ok(shape[vdim], mesh, plan):
            spec[vdim] = "model"
        elif _tp_ok(shape[ddim], mesh, plan):
            spec[ddim] = "model"
        _add_fsdp(spec, shape, mesh, plan)
        return P(*spec)

    if name == "router":
        # (L?, d, E): replicate over model (tiny); FSDP on d.
        _add_fsdp(spec, shape, mesh, plan)
        return P(*spec)

    if is_expert:
        # (L, E, d, de) or (L, E, de, d)
        e_dim = 1 if stacked else 0
        axes = ep_fold_axes(plan)
        n_fold = 1
        for a in axes:
            n_fold *= mesh.shape.get(a, 0)
        if axes and n_fold > 0 and shape[e_dim] % n_fold == 0:
            # expert dim over the folded expert ring (MoE parallel folding)
            spec[e_dim] = axes if len(axes) > 1 else axes[0]
        else:
            # tensor-parallel inside each expert: shard the d_expert dim
            de_dim = len(shape) - 2 if name in _ROW_KEYS else len(shape) - 1
            if _tp_ok(shape[de_dim], mesh, plan):
                spec[de_dim] = "model"
        _add_fsdp(spec, shape, mesh, plan)
        return P(*spec)

    # tensor parallelism follows the mesh: shard whenever a model axis exists
    # and divides (plan.tp is informational; the mesh is the source of truth)
    if name in _COL_KEYS:
        out_dim = len(shape) - 1
        if _tp_ok(shape[out_dim], mesh, plan):
            spec[out_dim] = "model"
        _add_fsdp(spec, shape, mesh, plan)
        return P(*spec)

    if name in _ROW_KEYS:
        in_dim = len(shape) - 2
        if _tp_ok(shape[in_dim], mesh, plan):
            spec[in_dim] = "model"
        _add_fsdp(spec, shape, mesh, plan)
        return P(*spec)

    # Unknown leaf: replicate (safe), FSDP if large.
    _add_fsdp(spec, shape, mesh, plan)
    return P(*spec)


def param_specs(params: Any, cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs too)."""
    def one(path, leaf):
        return spec_for_param(_path_names(path), tuple(leaf.shape), cfg, plan, mesh)
    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, cfg, plan, mesh)
    )


# ---------------------------------------------------------------------------
# Expert parallelism (folded expert ring, survey §4.1.5)


def ep_fold_axes(plan: ParallelPlan) -> Tuple[str, ...]:
    """The mesh axes the expert ring folds onto (MoE parallel folding).

    ``plan.ep`` ranks re-read the devices of the existing cp × model ring as
    one flat expert axis: ("cp", "model") when both are engaged, just one of
    them when only it is, and ("model",) in the ep-only placement (tp == cp
    == 1 — experts ride the model axis and attention runs as a cp ring over
    it). Empty tuple when EP is off."""
    if plan.ep <= 1:
        return ()
    axes = ("cp",) if plan.cp > 1 else ()
    if plan.tp > 1 or plan.cp <= 1:
        axes = axes + ("model",)
    return axes


def ep_spec_for_param(path_names: Tuple[str, ...], shape: Tuple[int, ...],
                      plan: ParallelPlan) -> Optional[P]:
    """EP override for one leaf entering the executor/pipeline ``shard_map``.

    Returns the spec EP imposes, or ``None`` when the leaf is not
    EP-affected (the caller falls through to its tp/overlap classification).
    This is the single source of truth three consumers share — the executor
    in_specs, the pipeline's per-stage param specs, and the pipeline's
    grad-finish psum logic:

    - routed experts ((L?, E, ...) with "experts" in the path): the expert
      dim shards over :func:`ep_fold_axes`; the d_expert dim stays full, so
      each fold rank holds complete experts and its expert-grad shard needs
      **no** cp/model psum;
    - shared experts and the router: replicated *full-width* over the fold
      axes — every fold rank routes its own sequence shard, so there is no
      width-partial psum to complete them; their grads **do** psum over the
      fold axes.
    """
    axes = ep_fold_axes(plan)
    if not axes:
        return None
    if "experts" in path_names:
        e_dim = 1 if "layers" in path_names else 0
        spec: list = [None] * len(shape)
        spec[e_dim] = axes if len(axes) > 1 else axes[0]
        return P(*spec)
    if "shared" in path_names or path_names[-1] == "router":
        return P(*([None] * len(shape)))
    return None


# ---------------------------------------------------------------------------
# Overlap-TP (shard_map ring path) parameter specs


def overlap_spec_for_param(path_names: Tuple[str, ...],
                           shape: Tuple[int, ...], cfg: ModelConfig) -> P:
    """Spec for one leaf entering the overlap-TP ``shard_map``.

    Same column/row/vocab classification as :func:`spec_for_param`, but:

    - always ``model``-sharded on the classified dim (the ring path validates
      divisibility up front — ``tensor_parallel.check_overlap_support`` —
      instead of silently replicating);
    - never FSDP-annotated (params enter the shard_map replicated over
      ``data``; ZeRO handles optimizer sharding outside the loss);
    - the embedding is always vocab-sharded: the ring path does the Megatron
      masked-lookup + psum, so no hidden-dim fallback exists;
    - small SSM per-head/per-channel leaves (A_log, D, dt_bias, conv_*,
      scale) stay replicated — the executor's ``ssm_block_ex``
      (train/executor.py) slices each rank's head/channel chunk explicitly.
    """
    name = path_names[-1]
    spec: list = [None] * len(shape)
    if name == "tok" or (name == "w" and "lm_head" in path_names):
        spec[0 if name == "tok" else 1] = "model"
    elif "experts" in path_names and name in ("gate", "up"):
        spec[-1] = "model"                      # (L?, E, d, de): shard d_expert
    elif "experts" in path_names and name == "down":
        spec[-2] = "model"
    elif name in _COL_KEYS:
        spec[-1] = "model"
    elif name in _ROW_KEYS:
        spec[-2] = "model"
    return P(*spec)


def overlap_param_specs(params: Any, cfg: ModelConfig, plan: ParallelPlan,
                        mesh: Mesh) -> Any:
    """PartitionSpec pytree for ``shard_map`` in_specs on the overlap-TP path."""
    del plan, mesh  # classification is static; callers validated divisibility
    def one(path, leaf):
        return overlap_spec_for_param(_path_names(path), tuple(leaf.shape), cfg)
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Activation / batch specs


def batch_axes(mesh: Mesh, plan: ParallelPlan) -> Tuple[str, ...]:
    """Mesh axes the global batch is split over."""
    axes = []
    if "pod" in mesh.shape and plan.pp == 1:
        axes.append("pod")
    axes.append("data")
    return tuple(axes)


def data_spec(mesh: Mesh, plan: ParallelPlan, ndim: int = 2) -> P:
    """Spec for (batch, seq, ...) token arrays."""
    return P(batch_axes(mesh, plan), *([None] * (ndim - 1)))


def activation_spec(mesh: Mesh, plan: ParallelPlan) -> P:
    """(batch, seq, d_model) residual-stream constraint."""
    return P(batch_axes(mesh, plan), None, None)


def seq_activation_spec(mesh: Mesh, plan: ParallelPlan) -> P:
    """(batch, seq/tp, d_model) sequence-sharded residual stream — the
    between-blocks layout of the overlap-TP path (Megatron-SP, §4.1.4)."""
    return P(batch_axes(mesh, plan), "model", None)


def cp_activation_spec(mesh: Mesh, plan: ParallelPlan) -> P:
    """(batch, seq/(cp·tp), d_model) residual stream under context
    parallelism (``plan.cp > 1``, survey §4.1.4): the sequence dim carries
    the "cp" axis end to end — and composes with the overlap-TP "model"
    sharding when both are on — so no device ever holds the full context.
    The block executor (train/executor.py) owns the in-block placement
    (ring/gathered attention, SSD state chain, shard-local MoE routing)."""
    seq_axes = ("cp", "model") if (plan.tp > 1 and "model" in mesh.shape) \
        else "cp"
    return P(batch_axes(mesh, plan), seq_axes, None)


def kv_cache_spec(mesh: Mesh, plan: ParallelPlan, seq_sharded: bool = True) -> P:
    """(batch, seq, kv_heads, head_dim) decode cache: batch@data, seq@model."""
    model = "model" if (seq_sharded and plan.seq_shard_decode) else None
    return P(batch_axes(mesh, plan), model, None, None)


def logits_spec(cfg: ModelConfig, mesh: Mesh, plan: ParallelPlan) -> P:
    vocab_axis = "model" if cfg.vocab % mesh.shape.get("model", 1) == 0 else None
    return P(batch_axes(mesh, plan), None, vocab_axis)


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Decode-cache sharding (DESIGN.md §3: (batch@data, seq@model, heads, hd))

_KV_CACHE_KEYS = {"k", "v", "attn_k", "attn_v"}
_CROSS_CACHE_KEYS = {"cross_k", "cross_v"}


def cache_specs(cache: Any, plan: ParallelPlan, mesh: Mesh,
                batch_axes: Tuple[str, ...]) -> Any:
    """Spec tree for a decode cache (leaves are layer-stacked: (L, B, ...))."""
    baxes = batch_axes if batch_axes else None
    model_free = "model" not in (batch_axes or ())

    def one(path, leaf):
        name = _path_names(path)[-1]
        shape = tuple(leaf.shape)
        bdim = 1                                     # (L, B, ...)
        spec = [None] * len(shape)
        if baxes:
            spec[bdim] = baxes
        if name in _KV_CACHE_KEYS:
            # (L, B, T, H, hd): shard T on model if enabled & divisible
            if (model_free and plan.seq_shard_decode
                    and _divisible(shape[2], mesh, "model")):
                spec[2] = "model"
        elif name in _CROSS_CACHE_KEYS:
            pass                                     # enc_frames rarely divisible
        elif name == "state":
            # SSM state (L, B, nh, hp, n): shard heads on model
            if model_free and _divisible(shape[2], mesh, "model"):
                spec[2] = "model"
        elif name.startswith("conv_"):
            # (L, B, K-1, C): shard channels on model
            if model_free and _divisible(shape[-1], mesh, "model"):
                spec[-1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)


# ---------------------------------------------------------------------------
# Optimizer-state sharding (ZeRO, survey §6.2)


def opt_state_specs(pspecs: Any, params: Any, plan: ParallelPlan, mesh: Mesh) -> Any:
    """Specs for per-param optimizer moments.

    zero_stage >= 1 shards moments over ``data`` even when params are replicated
    (ZeRO-1): take the param spec and add ``data`` on the largest free dim.
    """
    if plan.zero_stage == 0:
        return pspecs

    def one(spec: P, p) -> P:
        parts = list(spec) + [None] * (len(p.shape) - len(spec))
        if any(ax == "data" or (isinstance(ax, tuple) and "data" in ax) for ax in parts):
            return spec  # already data-sharded (FSDP)
        cands = [
            (p.shape[i], i) for i in range(len(p.shape))
            if parts[i] is None and _divisible(p.shape[i], mesh, "data") and p.shape[i] > 1
        ]
        if not cands:
            return spec
        _, idx = max(cands)
        parts[idx] = "data"
        return P(*parts)

    return jax.tree_util.tree_map(one, pspecs, params)


def train_state_specs(state: Any, cfg: ModelConfig, plan: ParallelPlan,
                      mesh: Mesh) -> Any:
    """PartitionSpec pytree for a whole ``train.TrainState`` (params + AdamW
    moments), matching what the jitted step's sharding constraints produce.

    This is the layout contract an elastic restore re-slices onto: params get
    :func:`param_specs`, the fp32 moments get :func:`opt_state_specs` (ZeRO-1
    scatters them over ``data``), the step counter replicates. Duck-typed on
    the NamedTuple shape ``state.params`` / ``state.opt.{step, mu, nu}`` so
    core stays import-independent of the train layer.
    """
    pspecs = param_specs(state.params, cfg, plan, mesh)
    ospecs = opt_state_specs(pspecs, state.params, plan, mesh)
    return state._replace(
        params=pspecs,
        opt=state.opt._replace(step=P(), mu=ospecs, nu=ospecs))


def train_state_shardings(state: Any, cfg: ModelConfig, plan: ParallelPlan,
                          mesh: Mesh) -> Any:
    """:func:`train_state_specs` as concrete ``NamedSharding``s — the
    ``shardings`` argument of ``CheckpointManager.restore_resharded``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        train_state_specs(state, cfg, plan, mesh),
        is_leaf=lambda x: isinstance(x, P))


def bytes_per_device(params: Any, shardings: Any) -> int:
    """Analytic parameter bytes resident per device under the given shardings."""
    total = 0
    for p, s in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(shardings)):
        n_shards = 1
        spec = s.spec if isinstance(s, NamedSharding) else s
        mesh = s.mesh if isinstance(s, NamedSharding) else None
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n_shards *= mesh.shape[a] if mesh else 1
        total += int(np.prod(p.shape)) * p.dtype.itemsize // max(n_shards, 1)
    return total
