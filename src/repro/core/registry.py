"""Architecture registry: ``--arch <id>`` resolution.

Each ``repro/configs/<id>.py`` module registers its :class:`ModelConfig` (full
production config) and a ``smoke()`` reduced variant at import time.
"""

from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from .config import ModelConfig

_FULL: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}

ARCH_IDS: List[str] = [
    "pixtral-12b",
    "olmoe-1b-7b",
    "qwen2.5-14b",
    "zamba2-1.2b",
    "codeqwen1.5-7b",
    "gemma2-9b",
    "whisper-small",
    "deepseek-moe-16b",
    "mamba2-370m",
    "qwen1.5-4b",
]


def register(cfg: ModelConfig, smoke: Callable[[], ModelConfig]) -> ModelConfig:
    _FULL[cfg.arch_id] = cfg
    _SMOKE[cfg.arch_id] = smoke
    return cfg


def _ensure_loaded(arch_id: str) -> None:
    if arch_id not in _FULL:
        mod = arch_id.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded(arch_id)
    return _FULL[arch_id]


def get_smoke_config(arch_id: str) -> ModelConfig:
    _ensure_loaded(arch_id)
    return _SMOKE[arch_id]()


def all_configs() -> Dict[str, ModelConfig]:
    for a in ARCH_IDS:
        _ensure_loaded(a)
    return dict(_FULL)
