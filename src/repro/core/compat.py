"""Compatibility shims for jax API drift across the supported version range.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg (``check_rep`` -> ``check_vma``) along
the way. Every call site in this repo goes through :func:`shard_map` so the
rest of the codebase is version-agnostic.
"""

from __future__ import annotations

import inspect

try:  # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# the top-level promotion and the kwarg rename (check_rep -> check_vma) were
# separate jax changes — key off the resolved signature, not the import path
try:
    _PARAMS = inspect.signature(_shard_map).parameters
except (TypeError, ValueError):  # signature not introspectable
    _PARAMS = {}
_CHECK_KW = "check_vma" if "check_vma" in _PARAMS else "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs):
    # The replication check stays off in both eras: 0.4.x's check_rep has no
    # rule for the `name` (checkpoint_name) primitive, and 0.6+'s check_vma
    # is stricter than these specs are annotated for. With the check off,
    # grad-of-shard_map additionally requires scan carries to be non-scalar
    # (see train/pipeline.py) — scalar residuals can't be spec'd per-device.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})
