"""Configuration system.

Two layers of configuration:

- :class:`ModelConfig` — architecture hyperparameters (one instance per assigned
  architecture lives in ``repro/configs/<arch>.py``).
- :class:`ParallelPlan` — how the model is laid out on the mesh, following the
  survey's taxonomy (§4.1): DP sharding factor, tensor parallelism, context
  (sequence) parallelism, expert parallelism, optimizer-state (ZeRO-1)
  sharding, pipeline stages, remat policy.

Everything is a frozen dataclass so configs hash and can key jit caches.

Parallel-composition knobs (survey §4.1) beyond tp/cp/pp at a glance:

====================================  =======================================
knob                                  meaning
====================================  =======================================
``ParallelPlan.ep``                   expert-parallel degree: MoE expert dim
                                      sharded over ``ep`` ranks, folded onto
                                      the cp × model device ring (MoE
                                      parallel folding) — attention keeps its
                                      cp/tp mapping, the MoE sublayer re-reads
                                      the same devices as one flat expert
                                      ring, so ``ep == cp·tp`` when either is
                                      > 1 (ep-only runs over ``model`` with
                                      attention as a cp ring). Executor-only.
``ParallelPlan.ep_impl``              ``auto`` | ``blocking`` | ``overlap``:
                                      how EP dispatch/combine all-to-alls
                                      execute. ``blocking`` = one
                                      ``lax.all_to_all`` each side (exposed);
                                      ``overlap`` = ppermute ring ticks
                                      interleaved with per-peer expert-GEMM
                                      chunks, custom-VJP reversed-ring
                                      backward; ``auto`` = overlap
====================================  =======================================

Robustness knobs (survey §8) at a glance:

====================================  =======================================
knob                                  meaning
====================================  =======================================
``ParallelPlan.integrity``            ``off`` | ``audit``: per-step uint32
                                      param/grad checksum cross-checked
                                      across replicas → ``sdc`` anomaly
``RecoveryPolicy.sdc``                action on checksum divergence
                                      (default ``rollback``)
``RecoveryPolicy.ckpt_io``            action on exhausted persist retries
                                      (default ``ignore``)
``CheckpointManager(keep=K)``         keep-last-K GC; corrupt checkpoints are
                                      skipped on restore, so K > 1 is the
                                      fallback budget
``CheckpointManager(io_retries=N,     persist-write retry loop: N attempts,
  io_backoff=s, io_timeout=T)``       exponential backoff starting at ``s``
                                      seconds, cumulative deadline ``T``
``RecoveryPolicy.ckpt_memory_keep``   hot in-memory checkpoint tier: RAM ring
                                      of the last K snapshots restored
                                      *before* any disk walk (0 disables;
                                      ``--ckpt-memory-keep``)
``RecoveryPolicy.peer_redundancy``    mirror each host-group's RAM shards
                                      onto its ring neighbor so one lost
                                      group rebuilds from surviving peers
                                      (``--no-peer-redundancy`` to disable)
``RecoveryPolicy.preempt_grace``      seconds of grace after SIGTERM/SIGUSR1
                                      for the just-in-time snapshot; tier
                                      picked from measured persist time
                                      (``--preempt-grace``)
``RecoveryPolicy.flight_len``         crash flight recorder: ring capacity
                                      of per-step events dumped to JSON on
                                      preemption/crash/RecoveryExhausted
                                      (``--flight-len``, ``--flight-path``)
``ParallelPlan.pp_layout``            uneven layers-per-stage pipeline
                                      partition (Malleus-style, survey §8.1):
                                      tuple summing to ``n_layers``; ``None``
                                      = even split. A ``pp_layout`` change is
                                      a *reshard*, not a refusal, so the
                                      straggler rebalance restarts through
                                      the elastic checkpoint path
``RecoveryPolicy.straggler``          action on a fail-slow attribution from
                                      ``ft/straggler`` (default ``ignore``;
                                      the ladder is ignore → ``rebalance``
                                      (re-partition ``pp_layout`` from
                                      measured per-stage times) → ``remesh``;
                                      ``--on-straggler``)
``RecoveryPolicy.straggler_factor``   relative slowdown threshold: a rank is
                                      slow when its section time exceeds
                                      ``factor ×`` its peers' median (or its
                                      own trailing median for global
                                      sections) (``--straggler-factor``)
``RecoveryPolicy.straggler_window``   sliding window (observations) of
                                      per-(section, rank) timings kept by
                                      the detector (``--straggler-window``)
``RecoveryPolicy.straggler_confirm``  consecutive slow observations before a
                                      ``straggler`` anomaly is raised — the
                                      detection latency in steps
                                      (``--straggler-confirm``)
``RecoveryPolicy.straggler_min_seconds``  absolute slowdown floor; below it
                                      the relative test never fires
                                      (scheduler jitter guard)
====================================  =======================================
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple


class Family:
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    AUDIO = "audio"   # encoder-decoder with audio-frame frontend stub
    VLM = "vlm"       # decoder with vision-patch frontend stub


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size (fine-grained MoE)
    num_shared_experts: int = 0   # DeepSeek-MoE style always-on experts
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    pos_emb: str = "rope"         # "rope" | "sinusoidal" (whisper)
    rope_theta: float = 1e4
    rms_eps: float = 1e-5
    tie_embeddings: bool = False

    # gemma2-style features
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    sliding_window: int = 0       # 0 -> full attention
    local_global_alternating: bool = False  # even layers local (sliding), odd global
    long_context: bool = False    # beyond-paper: force all layers sliding-window
    post_norm: bool = False       # gemma2 post-sub-block RMSNorms
    scale_embed: bool = False     # gemma: embeddings scaled by sqrt(d_model)

    # family extras
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (zamba2): apply a weight-shared attention block every k ssm layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_frames: int = 1500        # audio frontend stub: frame-embedding count

    # vlm (pixtral)
    vision_tokens: int = 0        # patch-embedding count supplied by frontend stub

    # citation: source paper / model card for this config
    source: str = ""

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_enc_dec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == Family.SSM

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (see DESIGN.md §4)."""
        if self.family in (Family.SSM, Family.HYBRID):
            return True
        return bool(self.sliding_window) and self.long_context

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.head_dim
        total = V * d                       # embedding
        if not self.tie_embeddings:
            total += V * d                  # lm head

        def attn_params() -> int:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return q + kv + o + b

        def mlp_params(dff: int) -> int:
            return 3 * d * dff              # SwiGLU: gate, up, down

        def ssm_params() -> int:
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            ng, ns = self.ssm.n_groups, self.ssm.d_state
            in_proj = d * (2 * di + 2 * ng * ns + nh)
            conv = (di + 2 * ng * ns) * self.ssm.d_conv
            out = di * d
            return in_proj + conv + out + 2 * nh  # + A_log, D

        if self.family == Family.SSM:
            total += L * (ssm_params() + d)
        elif self.family == Family.HYBRID:
            total += L * (ssm_params() + d)
            if self.shared_attn_every:
                total += attn_params() + 2 * d  # one shared block
        elif self.family == Family.MOE:
            per_layer = attn_params() + 2 * d
            e = self.moe
            per_layer += d * e.num_experts                       # router
            per_layer += e.num_experts * 3 * d * e.d_expert      # routed experts
            per_layer += e.num_shared_experts * 3 * d * e.d_expert
            total += L * per_layer
        else:  # dense / vlm decoder / audio
            total += L * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            if self.is_enc_dec:
                # encoder layers + decoder cross-attention
                total += self.enc_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
                total += L * (attn_params() + d)
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k + shared experts)."""
        if self.family != Family.MOE:
            return self.param_count()
        e = self.moe
        d, L = self.d_model, self.n_layers
        dense_like = self.param_count()
        inactive = L * (e.num_experts - e.top_k) * 3 * d * e.d_expert
        return dense_like - inactive


def warn_shard_local_routing(cfg: "ModelConfig") -> None:
    """Warn when shard-local MoE routing can drop tokens differently from
    the global-routing GSPMD baseline (the one documented divergence of the
    overlap-TP / cp paths). No-op for non-MoE or no-drop capacity."""
    if cfg.moe is None:
        return
    if cfg.moe.capacity_factor * cfg.moe.top_k >= cfg.moe.num_experts:
        return
    warnings.warn(
        "token-dropping capacity under shard-local MoE routing "
        f"(capacity_factor={cfg.moe.capacity_factor} < "
        f"E/top_k={cfg.moe.num_experts / cfg.moe.top_k:g}): drop decisions "
        "are per data/context shard and may diverge from the global-routing "
        "GSPMD baseline; use capacity_factor >= E/top_k for exact "
        "equivalence", UserWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Distribution strategy per survey §4.

    Axis semantics (see DESIGN.md §3): ``model`` = TP/EP/sequence, ``data`` = DP,
    ``pod`` = DP (default) or pipeline stages.
    """
    tp: int = 1                    # tensor-parallel degree (model axis)
    tp_impl: str = "auto"          # "auto" | "gspmd" | "overlap": how model-axis
                                   # tensor parallelism executes (survey §4.1.2,
                                   # §5.2). "gspmd" annotates layouts and lets
                                   # XLA insert the (blocking) all-reduce after
                                   # every row GEMM. "overlap" is the explicit
                                   # shard_map path (train/tensor_parallel.py):
                                   # collective matmuls decompose the column
                                   # GEMM's all-gather and the row GEMM's
                                   # reduce-scatter into ppermute ring steps
                                   # interleaved with partial GEMM tiles, and
                                   # activations stay sequence-sharded
                                   # (batch, seq/tp) between blocks (Megatron-
                                   # SP). "auto" resolves per backend in
                                   # repro.kernels.dispatch.select_tp_impl:
                                   # overlap on TPU (where the async ppermutes
                                   # actually hide the transfer), gspmd
                                   # elsewhere.
    cp: int = 1                    # context-parallel degree (survey §4.1.4):
                                   # shard the *sequence* dim over a dedicated
                                   # "cp" mesh axis, end to end — the residual
                                   # stream between blocks is
                                   # (batch, seq/(cp·tp), d) and no device
                                   # ever holds the full context. The block
                                   # executor (train/executor.py) owns the
                                   # wiring: attention runs ring or gathered
                                   # KV (``cp_impl``), the Mamba2 SSD scan
                                   # passes per-chunk entering states around
                                   # the cp ring, MoE routes on local
                                   # sequence shards with batch-global aux.
    cp_impl: str = "auto"          # "auto" | "gather" | "ring": how cp
                                   # attention executes. "gather" all-gathers
                                   # K/V over the cp axis (contiguous chunks,
                                   # O(S) KV per device, exact). "ring" keeps
                                   # KV sharded and ppermutes chunks around
                                   # the ring with zigzag causal load
                                   # balancing — the flash kernel runs as the
                                   # inner tile and per-chunk (out, lse)
                                   # partials merge exactly (chunked
                                   # softmax), so attention activation
                                   # memory scales with S/cp. "auto" =
                                   # ring when statically eligible (full
                                   # causal attention), gather otherwise;
                                   # resolved by
                                   # repro.kernels.dispatch.select_cp_impl.
    dp_shard: int = 1              # param sharding factor F over data axis (§4.1.1)
    zero_stage: int = 1            # 0: replicated opt state, 1: shard over data axis
    ep: int = 1                    # expert-parallel degree (survey §4.1.5):
                                   # shard the *expert* dim of MoE layers over
                                   # ``ep`` ranks and exchange token buffers
                                   # with dispatch/combine all-to-alls. The
                                   # expert axis is *folded* onto the existing
                                   # cp × model device ring (MoE parallel
                                   # folding, Megatron-Core arXiv 2504.14960):
                                   # attention keeps its cp/tp mapping while
                                   # the MoE sublayer re-reads the same
                                   # devices as one flat expert ring, so
                                   # ``ep`` must equal cp·tp when either is
                                   # > 1. With tp == cp == 1, ``ep`` ranks
                                   # run on the ``model`` mesh axis and
                                   # attention runs as a cp ring over it
                                   # (sequence-sharded). Executor-only:
                                   # ep > 1 always selects the block-executor
                                   # loss (train/executor.py).
    ep_impl: str = "auto"          # "auto" | "blocking" | "overlap": how the
                                   # EP dispatch/combine all-to-alls execute
                                   # (survey §4.1.5, §5.2). "blocking" is one
                                   # lax.all_to_all before and after the
                                   # expert GEMM — the whole token exchange
                                   # is exposed. "overlap" decomposes each
                                   # all-to-all into ppermute ring ticks
                                   # interleaved with per-peer expert-GEMM
                                   # chunks (each tick computes the chunk it
                                   # already holds while the next is in
                                   # flight), with a custom-VJP mirrored
                                   # reversed-ring backward; resolved by
                                   # repro.kernels.dispatch.select_ep_impl
                                   # ("auto" = overlap — the ring is
                                   # semantically identical everywhere and
                                   # its ticks compile to async DMAs on TPU).
    pp: int = 1                    # pipeline stages over pod axis (1 = pure DP pods)
    pp_layout: Optional[Tuple[int, ...]] = None
                                   # layers-per-stage partition for uneven
                                   # (Malleus-style) pipelining, survey §8.1:
                                   # a tuple of length pp summing to
                                   # cfg.n_layers, each stage >= 1 layer.
                                   # None = the even n_layers/pp split (and
                                   # then n_layers must divide pp). Uneven
                                   # layouts are the fail-slow mitigation:
                                   # a straggling stage gets fewer layers, so
                                   # a degraded device does less work per
                                   # tick instead of stalling the whole ring.
    pp_schedule: str = "1f1b"      # pipeline schedule (§4.1.3): "gpipe" is
                                   # fill-drain with reverse-AD through the
                                   # forward scan (keeps O(M) microbatches of
                                   # activations live); "1f1b" is a custom-VJP
                                   # one-forward-one-backward schedule whose
                                   # backward scan interleaves the mirrored
                                   # drain with forward recompute ticks —
                                   # same loss/grads, O(P) stages of in-flight
                                   # activations.
    microbatches: int = 1          # grad-accumulation / pipeline microbatches
    remat: str = "full"            # activation recomputation (§6.1), applied
                                   # per decoder layer: "none" saves every
                                   # intermediate, "full" recomputes the whole
                                   # layer in the backward, "selective" saves
                                   # only the fused-kernel outputs (flash-attn
                                   # out+lse, expert-GEMM out, SSD chunk
                                   # states — the residuals the custom VJPs
                                   # consume) and recomputes the cheap glue.
    seq_shard_decode: bool = True  # shard KV cache seq dim over model axis
    seq_shard_attn: bool = True    # Megatron-SP/context-parallel: shard the
                                   # query-sequence dim of attention over
                                   # ``model`` (survey §4.1.4) — needed because
                                   # GQA kv_heads < 16 defeats head sharding
    pad_vocab_to_multiple: int = 0 # pad embedding/LM-head vocab dim so it
                                   # divides the model axis (Megatron-style):
                                   # keeps logits vocab-parallel instead of
                                   # all-reducing a (B,S,V) tensor per step.
                                   # Padded logits are masked to -1e9. Under
                                   # tp_impl="overlap" the vocab-parallel
                                   # cross-entropy (train/loss.py
                                   # cross_entropy_vp) completes this: the
                                   # softmax reduces per shard + scalar psum,
                                   # so the full-vocab logits tensor never
                                   # exists.
    dp_over_model: bool = False    # beyond-paper mesh remap: run the model
                                   # axis as extra data parallelism (256-way
                                   # DP). Right for small models where 1-D TP
                                   # activation all-reduces dominate (the
                                   # survey's small-model guidance).
    moe_dispatch: str = "einsum"   # "einsum": GShard one-hot dispatch/combine
                                   # (paper-faithful). "scatter": MegaBlocks-
                                   # inspired index gather/scatter — same
                                   # routing, ~E·C/k less dispatch traffic.
    attn_impl: str = "auto"        # "auto" | "xla" | "pallas": which attention
                                   # implementation train/prefill use (survey
                                   # §5.1.1). Resolved per call site by
                                   # repro.kernels.dispatch — "auto" picks the
                                   # fused Pallas flash kernel on TPU backends
                                   # and the XLA twins elsewhere.
    moe_gemm_impl: str = "auto"    # same choices, for the MoE expert GEMMs
                                   # (survey §4.1.5): "pallas" routes all three
                                   # SwiGLU GEMMs of _expert_ffn through the
                                   # differentiable grouped kernel with
                                   # group_sizes padding-row masking, on both
                                   # the dense and the EP/shard_map paths.
    ssm_impl: str = "auto"         # same choices, for the Mamba2 SSD chunk
                                   # scan: "pallas" keeps the (q, q) decay
                                   # matrices and the running state in VMEM in
                                   # both passes (forward saves only per-chunk
                                   # entering states for the backward).
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    integrity: str = "off"         # "off" | "audit": silent-data-corruption
                                   # defense (survey §8.2). "audit" makes the
                                   # train step emit an exact uint32 bitcast
                                   # checksum of updated params + grads
                                   # (ft/integrity.tree_checksum) and cross-
                                   # check it across every mesh axis with a
                                   # pmax/pmin pair — metrics gain
                                   # "integrity_checksum" and
                                   # "integrity_div" (0.0 = all replicas
                                   # bit-identical); ft/recovery turns a
                                   # nonzero divergence into an "sdc"
                                   # anomaly (policy default: rollback).
                                   # Cost is one elementwise pass + two
                                   # scalar collectives, measured per family
                                   # by BENCH_integrity.json.

    def __post_init__(self):
        if self.pp_layout is not None:
            # normalize to a tuple of ints so the frozen plan stays hashable
            # and JSON-round-tripped layouts ([3, 1]) compare equal
            object.__setattr__(self, "pp_layout",
                               tuple(int(x) for x in self.pp_layout))

    def validate(self, cfg: ModelConfig) -> None:
        if self.integrity not in ("off", "audit"):
            raise ValueError(
                f"integrity must be off|audit, got {self.integrity!r}")
        for knob in ("attn_impl", "moe_gemm_impl", "ssm_impl"):
            if getattr(self, knob) not in ("auto", "xla", "pallas"):
                raise ValueError(
                    f"{knob} must be auto|xla|pallas, got {getattr(self, knob)!r}")
        if self.tp_impl not in ("auto", "gspmd", "overlap"):
            raise ValueError(
                f"tp_impl must be auto|gspmd|overlap, got {self.tp_impl!r}")
        if self.remat not in ("none", "selective", "full"):
            raise ValueError(
                f"remat must be none|selective|full, got {self.remat!r}")
        if self.pp_schedule not in ("gpipe", "1f1b"):
            raise ValueError(
                f"pp_schedule must be gpipe|1f1b, got {self.pp_schedule!r}")
        if self.cp_impl not in ("auto", "gather", "ring"):
            raise ValueError(
                f"cp_impl must be auto|gather|ring, got {self.cp_impl!r}")
        if self.ep_impl not in ("auto", "blocking", "overlap"):
            raise ValueError(
                f"ep_impl must be auto|blocking|overlap, got {self.ep_impl!r}")
        if isinstance(self.ep, bool):
            raise ValueError(
                "ParallelPlan.ep is an integer expert-parallel degree now "
                "(the legacy bool selected the pre-executor shard_map path, "
                f"which is gone); got ep={self.ep!r} — use ep=<degree>")
        if self.ep < 1:
            raise ValueError(f"ep must be >= 1, got {self.ep}")
        if self.cp < 1:
            raise ValueError(f"cp must be >= 1, got {self.cp}")
        if self.cp > 1:
            if cfg.family not in (Family.DENSE, Family.MOE, Family.SSM):
                raise ValueError(
                    f"cp > 1 supports dense/moe/ssm decoder-only families "
                    f"(the block-executor wiring), got {cfg.family!r}")
            if self.tp > 1 and self.tp_impl == "gspmd":
                raise ValueError(
                    "cp > 1 composes with tp via the executor's explicit "
                    "shard_map rings; set tp_impl='overlap' (or 'auto')")
            if self.dp_over_model:
                raise ValueError("cp > 1 is incompatible with dp_over_model")
        # Documented divergence (PR 4 / cp): with shard-local routing, GShard
        # token-dropping decisions are made per data/context shard while the
        # GSPMD baseline routes globally — same math only when no tokens
        # drop. Flag it loudly instead of silently differing; equivalence
        # tests force no-drop capacity (capacity_factor >= E / top_k).
        # (validate() only sees *explicit* knobs; the executor re-checks
        # against the resolved placement, catching tp_impl="auto"→overlap.)
        if self.cp > 1 or self.tp_impl == "overlap" or self.ep > 1:
            warn_shard_local_routing(cfg)
        if self.ep > 1:
            if cfg.family != Family.MOE:
                raise ValueError(
                    f"expert parallelism requires a MoE arch, got {cfg.family}")
            if self.dp_over_model:
                raise ValueError(
                    "dp_over_model consumes the model axis; EP needs it")
            if self.tp > 1 and self.tp_impl == "gspmd":
                raise ValueError(
                    "ep > 1 composes with tp via the executor's explicit "
                    "shard_map rings; set tp_impl='overlap' (or 'auto')")
            # MoE parallel folding: the expert ring reuses the cp × model
            # devices, so its size is pinned to their product. The ep-only
            # placement (tp == cp == 1 → experts over the model axis) is
            # checked against the actual mesh in executor.resolve_context.
            fold = (self.cp if self.cp > 1 else 1) * \
                   (self.tp if self.tp > 1 else 1)
            if fold > 1 and self.ep != fold:
                raise ValueError(
                    f"ep={self.ep} must equal cp×tp={fold}: the expert axis "
                    "folds onto the existing cp/model device ring (MoE "
                    "parallel folding) — it is a re-mapping of those "
                    "devices, not extra ones")
            if cfg.moe and cfg.moe.num_experts % self.ep != 0:
                raise ValueError(
                    f"ep={self.ep} must divide num_experts="
                    f"{cfg.moe.num_experts} for expert parallelism")
        if self.pp_layout is not None:
            if self.pp <= 1:
                raise ValueError(
                    f"pp_layout requires pp > 1, got pp={self.pp}")
            if len(self.pp_layout) != self.pp:
                raise ValueError(
                    f"pp_layout length {len(self.pp_layout)} != pp={self.pp}")
            if any(x < 1 for x in self.pp_layout):
                raise ValueError(
                    f"pp_layout stages need >= 1 layer, got {self.pp_layout}")
            if sum(self.pp_layout) != cfg.n_layers:
                raise ValueError(
                    f"pp_layout {self.pp_layout} sums to "
                    f"{sum(self.pp_layout)}, expected n_layers={cfg.n_layers}")
        elif self.pp > 1 and cfg.n_layers % self.pp != 0:
            raise ValueError(
                "n_layers must divide pp (or give an explicit pp_layout)")


# ---------------------------------------------------------------------------
# Recovery policy (survey §8): what ft/recovery.run_with_recovery does per
# anomaly kind reported by ft/anomaly.Monitor.

RECOVERY_ACTIONS = ("rollback", "lr_rescue", "remesh", "rebalance", "ignore")


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Anomaly -> action table for the recovery driver (survey §8.3).

    Actions:

    - ``"rollback"``: restore the latest checkpoint and replay (the
      deterministic pipeline makes the replay bit-faithful);
    - ``"lr_rescue"``: rollback, then damp the optimizer through the bad
      region — via the driver's ``rescue_step`` (LR scaled by
      ``rescue_lr_scale``) when one was built, else by skipping the
      offending batch (recorded as a nan in the loss trace);
    - ``"remesh"``: elastic recovery from host loss (survey §8.3.2) —
      rebuild the mesh at reduced size via the driver's ``remesh`` hook and
      :meth:`CheckpointManager.restore_resharded` the state (params + the
      ZeRO-1 moments, re-scattered over the new data axis), then continue
      on the shrunken cluster;
    - ``"rebalance"``: Malleus-style fail-slow mitigation (survey §8.1) —
      re-partition the pipeline's layers-per-stage (``ParallelPlan.
      pp_layout``) from the straggler detector's measured per-stage times
      via the driver's ``rebalance`` hook, restart through an elastic
      checkpoint reshard-restore, and continue degraded-but-faster; only
      meaningful for ``straggler`` anomalies attributed to a pipeline
      stage — other kinds fall back to ``remesh``/``ignore``;
    - ``"ignore"``: log the anomaly and keep going.
    """
    nan: str = "rollback"            # non-finite loss/grad-norm: numerical
                                     # failure — replay is the only safe move
    spike: str = "rollback"          # first loss spike at a step: assume
                                     # transient (bad host, bit flip), replay
    repeated_spike: str = "lr_rescue"  # the same step spikes again after a
                                     # rollback: replay alone is a loop —
                                     # escalate to LR-rescue / skip-batch
                                     # (PaLM-style spike handling)
    hang: str = "ignore"             # slow/hung step: "remesh" shrinks the
                                     # mesh and reshard-restores (needs the
                                     # driver's remesh hook); default ignore
                                     # keeps the watchdog advisory-only
    sdc: str = "rollback"            # cross-replica integrity-checksum
                                     # divergence under plan.integrity=
                                     # "audit": a device produced different
                                     # bits — the state cannot be trusted,
                                     # roll back to the last checkpoint
    straggler: str = "ignore"        # fail-slow attribution from
                                     # ft/straggler (rank, component,
                                     # compute|comm|host-io): the response
                                     # ladder is "ignore" (advisory, the
                                     # default) -> "rebalance" (uneven
                                     # pp_layout re-partition from measured
                                     # per-stage times, restarted through a
                                     # checkpoint reshard) -> "remesh" (evict
                                     # the slow rank's host entirely); a
                                     # rebalance that can't apply (no
                                     # pipeline, non-stage attribution, or
                                     # the same stage already rebalanced)
                                     # escalates to remesh when that hook
                                     # exists
    ckpt_io: str = "ignore"          # checkpoint persist failed after
                                     # io_retries attempts (ft/inject's
                                     # persist_exc, full disk, ...): the
                                     # *run* is still healthy, so default
                                     # ignore — the anomaly is recorded and
                                     # training continues on the older
                                     # checkpoint cadence; "rollback" forces
                                     # an immediate restore instead
    max_restores: int = 3            # give up after this many restores
    rescue_lr_scale: float = 0.1     # LR multiplier while an lr_rescue step
                                     # replays the offending step
    elastic: bool = True             # allow cross-layout restore routing
                                     # (check_plan returns "reshard" instead
                                     # of refusing on a layout change)
    ckpt_memory_keep: int = 2        # hot in-memory checkpoint tier (survey
                                     # §8.3.1, Gemini/CheckFreq): RAM ring of
                                     # the last K snapshots, restored before
                                     # any disk walk; 0 disables the tier
    peer_redundancy: bool = True     # mirror each host-group's RAM shards
                                     # onto its ring neighbor (host-side
                                     # stand-in for the fleet's ring
                                     # ppermute) so a lost group rebuilds
                                     # from surviving peers without disk
    preempt_grace: float = 30.0      # seconds between the preemption notice
                                     # (SIGTERM/SIGUSR1) and the kill: the
                                     # just-in-time snapshot must fit here;
                                     # ft/preempt.choose_tier picks disk when
                                     # measured persist time fits, RAM
                                     # otherwise
    flight_len: int = 256            # crash flight recorder ring capacity
                                     # (events, not steps); the ring is
                                     # dumped to JSON on preemption, crash,
                                     # or RecoveryExhausted
    straggler_factor: float = 2.0    # relative slowdown threshold: a rank is
                                     # slow when its section time exceeds
                                     # factor x the median of its peers (or
                                     # of its own trailing window for
                                     # global sections)
    straggler_window: int = 16       # sliding window (observations) kept per
                                     # (section, rank) by the detector
    straggler_confirm: int = 3       # consecutive slow observations before
                                     # the anomaly is raised — this IS the
                                     # detection latency in steps
    straggler_min_seconds: float = 5e-3
                                     # absolute slowdown floor (seconds above
                                     # baseline); below it the relative test
                                     # never fires, so scheduler jitter on
                                     # sub-ms sections can't page anyone

    def validate(self) -> None:
        for knob in ("nan", "spike", "repeated_spike", "hang", "sdc",
                     "ckpt_io", "straggler"):
            if getattr(self, knob) not in RECOVERY_ACTIONS:
                raise ValueError(
                    f"{knob} action must be one of {RECOVERY_ACTIONS}, "
                    f"got {getattr(self, knob)!r}")
        if self.max_restores < 0:
            raise ValueError(f"max_restores must be >= 0, got {self.max_restores}")
        if not 0.0 < self.rescue_lr_scale <= 1.0:
            raise ValueError(
                f"rescue_lr_scale must be in (0, 1], got {self.rescue_lr_scale}")
        if self.ckpt_memory_keep < 0:
            raise ValueError(
                f"ckpt_memory_keep must be >= 0, got {self.ckpt_memory_keep}")
        if self.preempt_grace <= 0.0:
            raise ValueError(
                f"preempt_grace must be > 0, got {self.preempt_grace}")
        if self.flight_len < 1:
            raise ValueError(
                f"flight_len must be >= 1, got {self.flight_len}")
        if self.straggler_factor <= 1.0:
            raise ValueError(
                f"straggler_factor must be > 1, got {self.straggler_factor}")
        if self.straggler_window < 4:
            raise ValueError(
                f"straggler_window must be >= 4, got {self.straggler_window}")
        if self.straggler_confirm < 1:
            raise ValueError(
                f"straggler_confirm must be >= 1, got {self.straggler_confirm}")
        if self.straggler_min_seconds < 0.0:
            raise ValueError(
                f"straggler_min_seconds must be >= 0, "
                f"got {self.straggler_min_seconds}")


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (fixed public pool).

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}
