from .roofline import (
    HBM_BW, LINK_BW, PEAK_FLOPS,
    CollectiveStats, Roofline, model_flops_for, parse_collectives,
)

__all__ = [
    "HBM_BW", "LINK_BW", "PEAK_FLOPS",
    "CollectiveStats", "Roofline", "model_flops_for", "parse_collectives",
]
