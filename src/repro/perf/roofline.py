"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), per the assignment:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective bytes
are NOT in cost_analysis — we parse the optimized HLO text and cost each
collective with the standard ring model on its parsed replica-group size N:

    all-reduce      2·(N-1)/N · size     (reduce-scatter + all-gather phases)
    all-gather      (N-1)/N · result_size
    reduce-scatter  (N-1)/N · operand_size ≈ (N-1) · result_size
    all-to-all      (N-1)/N · size
    collective-permute  size

giving *per-device bytes crossing links*, which is what the link-bandwidth
denominator wants. Hardware constants: TPU v5e-class chip.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                     "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[2048,1024]' -> bytes. Tuples: sum parts."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    """Parse replica_groups; returns participants per group."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)      # iota v2
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{(.*?)\}\}", line)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        return max(len([t for t in first.split(",") if t.strip() != ""]), 1)
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]       # raw result sizes per kind
    link_bytes: Dict[str, float]       # ring-model per-device bytes per kind

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVE_KINDS}
    rbytes = {k: 0 for k in _COLLECTIVE_KINDS}
    lbytes = {k: 0.0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*?) ([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        kind = next((k for k in _COLLECTIVE_KINDS
                     if op == k or op.startswith(k + "-")), None)
        if kind is None or op.endswith("-done"):
            continue
        size = _shape_bytes(m.group(1))
        if op.endswith("-start"):
            size //= 2            # async start: result tuple carries operand+result
        n = _group_size(ls, total_devices)
        counts[kind] += 1
        rbytes[kind] += size
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-reduce":
            lbytes[kind] += 2.0 * frac * size
        elif kind == "all-gather":
            lbytes[kind] += frac * size
        elif kind == "reduce-scatter":
            lbytes[kind] += frac * size * n       # operand = result × N
        elif kind == "all-to-all":
            lbytes[kind] += frac * size
        else:  # collective-permute
            lbytes[kind] += float(size)
    return CollectiveStats(counts, rbytes, lbytes)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float                  # 6·N(active)·D analytic
    collectives: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        # cost_analysis flops are whole-program (all devices for SPMD on the
        # host platform count once) — they are per-program; divide by chips.
        return self.hlo_flops / (PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        if self.hlo_flops <= 0:
            return float("nan")
        return self.model_flops / (self.hlo_flops * self.chips)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collectives": self.collectives,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N_active·D per the assignment (D = tokens processed per step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
