"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — but this
framework deliberately wraps layer stacks, KV-block streams and microbatches in
``lax.scan`` (compile-time hygiene), so naive cost analysis undercounts FLOPs by
~n_layers×. This walker parses the optimized HLO, multiplies per-computation
costs by loop trip counts (``backend_config known_trip_count``, emitted by XLA:CPU and
XLA:TPU for counted loops), and accumulates:

- **flops**: 2 · result_elems · contracted_elems for every ``dot`` (matmuls are
  ≥99% of LLM FLOPs; elementwise ops are ignored, consistent with how MFU is
  conventionally counted);
- **bytes**: Σ (operand bytes + result bytes) per instruction — an HBM-traffic
  proxy assuming no fusion reuse *between* instructions (fusions are costed at
  the fusion boundary, which is exactly the set of buffers that must
  materialize);
- **collectives**: per-kind link bytes with the ring model (see roofline.py).

This is a text-level reimplementation of HloCostAnalysis with loop semantics —
validated against analytic 6ND in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from .roofline import _COLLECTIVE_KINDS, _DTYPE_BYTES, _group_size

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"(?:branch_computations|true_computation|"
                        r"false_computation)=\{?%?([\w.\-,% ]+)\}?")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
}


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_TOK.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _shapes_bytes(s: str) -> int:
    return sum(int(np.prod(sh)) * _DTYPE_BYTES[dt] if sh else _DTYPE_BYTES[dt]
               for dt, sh in _parse_shapes(s))


@dataclasses.dataclass
class Instr:
    name: str
    result: str          # result shape string
    op: str
    rest: str            # everything after the open paren


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_link_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVE_KINDS})
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVE_KINDS})


class HloModule:
    def __init__(self, text: str, total_devices: int):
        self.total_devices = total_devices
        self.comps: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            mh = _COMP_RE.match(line)
            if mh and " = " not in line:
                cur = mh.group(1)
                self.comps[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(line)
            if mi:
                self.comps[cur].append(
                    Instr(mi.group(1), mi.group(2), mi.group(3), mi.group(4)))
        # symbol table: instruction name -> result shape string (per computation)
        self.symtab: Dict[str, Dict[str, str]] = {
            c: {i.name: i.result for i in instrs}
            for c, instrs in self.comps.items()
        }

    # -- per-instruction costs ------------------------------------------------

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        res = _parse_shapes(ins.result)
        if not res:
            return 0.0
        result_elems = int(np.prod(res[0][1])) if res[0][1] else 1
        mc = _LHS_CONTRACT_RE.search(ins.rest)
        operands = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
        contracted = 1
        if mc and operands:
            lhs_shape_str = self.symtab.get(comp, {}).get(operands[0], "")
            lhs = _parse_shapes(lhs_shape_str)
            if lhs and mc.group(1):
                dims = [int(d) for d in mc.group(1).split(",")]
                for d in dims:
                    if d < len(lhs[0][1]):
                        contracted *= lhs[0][1][d]
        return 2.0 * result_elems * contracted

    def _instr_bytes(self, comp: str, ins: Instr) -> int:
        if ins.op in _SKIP_BYTES_OPS or ins.op == "fusion":
            return 0
        st = self.symtab.get(comp, {})
        operands = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
        # Slice-like ops only touch the slice, not the whole operand; DUS/scatter
        # are in-place after buffer assignment and touch ~2× the update region.
        if ins.op in ("slice", "dynamic-slice", "gather", "reshape", "copy",
                      "transpose", "broadcast"):
            return 2 * _shapes_bytes(ins.result)
        if ins.op == "dynamic-update-slice":
            upd = operands[1] if len(operands) > 1 else None
            if upd and upd in st:
                return 2 * _shapes_bytes(st[upd])
            return 2 * _shapes_bytes(ins.result)
        if ins.op == "scatter":
            upd = operands[2] if len(operands) > 2 else None
            if upd and upd in st:
                return 2 * _shapes_bytes(st[upd])
            return 2 * _shapes_bytes(ins.result)
        total = _shapes_bytes(ins.result)
        for o in operands:
            if o in st:
                total += _shapes_bytes(st[o])
        return total

    _SLICE_LIKE = ("slice", "dynamic-slice", "gather")

    def _fusion_bytes(self, comp: str, ins: Instr) -> int:
        """Fusion boundary = materialized buffers (operands + result), except:

        - a fused *parameter* whose every use is a slice-like op only reads the
          slices (a scan body slicing its stacked xs must not be billed the
          whole stack every iteration);
        - a fused root that is a dynamic-update-slice writes only the update
          region (XLA buffer assignment makes it in-place).
        """
        st = self.symtab.get(comp, {})
        operands = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
        mcalls = _CALLS_RE.search(ins.rest)
        fused = self.comps.get(mcalls.group(1), []) if mcalls else []
        fsym = self.symtab.get(mcalls.group(1), {}) if mcalls else {}

        # map parameter index -> parameter instr name in the fused computation
        param_names: Dict[int, str] = {}
        for fi in fused:
            if fi.op == "parameter":
                m = re.match(r"(\d+)", fi.rest)
                if m:
                    param_names[int(m.group(1))] = fi.name

        # uses of each fused instruction name
        uses: Dict[str, List[Instr]] = {}
        for fi in fused:
            for o in _OPERAND_RE.findall(fi.rest.split(")", 1)[0]):
                uses.setdefault(o, []).append(fi)

        total = 0
        # result: if root is a DUS, bill 2× the update region instead
        root = fused[-1] if fused else None
        if root is not None and root.op == "dynamic-update-slice":
            r_ops = _OPERAND_RE.findall(root.rest.split(")", 1)[0])
            upd = r_ops[1] if len(r_ops) > 1 else None
            total += 2 * _shapes_bytes(fsym.get(upd, "")) if upd in fsym \
                else _shapes_bytes(ins.result)
        else:
            total += _shapes_bytes(ins.result)

        for idx, o in enumerate(operands):
            if o not in st:
                continue
            pname = param_names.get(idx)
            puses = uses.get(pname, []) if pname else []
            if puses and all(u.op in self._SLICE_LIKE for u in puses):
                total += sum(_shapes_bytes(u.result) for u in puses)
            else:
                total += _shapes_bytes(st[o])
        return total

    # -- recursive walk -------------------------------------------------------

    def cost(self) -> HloCost:
        out = HloCost()
        if self.entry:
            self._walk(self.entry, 1.0, out, set())
        return out

    def _walk(self, comp: str, mult: float, out: HloCost, stack: frozenset):
        if comp not in self.comps or comp in stack:
            return
        stack = stack | {comp}
        for ins in self.comps[comp]:
            op = ins.op
            if op == "while":
                trip = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trip = int(mt.group(1))
                mb = _BODY_RE.search(ins.rest)
                if mb:
                    self._walk(mb.group(1), mult * trip, out, stack)
                mcnd = _COND_RE.search(ins.rest)
                if mcnd:
                    self._walk(mcnd.group(1), mult * (trip + 1), out, stack)
                continue
            if op == "fusion":
                mcalls = _CALLS_RE.search(ins.rest)
                if mcalls:
                    self._walk_fusion_flops(mcalls.group(1), mult, out, stack)
                out.bytes += mult * self._fusion_bytes(comp, ins)
                continue
            if op in ("call", "custom-call", "async-start"):
                mto = _TOAPPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
                if mto:
                    self._walk(mto.group(1), mult, out, stack)
                if op != "call":
                    out.bytes += mult * self._instr_bytes(comp, ins)
                continue
            if op == "conditional":
                mbr = _BRANCH_RE.search(ins.rest)
                if mbr:
                    for b in re.findall(r"[\w.\-]+", mbr.group(1)):
                        self._walk(b, mult, out, stack)
                continue

            kind = next((k for k in _COLLECTIVE_KINDS
                         if op == k or op.startswith(k + "-")), None)
            if kind is not None and not op.endswith("-done"):
                size = _shapes_bytes(ins.result)
                if op.endswith("-start"):
                    size //= 2
                n = _group_size(ins.rest, self.total_devices)
                out.collective_counts[kind] += mult
                frac = (n - 1) / n if n > 1 else 0.0
                if kind == "all-reduce":
                    link = 2.0 * frac * size
                elif kind == "all-gather":
                    link = frac * size
                elif kind == "reduce-scatter":
                    link = frac * size * n
                elif kind == "all-to-all":
                    link = frac * size
                else:
                    link = float(size) if n > 1 else 0.0
                out.collective_bytes_by_kind[kind] += mult * link
                out.collective_link_bytes += mult * link
                out.bytes += mult * self._instr_bytes(comp, ins)
                continue

            if op == "dot" or op == "convolution":
                out.flops += mult * self._dot_flops(comp, ins)
            out.bytes += mult * self._instr_bytes(comp, ins)

    def _walk_fusion_flops(self, comp: str, mult: float, out: HloCost,
                           stack: frozenset):
        """Inside fusions only dots contribute flops; bytes counted at boundary."""
        if comp not in self.comps or comp in stack:
            return
        stack = stack | {comp}
        for ins in self.comps[comp]:
            if ins.op in ("dot", "convolution"):
                out.flops += mult * self._dot_flops(comp, ins)
            elif ins.op == "fusion":
                mcalls = _CALLS_RE.search(ins.rest)
                if mcalls:
                    self._walk_fusion_flops(mcalls.group(1), mult, out, stack)


def analyze_hlo(text: str, total_devices: int) -> HloCost:
    return HloModule(text, total_devices).cost()
