"""gemma2-9b [dense] — local/global alternating attention + logit softcaps
[arXiv:2408.00118].

42L, d_model=3584, 16 heads (GQA kv=8, head_dim=256), d_ff=14336, vocab=256000.
Sliding window 4096 on even (local) layers; attn softcap 50, final softcap 30;
post-sub-block RMSNorms; embeddings scaled by sqrt(d); tied embeddings.

long_500k runs via the ``long_context`` beyond-paper variant (all layers
sliding-window — see DESIGN.md §4): use ``LONG_CONTEXT`` below.
"""

import dataclasses

from repro.core import Family, ModelConfig, register

FULL = ModelConfig(
    arch_id="gemma2-9b",
    family=Family.DENSE,
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    local_global_alternating=True,
    post_norm=True,
    scale_embed=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)

LONG_CONTEXT = dataclasses.replace(FULL, long_context=True)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, sliding_window=8)


register(FULL, smoke)
