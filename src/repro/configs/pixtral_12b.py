"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo decoder.

[hf:mistralai/Pixtral-12B-2409]: 40L, d_model=5120, 32 heads (GQA kv=8,
head_dim=128), d_ff=14336, vocab=131072. The vision encoder is a STUB per the
assignment carve-out: ``input_specs`` supplies precomputed patch embeddings
(B, 256, 5120) and their scatter positions.
"""

from repro.core import Family, ModelConfig, register

FULL = ModelConfig(
    arch_id="pixtral-12b",
    family=Family.VLM,
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e6,
    vision_tokens=256,
    source="hf:mistralai/Pixtral-12B-2409",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, vision_tokens=4)


register(FULL, smoke)
