"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B arch family].

40L, d_model=2560, 20 heads (kv=20, head_dim=128), d_ff=6912, vocab=151936.
"""

from repro.core import Family, ModelConfig, register

FULL = ModelConfig(
    arch_id="qwen1.5-4b",
    family=Family.DENSE,
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=512)


register(FULL, smoke)
