"""deepseek-moe-16b [moe] — fine-grained experts, 2 shared + 64 routed top-6
[arXiv:2401.06066].

28L, d_model=2048, 16 heads (kv=16), per-expert d_ff=1408, vocab=102400.
"""

from repro.core import Family, ModelConfig, MoEConfig, register

FULL = ModelConfig(
    arch_id="deepseek-moe-16b",
    family=Family.MOE,
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared_experts=2),
    source="arXiv:2401.06066",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, num_shared_experts=1))


register(FULL, smoke)
