"""whisper-small [audio] — encoder-decoder, conv frontend stub [arXiv:2212.04356].

12L encoder + 12L decoder, d_model=768, 12 heads (kv=12), d_ff=3072,
vocab=51865, sinusoidal positions. The mel-spectrogram + conv feature extractor
is a STUB per the assignment carve-out: ``input_specs`` supplies precomputed
frame embeddings (B, 1500, 768).

Decode shapes run (decoder has a KV cache); long_500k skipped (full attention).
"""

from repro.core import Family, ModelConfig, register

FULL = ModelConfig(
    arch_id="whisper-small",
    family=Family.AUDIO,
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    pos_emb="sinusoidal",
    enc_layers=12,
    enc_frames=1500,
    source="arXiv:2212.04356",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, enc_layers=2, enc_frames=16)


register(FULL, smoke)
