"""olmoe-1b-7b [moe] — 64 experts, top-8 routing [arXiv:2409.02060].

16L, d_model=2048, 16 heads (kv=16), per-expert d_ff=1024, vocab=50304.
1B active / 7B total parameters.
"""

from repro.core import Family, ModelConfig, MoEConfig, register

FULL = ModelConfig(
    arch_id="olmoe-1b-7b",
    family=Family.MOE,
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    source="arXiv:2409.02060",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=512, moe=MoEConfig(num_experts=4, top_k=2, d_expert=64))


register(FULL, smoke)
