"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242].

38L, d_model=2048, shared attention block (32 heads, kv=32, d_ff=8192) applied
every 6 Mamba2 layers; ssm_state=64. Sub-quadratic: runs long_500k.
"""

from repro.core import Family, ModelConfig, SSMConfig, register

FULL = ModelConfig(
    arch_id="zamba2-1.2b",
    family=Family.HYBRID,
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1),
    shared_attn_every=6,
    source="arXiv:2411.15242",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, shared_attn_every=2,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, n_groups=1))


register(FULL, smoke)
