"""Assigned-architecture configs + dry-run input specs.

``input_specs(cfg, shape, mesh, plan)`` returns ShapeDtypeStruct stand-ins for
every input of the step function selected by the shape's kind — weak-type
correct, shardable, zero allocation (the dry-run contract).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import Family, InputShape, ModelConfig, ParallelPlan
from repro.core.registry import ARCH_IDS, all_configs, get_config, get_smoke_config

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
    }
    if cfg.family == Family.AUDIO:
        specs["frames"] = SDS((b, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.family == Family.VLM and cfg.vision_tokens:
        specs["vision_embeds"] = SDS((b, cfg.vision_tokens, cfg.d_model), jnp.float32)
        specs["vision_pos"] = SDS((b, cfg.vision_tokens), jnp.int32)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    del specs["labels"]
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape, model) -> Dict[str, Any]:
    """Specs for decode_step(params, cache, tokens, pos)."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {
        "cache": cache,
        "tokens": SDS((b,), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: InputShape, model=None) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    assert model is not None, "decode specs need the model (cache shapes)"
    return decode_input_specs(cfg, shape, model)


__all__ = [
    "ARCH_IDS", "all_configs", "get_config", "get_smoke_config",
    "input_specs", "train_input_specs", "prefill_input_specs",
    "decode_input_specs",
]
