"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060].

48L, d_model=1024, ssm_state=128, vocab=50280. Sub-quadratic: runs long_500k.
"""

from repro.core import Family, ModelConfig, SSMConfig, register

FULL = ModelConfig(
    arch_id="mamba2-370m",
    family=Family.SSM,
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    source="arXiv:2405.21060",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=128, vocab=512,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, n_groups=1))


register(FULL, smoke)
