"""qwen2.5-14b [dense] — GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B arch family].

48L, d_model=5120, 40 heads (GQA kv=8, head_dim=128), d_ff=13824, vocab=152064.
"""

from repro.core import Family, ModelConfig, register

FULL = ModelConfig(
    arch_id="qwen2.5-14b",
    family=Family.DENSE,
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=512)


register(FULL, smoke)
