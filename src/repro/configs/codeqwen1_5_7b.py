"""codeqwen1.5-7b [dense] — qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B].

32L, d_model=4096, 32 heads (kv=32 — MHA-equal GQA), d_ff=13440, vocab=92416,
QKV bias.
"""

from repro.core import Family, ModelConfig, register

FULL = ModelConfig(
    arch_id="codeqwen1.5-7b",
    family=Family.DENSE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        FULL, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab=512)


register(FULL, smoke)
