"""Model assembly for all assigned architecture families.

Unified functional API (``build_model`` returns a :class:`Model`):

- ``init(rng) -> params``                       (fp32 master weights)
- ``forward(params, batch) -> (logits, aux)``   (train / prefill)
- ``init_cache(batch, max_seq) -> cache``       (decode state, zeros)
- ``decode_step(params, cache, tokens, pos) -> (logits, cache)``

Layer stacks are built with ``jax.vmap`` over per-layer RNGs and executed with
``jax.lax.scan`` so HLO size is O(1) in depth (compile-time hygiene, DESIGN.md
§5). Per-layer heterogeneity (gemma2 local/global alternation) rides along as a
scanned metadata array rather than unrolled python branches.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.config import Family, ModelConfig, ParallelPlan
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (
    attention,
    dense_init,
    init_attn,
    init_mlp,
    mlp_block,
    qkv_proj,
    rms_norm,
    rope,
    sinusoidal_pos_emb,
    split_tree,
)


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[[Any, Dict[str, jax.Array]], Tuple[jax.Array, jax.Array]]
    init_cache: Callable[[int, int], Any]
    decode_step: Callable[[Any, Any, jax.Array, jax.Array], Tuple[jax.Array, Any]]
    extras: Dict[str, Callable] = {}


# ---------------------------------------------------------------------------
# helpers

def _stacked_init(rng, n: int, fn: Callable[[jax.Array], Any]) -> Any:
    """Stack per-layer params along a new leading dim via vmap over rngs."""
    return jax.vmap(fn)(jax.random.split(rng, n))


# Selective-remat save set (survey §6.1): the fused-kernel outputs and the
# residuals their custom VJPs consume — flash-attention out + per-row
# logsumexp, the grouped expert-GEMM output, the SSD per-chunk entering
# states — plus the glue-level block outputs the XLA twins tag. Everything
# else (norms, projections, rotary, SwiGLU glue) is cheap to recompute.
REMAT_SAVE_NAMES: Tuple[str, ...] = (
    "flash_out", "flash_lse",        # kernels/flash_attention.py fwd residuals
    "expert_gemm_out",               # kernels/grouped_gemm.py fwd output
    "ssd_out", "ssd_state",          # kernels/ssd_scan.py output + chunk states
    "attn_out", "block_out",         # glue-level tags (XLA twin paths)
)


def _remat(f, mode: str):
    """Per-decoder-layer activation recomputation (``plan.remat``).

    ``none`` differentiates normally (every intermediate saved), ``full``
    recomputes the whole layer in the backward, ``selective`` saves only
    :data:`REMAT_SAVE_NAMES` and recomputes the cheap glue around the kernels.
    """
    if mode == "none":
        return f
    if mode == "selective":
        pol = jax.checkpoint_policies.save_only_these_names(*REMAT_SAVE_NAMES)
        return jax.checkpoint(f, policy=pol)
    return jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)


def _layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding-window size (0 = full attention)."""
    if cfg.long_context and cfg.sliding_window:
        return np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    if cfg.local_global_alternating and cfg.sliding_window:
        w = np.zeros((cfg.n_layers,), np.int32)
        w[0::2] = cfg.sliding_window          # even layers local (gemma2)
        return w
    return np.full((cfg.n_layers,), cfg.sliding_window, np.int32)


def _padded_vocab(cfg: ModelConfig, plan: Optional[ParallelPlan]) -> int:
    m = plan.pad_vocab_to_multiple if plan else 0
    if not m:
        return cfg.vocab
    return -(-cfg.vocab // m) * m


def _logits(params, x, cfg: ModelConfig, dtype, plan: Optional[ParallelPlan] = None):
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(dtype).T
    else:
        w = params["lm_head"]["w"].astype(dtype)
    logits = x @ w
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    if vp != cfg.vocab:
        # Megatron-style padded vocab: mask the padded tail out of the softmax
        pad_mask = jnp.arange(vp) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e9, logits)
    return logits


def _embed(params, tokens, cfg: ModelConfig, dtype):
    x = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def _residual_constrainer(mesh, batch_axes):
    """Anchor the (B, S, d) residual stream's batch sharding. GSPMD propagation
    can silently replicate the batch over mesh axes that only appear in the
    batch spec (e.g. the dp_over_model remap) — one constraint per scan body
    pins it."""
    if mesh is None or not batch_axes:
        return lambda x: x
    baxes = batch_axes

    def cx(x):
        if x.ndim != 3:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(baxes, None, None)))
    return cx


def _seq_constrainers(plan, mesh, batch_axes):
    """Megatron-SP / context-parallel constraints (survey §4.1.4).

    Returns (cq, ckv): ``cq`` shards a (B, S, H, hd) tensor's sequence dim over
    ``model`` (queries + attention output); ``ckv`` pins K/V replicated over
    ``model`` (each query shard attends to full KV — exact attention, the
    all-gather is one (B,T,Hkv,hd) tensor vs. a (B,S,S)-sized score matrix).
    No-ops when disabled or when shapes don't divide.
    """
    if mesh is None or plan is None or not plan.seq_shard_attn \
            or "model" not in mesh.shape or "model" in (batch_axes or ()):
        ident = lambda x: x
        return ident, ident
    tp = mesh.shape["model"]
    baxes = batch_axes if batch_axes else None

    def cq(x):
        if x.ndim != 4 or x.shape[1] % tp or x.shape[1] < tp:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(baxes, "model", None, None)))

    def ckv(x):
        if x.ndim != 4:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(baxes, None, None, None)))

    return cq, ckv


# ---------------------------------------------------------------------------
# decoder-only transformer (dense / moe / vlm backbone)

def _init_decoder_layer(cfg: ModelConfig):
    def one(rng):
        r = split_tree(rng, 2)
        p = {
            "norm1": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "norm2": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "attn": init_attn(r[0], cfg),
        }
        if cfg.post_norm:
            p["norm1_post"] = {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}
            p["norm2_post"] = {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}
        if cfg.family == Family.MOE:
            p["moe"] = moe_lib.init_moe(r[1], cfg)
        else:
            p["mlp"] = init_mlp(r[1], cfg.d_model, cfg.d_ff)
        return p
    return one


def _decoder_layer_fwd(cfg: ModelConfig, dtype, mesh, plan, batch_axes,
                       collect_kv: bool = False):
    """The dense/MoE decoder layer body — one wiring for every placement.

    Routes through the unified block executor (``repro.train.executor``)
    with a *local* ParallelContext: identity collectives, the GSPMD
    seq-shard/residual constrainers as placement hooks. The overlap-TP and
    context-parallel paths build the same layer with ring contexts instead
    — the family math is defined once, the executor decides placement.
    """
    from repro.train import executor as exlib  # noqa: PLC0415 (import cycle)
    cq, ckv = _seq_constrainers(plan, mesh, batch_axes)
    cx = _residual_constrainer(mesh, batch_axes)
    ctx = exlib.local_context(mesh=mesh, batch_axes=tuple(batch_axes or ()),
                              cx=cx, cq=cq, ckv=ckv)
    return exlib.decoder_layer(ctx, cfg, plan, dtype, collect_kv=collect_kv)


def build_decoder_only(cfg: ModelConfig, plan: Optional[ParallelPlan] = None,
                       mesh=None, batch_axes=("data",)) -> Model:
    plan = plan or ParallelPlan()
    dtype = jnp.dtype(plan.compute_dtype)
    windows = jnp.asarray(_layer_windows(cfg))

    def init(rng):
        r = split_tree(rng, 3)
        params = {
            "embed": {"tok": dense_init(r[0], (_padded_vocab(cfg, plan), cfg.d_model), in_axis=-1)},
            "layers": _stacked_init(r[1], cfg.n_layers, _init_decoder_layer(cfg)),
            "final_norm": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": dense_init(r[2], (cfg.d_model, _padded_vocab(cfg, plan)))}
        return params

    layer_fwd = _decoder_layer_fwd(cfg, dtype, mesh, plan, batch_axes)

    def forward(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = _embed(params, tokens, cfg, dtype)
        if cfg.family == Family.VLM and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(dtype)      # (B, N_img, d)
            vp = batch["vision_pos"]                       # (B, N_img)
            x = x.at[jnp.arange(b)[:, None], vp].set(ve)
        positions = jnp.arange(s)
        if cfg.pos_emb == "sinusoidal":
            x = x + sinusoidal_pos_emb(positions, cfg.d_model).astype(dtype)

        def body(carry, xs):
            xc, aux = carry
            lp, w = xs
            xn, a = layer_fwd(xc, lp, w, positions)
            return (xn, aux + a), None

        body = _remat(body, plan.remat)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   (params["layers"], windows))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        return _logits(params, x, cfg, dtype), aux

    def init_cache(batch: int, max_seq: int):
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, hkv, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, hkv, hd), dtype),
        }

    def decode_step(params, cache, tokens, pos):
        from repro.serve.attention import decode_attention  # noqa: PLC0415
        b = tokens.shape[0]
        x = _embed(params, tokens, cfg, dtype)[:, None, :]   # (B, 1, d)
        positions = jnp.asarray(pos)[None]
        if cfg.pos_emb == "sinusoidal":
            x = x + sinusoidal_pos_emb(positions, cfg.d_model).astype(dtype)[None]

        def body(x, xs):
            lp, kc, vc, w = xs
            h = rms_norm(x, lp["norm1"]["scale"], cfg.rms_eps)
            q, k, v = qkv_proj(lp["attn"], h, cfg, dtype)
            if cfg.pos_emb == "rope":
                q = rope(q, positions, cfg.rope_theta)
                k = rope(k, positions, cfg.rope_theta)
            a, kc, vc = decode_attention(q, kc, vc, k, v, pos, window=w,
                                         softcap=cfg.attn_logit_softcap,
                                         mesh=mesh, batch_axes=batch_axes)
            a = a.reshape(b, 1, -1) @ lp["attn"]["wo"].astype(dtype)
            if cfg.post_norm:
                a = rms_norm(a, lp["norm1_post"]["scale"], cfg.rms_eps)
            x = x + a
            h = rms_norm(x, lp["norm2"]["scale"], cfg.rms_eps)
            if cfg.family == Family.MOE:
                m, _ = moe_lib.moe_block(lp["moe"], h, cfg, dtype, mesh, plan,
                                         batch_axes)
            else:
                m = mlp_block(lp["mlp"], h, dtype)
            if cfg.post_norm:
                m = rms_norm(m, lp["norm2_post"]["scale"], cfg.rms_eps)
            return x + m, (kc, vc)

        # decode sliding window must be static per layer for mask simplicity;
        # pass the per-layer window array as scanned metadata.
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], windows))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        logits = _logits(params, x[:, 0, :], cfg, dtype)
        return logits, {"k": ks, "v": vs}

    def prefill(params, batch, max_seq: int):
        """Process a prompt in parallel and return (logits, filled cache).

        The production serving flow: prefill once (full forward, KV emitted per
        layer) then call decode_step from position S onward.
        """
        tokens = batch["tokens"]
        b, s = tokens.shape
        assert s <= max_seq
        x = _embed(params, tokens, cfg, dtype)
        if cfg.family == Family.VLM and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(dtype)
            vp = batch["vision_pos"]
            x = x.at[jnp.arange(b)[:, None], vp].set(ve)
        positions = jnp.arange(s)
        if cfg.pos_emb == "sinusoidal":
            x = x + sinusoidal_pos_emb(positions, cfg.d_model).astype(dtype)

        layer_kv = _decoder_layer_fwd(cfg, dtype, mesh, plan, batch_axes,
                                      collect_kv=True)

        def body(carry, xs):
            xc, aux = carry
            lp, w = xs
            xn, a, kv = layer_kv(xc, lp, w, positions)
            return (xn, aux + a), kv

        (x, aux), (ks, vs) = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (params["layers"], windows))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        logits = _logits(params, x, cfg, dtype)

        cache = init_cache(b, max_seq)
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], ks, 0, axis=2),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vs, 0, axis=2),
        }
        return logits, cache

    return Model(cfg, init, forward, init_cache, decode_step,
                 extras={"prefill": prefill})


# ---------------------------------------------------------------------------
# SSM (mamba2) — attention-free

def build_ssm(cfg: ModelConfig, plan: Optional[ParallelPlan] = None,
              mesh=None, batch_axes=("data",)) -> Model:
    plan = plan or ParallelPlan()
    dtype = jnp.dtype(plan.compute_dtype)
    cx = _residual_constrainer(mesh, batch_axes)

    def init_layer(rng):
        return {
            "norm1": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "ssm": ssm_lib.init_ssm(rng, cfg),
        }

    def init(rng):
        r = split_tree(rng, 3)
        params = {
            "embed": {"tok": dense_init(r[0], (_padded_vocab(cfg, plan), cfg.d_model), in_axis=-1)},
            "layers": _stacked_init(r[1], cfg.n_layers, init_layer),
            "final_norm": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": dense_init(r[2], (cfg.d_model, _padded_vocab(cfg, plan)))}
        return params

    def forward(params, batch):
        from repro.train import executor as exlib  # noqa: PLC0415
        tokens = batch["tokens"]
        x = _embed(params, tokens, cfg, dtype)
        layer = exlib.ssm_layer(
            exlib.local_context(mesh=mesh,
                                batch_axes=tuple(batch_axes or ()), cx=cx),
            cfg, plan, dtype)

        def body(carry, lp):
            xn, _ = layer(carry, lp, None, None)
            return xn, None

        body = _remat(body, plan.remat)
        x, _ = jax.lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        return _logits(params, x, cfg, dtype), jnp.float32(0.0)

    def init_cache(batch: int, max_seq: int):
        one = ssm_lib.init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)

    def decode_step(params, cache, tokens, pos):
        x = _embed(params, tokens, cfg, dtype)               # (B, d)

        def body(x, xs):
            lp, c = xs
            h = rms_norm(x, lp["norm1"]["scale"], cfg.rms_eps)
            y, c = ssm_lib.ssm_step(lp["ssm"], h, c, cfg, dtype)
            return x + y, c

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        return _logits(params, x, cfg, dtype), new_cache

    return Model(cfg, init, forward, init_cache, decode_step)


# ---------------------------------------------------------------------------
# hybrid (zamba2): mamba2 backbone + weight-shared attention block

def build_hybrid(cfg: ModelConfig, plan: Optional[ParallelPlan] = None,
                 mesh=None, batch_axes=("data",)) -> Model:
    plan = plan or ParallelPlan()
    dtype = jnp.dtype(plan.compute_dtype)
    every = cfg.shared_attn_every
    n_apps = cfg.n_layers // every
    covered = n_apps * every
    rest = cfg.n_layers - covered

    def init_layer(rng):
        return {
            "norm1": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "ssm": ssm_lib.init_ssm(rng, cfg),
        }

    def init(rng):
        r = split_tree(rng, 5)
        params = {
            "embed": {"tok": dense_init(r[0], (_padded_vocab(cfg, plan), cfg.d_model), in_axis=-1)},
            "layers": _stacked_init(r[1], cfg.n_layers, init_layer),
            "shared_attn": {
                "norm1": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
                "norm2": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
                "attn": init_attn(r[2], cfg),
                "mlp": init_mlp(r[3], cfg.d_model, cfg.d_ff),
            },
            "final_norm": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "lm_head": {"w": dense_init(r[4], (cfg.d_model, _padded_vocab(cfg, plan)))},
        }
        return params

    def _split_groups(layers):
        head = jax.tree.map(lambda a: a[:covered].reshape(
            (n_apps, every) + a.shape[1:]), layers)
        tail = jax.tree.map(lambda a: a[covered:], layers)
        return head, tail

    def _ssm_layers(x, stacked, remat_mode):
        def body(xc, lp):
            xc = cx(xc)
            h = rms_norm(xc, lp["norm1"]["scale"], cfg.rms_eps)
            y = ssm_lib.ssm_block(lp["ssm"], h, cfg, dtype, plan=plan)
            y = checkpoint_name(y, "block_out")
            return xc + y, None
        x, _ = jax.lax.scan(_remat(body, remat_mode), x, stacked)
        return x

    cq, ckv = _seq_constrainers(plan, mesh, batch_axes)
    cx = _residual_constrainer(mesh, batch_axes)

    def _shared_attn_fwd(sp, x, positions):
        h = rms_norm(x, sp["norm1"]["scale"], cfg.rms_eps)
        q, k, v = qkv_proj(sp["attn"], h, cfg, dtype)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        q, k, v = cq(q), ckv(k), ckv(v)
        a = cq(attention(q, k, v, causal=True, window=cfg.sliding_window,
                         impl=plan.attn_impl))
        x = x + a.reshape(x.shape[0], x.shape[1], -1) @ sp["attn"]["wo"].astype(dtype)
        h = rms_norm(x, sp["norm2"]["scale"], cfg.rms_eps)
        return x + mlp_block(sp["mlp"], h, dtype)

    def forward(params, batch):
        tokens = batch["tokens"]
        x = _embed(params, tokens, cfg, dtype)
        positions = jnp.arange(tokens.shape[1])
        head, tail = _split_groups(params["layers"])
        sp = params["shared_attn"]

        def group(xc, gp):
            xc = _ssm_layers(xc, gp, plan.remat)
            xc = _shared_attn_fwd(sp, xc, positions)
            return xc, None

        x, _ = jax.lax.scan(group, x, head)
        if rest:
            x = _ssm_layers(x, tail, plan.remat)
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        return _logits(params, x, cfg, dtype), jnp.float32(0.0)

    def init_cache(batch: int, max_seq: int):
        one = ssm_lib.init_ssm_cache(cfg, batch, dtype)
        ssm_cache = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "ssm": ssm_cache,
            "attn_k": jnp.zeros((n_apps, batch, max_seq, hkv, hd), dtype),
            "attn_v": jnp.zeros((n_apps, batch, max_seq, hkv, hd), dtype),
        }

    def decode_step(params, cache, tokens, pos):
        from repro.serve.attention import decode_attention  # noqa: PLC0415
        x = _embed(params, tokens, cfg, dtype)               # (B, d)
        positions = jnp.asarray(pos)[None]
        sp = params["shared_attn"]
        head, tail = _split_groups(params["layers"])
        c_head, c_tail = _split_groups(cache["ssm"])

        def ssm_body(x, xs):
            lp, c = xs
            h = rms_norm(x, lp["norm1"]["scale"], cfg.rms_eps)
            y, c = ssm_lib.ssm_step(lp["ssm"], h, c, cfg, dtype)
            return x + y, c

        def shared_step(x, kc, vc):
            xs = x[:, None, :]
            h = rms_norm(xs, sp["norm1"]["scale"], cfg.rms_eps)
            q, k, v = qkv_proj(sp["attn"], h, cfg, dtype)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            a, kc, vc = decode_attention(q, kc, vc, k, v, pos,
                                         window=cfg.sliding_window,
                                         mesh=mesh, batch_axes=batch_axes)
            xs = xs + a.reshape(a.shape[0], 1, -1) @ sp["attn"]["wo"].astype(dtype)
            h = rms_norm(xs, sp["norm2"]["scale"], cfg.rms_eps)
            xs = xs + mlp_block(sp["mlp"], h, dtype)
            return xs[:, 0, :], kc, vc

        def group(x, xs):
            gp, gc, kc, vc = xs
            x, gc = jax.lax.scan(ssm_body, x, (gp, gc))
            x, kc, vc = shared_step(x, kc, vc)
            return x, (gc, kc, vc)

        x, (new_head, ks, vs) = jax.lax.scan(
            group, x, (head, c_head, cache["attn_k"], cache["attn_v"]))
        if rest:
            x, new_tail = jax.lax.scan(ssm_body, x, (tail, c_tail))
        else:
            new_tail = c_tail
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        new_ssm = jax.tree.map(
            lambda h, t: jnp.concatenate(
                [h.reshape((covered,) + h.shape[2:]), t], axis=0),
            new_head, new_tail)
        logits = _logits(params, x, cfg, dtype)
        return logits, {"ssm": new_ssm, "attn_k": ks, "attn_v": vs}

    return Model(cfg, init, forward, init_cache, decode_step)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper): frame-embedding frontend stub + cross attention

def build_enc_dec(cfg: ModelConfig, plan: Optional[ParallelPlan] = None,
                  mesh=None, batch_axes=("data",)) -> Model:
    plan = plan or ParallelPlan()
    dtype = jnp.dtype(plan.compute_dtype)
    impl = plan.attn_impl

    def init_enc_layer(rng):
        r = split_tree(rng, 2)
        return {
            "norm1": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "norm2": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "attn": init_attn(r[0], cfg),
            "mlp": init_mlp(r[1], cfg.d_model, cfg.d_ff),
        }

    def init_dec_layer(rng):
        r = split_tree(rng, 3)
        return {
            "norm1": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "norm2": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "norm3": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "attn": init_attn(r[0], cfg),
            "xattn": init_attn(r[1], cfg),
            "mlp": init_mlp(r[2], cfg.d_model, cfg.d_ff),
        }

    def init(rng):
        r = split_tree(rng, 4)
        return {
            "embed": {"tok": dense_init(r[0], (_padded_vocab(cfg, plan), cfg.d_model), in_axis=-1)},
            "encoder": {
                "layers": _stacked_init(r[1], cfg.enc_layers, init_enc_layer),
                "final_norm": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            },
            "layers": _stacked_init(r[2], cfg.n_layers, init_dec_layer),
            "final_norm": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
            "lm_head": {"w": dense_init(r[3], (cfg.d_model, _padded_vocab(cfg, plan)))},
        }

    cq, ckv = _seq_constrainers(plan, mesh, batch_axes)
    cx = _residual_constrainer(mesh, batch_axes)

    def encode(params, frames):
        x = frames.astype(dtype)
        x = x + sinusoidal_pos_emb(jnp.arange(x.shape[1]), cfg.d_model).astype(dtype)

        def body(xc, lp):
            xc = cx(xc)
            h = rms_norm(xc, lp["norm1"]["scale"], cfg.rms_eps)
            q, k, v = qkv_proj(lp["attn"], h, cfg, dtype)
            a = attention(q, k, v, causal=False, impl=impl)
            a = checkpoint_name(
                a.reshape(xc.shape[0], xc.shape[1], -1) @ lp["attn"]["wo"].astype(dtype),
                "attn_out")
            xc = xc + a
            h = rms_norm(xc, lp["norm2"]["scale"], cfg.rms_eps)
            return xc + mlp_block(lp["mlp"], h, dtype), None

        x, _ = jax.lax.scan(_remat(body, plan.remat), x, params["encoder"]["layers"])
        return rms_norm(x, params["encoder"]["final_norm"]["scale"], cfg.rms_eps)

    def _xattn(lp, x, enc_kv):
        b, s = x.shape[:2]
        hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        q = (x @ lp["xattn"]["wq"].astype(dtype)).reshape(b, s, hq, hd)
        k, v = enc_kv
        a = attention(q, k, v, causal=False, impl=impl)
        return a.reshape(b, s, -1) @ lp["xattn"]["wo"].astype(dtype)

    def _enc_kv(lp, enc_out):
        b, f = enc_out.shape[:2]
        hd, hkv = cfg.head_dim, cfg.n_kv_heads
        k = (enc_out @ lp["xattn"]["wk"].astype(dtype)).reshape(b, f, hkv, hd)
        v = (enc_out @ lp["xattn"]["wv"].astype(dtype)).reshape(b, f, hkv, hd)
        return k, v

    def forward(params, batch):
        enc_out = encode(params, batch["frames"])
        tokens = batch["tokens"]
        x = _embed(params, tokens, cfg, dtype)
        x = x + sinusoidal_pos_emb(jnp.arange(tokens.shape[1]),
                                   cfg.d_model).astype(dtype)

        def body(xc, lp):
            xc = cx(xc)
            h = rms_norm(xc, lp["norm1"]["scale"], cfg.rms_eps)
            q, k, v = qkv_proj(lp["attn"], h, cfg, dtype)
            q, k, v = cq(q), ckv(k), ckv(v)
            a = cq(attention(q, k, v, causal=True, impl=impl))
            a = checkpoint_name(
                a.reshape(xc.shape[0], xc.shape[1], -1) @ lp["attn"]["wo"].astype(dtype),
                "attn_out")
            xc = xc + a
            h = rms_norm(xc, lp["norm2"]["scale"], cfg.rms_eps)
            xc = xc + _xattn(lp, h, _enc_kv(lp, enc_out))
            h = rms_norm(xc, lp["norm3"]["scale"], cfg.rms_eps)
            return xc + mlp_block(lp["mlp"], h, dtype), None

        x, _ = jax.lax.scan(_remat(body, plan.remat), x, params["layers"])
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        return _logits(params, x, cfg, dtype), jnp.float32(0.0)

    def init_cache(batch: int, max_seq: int):
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, hkv, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, hkv, hd), dtype),
            "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, hkv, hd), dtype),
            "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, hkv, hd), dtype),
        }

    def decode_step(params, cache, tokens, pos):
        from repro.serve.attention import decode_attention  # noqa: PLC0415
        b = tokens.shape[0]
        x = _embed(params, tokens, cfg, dtype)[:, None, :]
        positions = jnp.asarray(pos)[None]
        x = x + sinusoidal_pos_emb(positions, cfg.d_model).astype(dtype)[None]

        def body(x, xs):
            lp, kc, vc, xk, xv = xs
            h = rms_norm(x, lp["norm1"]["scale"], cfg.rms_eps)
            q, k, v = qkv_proj(lp["attn"], h, cfg, dtype)
            a, kc, vc = decode_attention(q, kc, vc, k, v, pos,
                                         mesh=mesh, batch_axes=batch_axes)
            x = x + a.reshape(b, 1, -1) @ lp["attn"]["wo"].astype(dtype)
            h = rms_norm(x, lp["norm2"]["scale"], cfg.rms_eps)
            x = x + _xattn(lp, h, (xk, xv))
            h = rms_norm(x, lp["norm3"]["scale"], cfg.rms_eps)
            return x + mlp_block(lp["mlp"], h, dtype), (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
        logits = _logits(params, x[:, 0, :], cfg, dtype)
        new_cache = dict(cache, k=ks, v=vs)
        return logits, new_cache

    def fill_cross(params, cache, frames):
        """Run the encoder and populate the cross-attention K/V cache."""
        enc_out = encode(params, frames)

        def per_layer(_, lp):
            return None, _enc_kv(lp, enc_out)

        _, (xk, xv) = jax.lax.scan(per_layer, None, params["layers"])
        return dict(cache, cross_k=xk, cross_v=xv)

    return Model(cfg, init, forward, init_cache, decode_step,
                 extras={"encode": encode, "fill_cross": fill_cross})


# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig, plan: Optional[ParallelPlan] = None,
                mesh=None, batch_axes=("data",)) -> Model:
    if plan is not None:
        plan.validate(cfg)
    if cfg.family == Family.SSM:
        return build_ssm(cfg, plan, mesh, batch_axes)
    if cfg.family == Family.HYBRID:
        return build_hybrid(cfg, plan, mesh, batch_axes)
    if cfg.is_enc_dec:
        return build_enc_dec(cfg, plan, mesh, batch_axes)
    return build_decoder_only(cfg, plan, mesh, batch_axes)
