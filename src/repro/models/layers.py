"""Shared transformer building blocks (pure functional JAX).

Naming convention matters: leaf names (``wq``, ``wo``, ``gate``, ``down``, ...)
drive the sharding-rule engine in ``repro.core.sharding``.

Attention comes in three exact implementations (survey §5.1.1):

- :func:`attention_direct` — materializes the score matrix; fine for short seqs.
- :func:`attention_blockwise` — Rabe–Staats / FlashAttention-style online-softmax
  scan over KV blocks; O(S·B_k) live memory, used for 32k/500k sequences. This is
  the pure-JAX oracle twin (forward and gradient) of the fused kernel.
- ``repro.kernels.flash_attention`` — fused differentiable Pallas kernel.

:func:`attention` routes between them via ``repro.kernels.dispatch``
(``ParallelPlan.attn_impl``).

Both support GQA (grouped queries, never materializing repeated KV), causal and
sliding-window masks (gemma2 local/global alternation), attention-logit softcap,
and a query position offset (for decode / chunked prefill).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers

def dense_init(rng, shape, in_axis=-2):
    fan_in = shape[in_axis]
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            / np.sqrt(fan_in))


def split_tree(rng, n):
    return list(jax.random.split(rng, n))


# ---------------------------------------------------------------------------
# norms / embeddings

def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def sinusoidal_pos_emb(positions, dim, max_timescale=10_000.0):
    """(..., ) int positions -> (..., dim) sinusoidal embeddings (whisper-style)."""
    half = dim // 2
    freqs = jnp.exp(-np.log(max_timescale) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    args = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


def rope(x, positions, theta=10_000.0):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masking

def attn_mask(q_pos, k_pos, *, causal: bool, window: int | jax.Array):
    """Boolean mask (True = attend). q_pos: (S,), k_pos: (T,). ``window`` may be a
    traced scalar (gemma2 alternation selects it per layer inside a scan)."""
    i = q_pos[:, None]
    j = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= j <= i
    if isinstance(window, jax.Array) or window:
        w = jnp.asarray(window)
        m &= jnp.where(w > 0, (i - j) < w, True)
    return m


def _softcap(s, cap):
    if isinstance(cap, (int, float)) and cap == 0.0:
        return s
    return cap * jnp.tanh(s / cap)


# ---------------------------------------------------------------------------
# attention

def _group_q(q, n_kv):
    """(B, S, Hq, hd) -> (B, S, Hkv, G, hd)."""
    b, s, hq, hd = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, hd)


def attention_direct(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
                     scale: Optional[float] = None):
    """q: (B,S,Hq,hd), k/v: (B,T,Hkv,hd) -> (B,S,Hq,hd). Materializes scores."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    qg = _group_q(q, hkv)
    # scores: (B, Hkv, G, S, T) in fp32
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    q_pos = q_offset + jnp.arange(s)
    mask = attn_mask(q_pos, jnp.arange(t), causal=causal, window=window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, hd)


def attention_blockwise(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
                        block_size=1024, scale: Optional[float] = None,
                        kv_len: Optional[int] = None):
    """Online-softmax scan over KV blocks; exact, O(S·block) live memory.

    ``kv_len`` masks keys at positions >= kv_len — callers pad unaligned KV to
    the block boundary (see repro.kernels.dispatch) and pass the true length.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    assert t % block_size == 0, (t, block_size)
    nb = t // block_size
    scale = scale if scale is not None else hd ** -0.5
    g = hq // hkv
    qg = _group_q(q, hkv)
    q_pos = q_offset + jnp.arange(s)

    kb = k.reshape(b, nb, block_size, hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block_size, hkv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        m, l, o = carry
        blk_idx, k_blk, v_blk = inputs
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk,
                            preferred_element_type=jnp.float32) * scale
        scores = _softcap(scores, softcap)
        k_pos = blk_idx * block_size + jnp.arange(block_size)
        mask = attn_mask(q_pos, k_pos, causal=causal, window=window)
        if kv_len is not None and kv_len < t:
            mask &= (k_pos < kv_len)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None]) * mask[None, None, None]
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        o_new = o * corr[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    o0 = jnp.zeros((b, hkv, g, s, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0),
                                (jnp.arange(nb), kb, vb))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, hd).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
              block_size=1024, scale: Optional[float] = None,
              impl: str = "auto"):
    """Dispatch to the best implementation for this call site.

    ``impl`` follows ``ParallelPlan.attn_impl`` ("auto" | "xla" | "pallas");
    the rules live in :mod:`repro.kernels.dispatch`.
    """
    # lazy import: kernels.ref imports this module at load time
    from repro.kernels.dispatch import dispatch_attention  # noqa: PLC0415
    return dispatch_attention(q, k, v, impl=impl, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset,
                              block_size=block_size, scale=scale)


# ---------------------------------------------------------------------------
# attention block (projections + rope + attention)

def init_attn(rng, cfg, d_model=None):
    d = d_model or cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    r = split_tree(rng, 4)
    p = {
        "wq": dense_init(r[0], (d, hq * hd)),
        "wk": dense_init(r[1], (d, hkv * hd)),
        "wv": dense_init(r[2], (d, hkv * hd)),
        "wo": dense_init(r[3], (hq * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def qkv_proj(p, x, cfg, dtype):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return (q.reshape(b, s, hq, hd), k.reshape(b, s, hkv, hd),
            v.reshape(b, s, hkv, hd))


def attn_block(p, x, cfg, *, positions, window=0, causal=True, dtype=jnp.bfloat16,
               use_rope=True, impl="auto"):
    """Full attention sub-block: qkv proj + rope + attention + output proj."""
    q, k, v = qkv_proj(p, x, cfg, dtype)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    out = attention(q, k, v, causal=causal, window=window,
                    softcap=cfg.attn_logit_softcap, impl=impl)
    b, s = x.shape[:2]
    return out.reshape(b, s, -1) @ p["wo"].astype(dtype)


def attn_sublayer_tp(lp, x, cfg, ctx, *, positions, window=0,
                     dtype=jnp.bfloat16, impl="auto"):
    """Sequence-sharded attention sub-block for overlap TP (survey §4.1.2/4).

    ``x``: (B, S/tp, d) sequence shard; ``lp`` holds this rank's head shards
    (wq/wk/wv column-sharded, wo row-sharded — the shard_map in_specs from
    ``core.sharding.overlap_param_specs`` deliver them pre-sliced). The ring
    all-gather that re-materializes the full sequence is fused into the QKV
    GEMM ticks; attention runs on this rank's head group through the usual
    dispatcher (so ``attn_impl="pallas"`` composes); the output projection
    ring-reduce-scatters back to the (B, S/tp, d) shard.
    """
    from repro.train.tensor_parallel import (  # noqa: PLC0415 (import cycle)
        all_gather_matmul, matmul_reduce_scatter)
    b, s_loc, _ = x.shape
    s = s_loc * ctx.size
    hd = cfg.head_dim
    ws = (lp["wq"].astype(dtype), lp["wk"].astype(dtype),
          lp["wv"].astype(dtype))
    (q, k, v), _ = all_gather_matmul(ctx, x, ws)
    if cfg.qkv_bias:
        idx = jax.lax.axis_index(ctx.axis)

        def bias(name, n_loc):
            return jax.lax.dynamic_slice_in_dim(
                lp[name].astype(dtype), idx * n_loc, n_loc, 0)
        q = q + bias("bq", q.shape[-1])
        k = k + bias("bk", k.shape[-1])
        v = v + bias("bv", v.shape[-1])
    q = q.reshape(b, s, q.shape[-1] // hd, hd)
    k = k.reshape(b, s, k.shape[-1] // hd, hd)
    v = v.reshape(b, s, v.shape[-1] // hd, hd)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    a = attention(q, k, v, causal=True, window=window,
                  softcap=cfg.attn_logit_softcap, impl=impl)
    return matmul_reduce_scatter(ctx, a.reshape(b, s, -1),
                                 lp["wo"].astype(dtype))


def mlp_sublayer_tp(p, x, ctx, dtype=jnp.bfloat16):
    """Sequence-sharded SwiGLU for overlap TP: one ring all-gather fused into
    both the gate and up GEMM ticks, ring reduce-scatter after down."""
    from repro.train.tensor_parallel import (  # noqa: PLC0415 (import cycle)
        all_gather_matmul, matmul_reduce_scatter)
    (g, u), _ = all_gather_matmul(
        ctx, x, (p["gate"].astype(dtype), p["up"].astype(dtype)))
    return matmul_reduce_scatter(ctx, jax.nn.silu(g) * u,
                                 p["down"].astype(dtype))


# ---------------------------------------------------------------------------
# MLP (SwiGLU)

def init_mlp(rng, d_model, d_ff):
    r = split_tree(rng, 3)
    return {
        "gate": dense_init(r[0], (d_model, d_ff)),
        "up": dense_init(r[1], (d_model, d_ff)),
        "down": dense_init(r[2], (d_ff, d_model)),
    }


def mlp_block(p, x, dtype=jnp.bfloat16):
    h = jax.nn.silu(x @ p["gate"].astype(dtype)) * (x @ p["up"].astype(dtype))
    return h @ p["down"].astype(dtype)
