"""Shared transformer building blocks (pure functional JAX).

Naming convention matters: leaf names (``wq``, ``wo``, ``gate``, ``down``, ...)
drive the sharding-rule engine in ``repro.core.sharding``.

Attention comes in three exact implementations (survey §5.1.1):

- :func:`attention_direct` — materializes the score matrix; fine for short seqs.
- :func:`attention_blockwise` — Rabe–Staats / FlashAttention-style online-softmax
  scan over KV blocks; O(S·B_k) live memory, used for 32k/500k sequences. This is
  the pure-JAX oracle twin (forward and gradient) of the fused kernel.
- ``repro.kernels.flash_attention`` — fused differentiable Pallas kernel.

:func:`attention` routes between them via ``repro.kernels.dispatch``
(``ParallelPlan.attn_impl``).

Both support GQA (grouped queries, never materializing repeated KV), causal and
sliding-window masks (gemma2 local/global alternation), attention-logit softcap,
and a query position offset (for decode / chunked prefill).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers

def dense_init(rng, shape, in_axis=-2):
    fan_in = shape[in_axis]
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            / np.sqrt(fan_in))


def split_tree(rng, n):
    return list(jax.random.split(rng, n))


# ---------------------------------------------------------------------------
# norms / embeddings

def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def sinusoidal_pos_emb(positions, dim, max_timescale=10_000.0):
    """(..., ) int positions -> (..., dim) sinusoidal embeddings (whisper-style)."""
    half = dim // 2
    freqs = jnp.exp(-np.log(max_timescale) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    args = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


def rope(x, positions, theta=10_000.0):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masking

def attn_mask(q_pos, k_pos, *, causal: bool, window: int | jax.Array):
    """Boolean mask (True = attend). q_pos: (S,), k_pos: (T,). ``window`` may be a
    traced scalar (gemma2 alternation selects it per layer inside a scan)."""
    i = q_pos[:, None]
    j = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= j <= i
    if isinstance(window, jax.Array) or window:
        w = jnp.asarray(window)
        m &= jnp.where(w > 0, (i - j) < w, True)
    return m


def _softcap(s, cap):
    if isinstance(cap, (int, float)) and cap == 0.0:
        return s
    return cap * jnp.tanh(s / cap)


# ---------------------------------------------------------------------------
# attention

def _group_q(q, n_kv):
    """(B, S, Hq, hd) -> (B, S, Hkv, G, hd)."""
    b, s, hq, hd = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, hd)


def attention_direct(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
                     scale: Optional[float] = None):
    """q: (B,S,Hq,hd), k/v: (B,T,Hkv,hd) -> (B,S,Hq,hd). Materializes scores."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    qg = _group_q(q, hkv)
    # scores: (B, Hkv, G, S, T) in fp32
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    q_pos = q_offset + jnp.arange(s)
    mask = attn_mask(q_pos, jnp.arange(t), causal=causal, window=window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, hd)


def attention_direct_lse(q, k, v, *, causal=True, window=0, softcap=0.0,
                         q_offset=0, scale: Optional[float] = None):
    """:func:`attention_direct` twin that also returns the per-row logsumexp.

    The XLA oracle of the lse-merging chunk entry (ring context parallelism):
    returns (out (B,S,Hq,hd), lse (B,S,Hq) fp32). Fully-masked rows report a
    finite ``lse ≈ NEG_INF`` so they drop out of the cross-chunk merge.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    g = hq // hkv
    qg = _group_q(q, hkv)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    q_pos = q_offset + jnp.arange(s)
    mask = attn_mask(q_pos, jnp.arange(t), causal=causal, window=window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    m = scores.max(axis=-1)                                  # (b, kv, g, s)
    p = jnp.exp(scores - m[..., None]) * mask[None, None, None]
    l = p.sum(axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return (out.reshape(b, s, hq, hd).astype(q.dtype),
            lse.transpose(0, 3, 1, 2).reshape(b, s, hq))


def attention_chunk_grads(q, k, v, do, lse, delta, *, causal=True, window=0,
                          softcap=0.0, q_offset=0,
                          scale: Optional[float] = None):
    """One KV chunk's (dq, dk, dv) against externally merged softmax stats.

    XLA twin of :func:`repro.kernels.flash_attention.flash_attention_bwd`:
    ``lse``/``delta`` (B, S, Hq) come from the *merged* softmax (ring context
    parallelism merges them across KV chunks), so
    ``p = exp(s - lse)`` is each pair's share of the global attention and the
    returned gradients are exactly this chunk's contribution. All math fp32.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    g = hq // hkv
    qg = _group_q(q, hkv).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dog = _group_q(do, hkv).astype(jnp.float32)
    s_raw = jnp.einsum("bskgd,btkd->bkgst", qg, kf,
                       preferred_element_type=jnp.float32) * scale
    if isinstance(softcap, (int, float)) and softcap:
        th = jnp.tanh(s_raw / softcap)
        s_c = softcap * th
    else:
        th = None
        s_c = s_raw
    mask = attn_mask(q_offset + jnp.arange(s), jnp.arange(t), causal=causal,
                     window=window)[None, None, None]
    lse_g = lse.reshape(b, s, hkv, g).transpose(0, 2, 3, 1)   # (b, kv, g, s)
    delta_g = delta.reshape(b, s, hkv, g).transpose(0, 2, 3, 1)
    # where() before exp: fully-masked rows carry lse ≈ NEG_INF and the
    # subtraction would overflow before the mask zeros it
    p = jnp.exp(jnp.where(mask, s_c - lse_g[..., None], NEG_INF))
    dp = jnp.einsum("bskgd,btkd->bkgst", dog, vf,
                    preferred_element_type=jnp.float32)
    ds = p * (dp - delta_g[..., None])
    if th is not None:
        ds = ds * (1.0 - th * th)
    dq = (jnp.einsum("bkgst,btkd->bskgd", ds, kf) * scale).reshape(
        b, s, hq, hd)
    dk = jnp.einsum("bkgst,bskgd->btkd", ds, qg) * scale
    dv = jnp.einsum("bkgst,bskgd->btkd", p, dog)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def attention_blockwise(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
                        block_size=1024, scale: Optional[float] = None,
                        kv_len: Optional[int] = None, return_lse: bool = False):
    """Online-softmax scan over KV blocks; exact, O(S·block) live memory.

    ``kv_len`` masks keys at positions >= kv_len — callers pad unaligned KV to
    the block boundary (see repro.kernels.dispatch) and pass the true length.
    ``return_lse`` additionally returns the per-row logsumexp (B, S, Hq) — the
    streaming twin of :func:`attention_direct_lse` for long ring-cp chunks.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    assert t % block_size == 0, (t, block_size)
    nb = t // block_size
    scale = scale if scale is not None else hd ** -0.5
    g = hq // hkv
    qg = _group_q(q, hkv)
    q_pos = q_offset + jnp.arange(s)

    kb = k.reshape(b, nb, block_size, hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block_size, hkv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        m, l, o = carry
        blk_idx, k_blk, v_blk = inputs
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk,
                            preferred_element_type=jnp.float32) * scale
        scores = _softcap(scores, softcap)
        k_pos = blk_idx * block_size + jnp.arange(block_size)
        mask = attn_mask(q_pos, k_pos, causal=causal, window=window)
        if kv_len is not None and kv_len < t:
            mask &= (k_pos < kv_len)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None]) * mask[None, None, None]
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        o_new = o * corr[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    o0 = jnp.zeros((b, hkv, g, s, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0),
                                (jnp.arange(nb), kb, vb))
    out = o / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, hd).astype(q.dtype)
    if return_lse:
        lse = m + jnp.log(jnp.maximum(l, 1e-30))              # (b, kv, g, s)
        return out, lse.transpose(0, 3, 1, 2).reshape(b, s, hq)
    return out


def attention(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
              block_size=1024, scale: Optional[float] = None,
              impl: str = "auto"):
    """Dispatch to the best implementation for this call site.

    ``impl`` follows ``ParallelPlan.attn_impl`` ("auto" | "xla" | "pallas");
    the rules live in :mod:`repro.kernels.dispatch`.
    """
    # lazy import: kernels.ref imports this module at load time
    from repro.kernels.dispatch import dispatch_attention  # noqa: PLC0415
    return dispatch_attention(q, k, v, impl=impl, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset,
                              block_size=block_size, scale=scale)


# ---------------------------------------------------------------------------
# attention block (projections + rope + attention)

def init_attn(rng, cfg, d_model=None):
    d = d_model or cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    r = split_tree(rng, 4)
    p = {
        "wq": dense_init(r[0], (d, hq * hd)),
        "wk": dense_init(r[1], (d, hkv * hd)),
        "wv": dense_init(r[2], (d, hkv * hd)),
        "wo": dense_init(r[3], (hq * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def qkv_proj(p, x, cfg, dtype):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return (q.reshape(b, s, hq, hd), k.reshape(b, s, hkv, hd),
            v.reshape(b, s, hkv, hd))


def attn_block(p, x, cfg, *, positions, window=0, causal=True, dtype=jnp.bfloat16,
               use_rope=True, impl="auto"):
    """Full attention sub-block: qkv proj + rope + attention + output proj."""
    q, k, v = qkv_proj(p, x, cfg, dtype)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    out = attention(q, k, v, causal=causal, window=window,
                    softcap=cfg.attn_logit_softcap, impl=impl)
    b, s = x.shape[:2]
    return out.reshape(b, s, -1) @ p["wo"].astype(dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)

def init_mlp(rng, d_model, d_ff):
    r = split_tree(rng, 3)
    return {
        "gate": dense_init(r[0], (d_model, d_ff)),
        "up": dense_init(r[1], (d_model, d_ff)),
        "down": dense_init(r[2], (d_ff, d_model)),
    }


def mlp_block(p, x, dtype=jnp.bfloat16):
    h = jax.nn.silu(x @ p["gate"].astype(dtype)) * (x @ p["up"].astype(dtype))
    return h @ p["down"].astype(dtype)
