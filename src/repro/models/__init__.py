from .families import Model, build_model
from . import layers, moe, ssm, families

__all__ = ["Model", "build_model", "layers", "moe", "ssm", "families"]
