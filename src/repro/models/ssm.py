"""Mamba2 — State Space Duality (SSD) layer [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like compute
inside fixed-size chunks, linear state recurrence across chunks (a ``lax.scan``).
Decode uses the O(1) recurrent step form with a conv rolling buffer.

TP note (DESIGN.md §2): the fused in_proj of the reference CUDA implementation is
split into separate per-stream projections (``wz/wx/wB/wC/wdt``) so each output
dim shards cleanly on the ``model`` axis without cutting across stream
boundaries — the TPU/GSPMD-native layout.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from .layers import dense_init, rms_norm, split_tree


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.n_groups, s.d_state


def init_ssm(rng, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di, nh, g, n = ssm_dims(cfg)
    r = split_tree(rng, 8)
    # A init in [1, 16) as in the reference implementation
    a = jax.random.uniform(r[5], (nh,), jnp.float32, 1.0, 16.0)
    return {
        "wz": dense_init(r[0], (d, di)),
        "wx": dense_init(r[1], (d, di)),
        "wB": dense_init(r[2], (d, g * n)),
        "wC": dense_init(r[3], (d, g * n)),
        "wdt": dense_init(r[4], (d, nh)),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            r[6], (nh,), jnp.float32, np.log(1e-3), np.log(1e-1))))),
        "A_log": jnp.log(a),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_x": jnp.zeros((di, s.d_conv), jnp.float32),
        "conv_B": jnp.zeros((g * n, s.d_conv), jnp.float32),
        "conv_C": jnp.zeros((g * n, s.d_conv), jnp.float32),
        "scale": jnp.zeros((di,), jnp.float32),     # gated RMSNorm weight
        "out_proj": dense_init(r[7], (di, d)),
    }


def _causal_conv(x, w, dtype, left=None):
    """Depthwise causal conv1d. x: (B, L, C), w: (C, K).

    ``left`` (B, K-1, C) replaces the zero left-padding with real context —
    the context-parallel executor passes the previous cp rank's halo so the
    conv is seamless across sequence shards.
    """
    k = w.shape[-1]
    if left is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([left.astype(x.dtype), x], axis=1)
    # windowed sum: out[:, t, c] = sum_j x[:, t+j, c] * w[c, j]
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1], :] * w[None, None, :, j].astype(dtype)
    return out


def _segsum(x):
    """x: (..., q) -> (..., q, q) with out[i, j] = sum_{k=j+1..i} x[k]; -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD. Shapes:
      x: (b, l, h, p)   dt: (b, l, h)   A: (h,) negative   B, C: (b, l, g, n)
    Returns (y: (b, l, h, p), final_state: (b, h, p, n)).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, (l, chunk)
    c, q = l // chunk, chunk
    hpg = h // g

    xd = (x * dt[..., None]).astype(jnp.float32)             # discretized input
    dA = (dt * A).astype(jnp.float32)                        # (b, l, h) log-decays

    # chunked views
    xc = xd.reshape(b, c, q, g, hpg, p)
    Bc = B.reshape(b, c, q, g, n).astype(jnp.float32)
    Cc = C.reshape(b, c, q, g, n).astype(jnp.float32)
    dAc = dA.reshape(b, c, q, h).transpose(0, 1, 3, 2)       # (b, c, h, q)
    dA_cs = jnp.cumsum(dAc, axis=-1)                          # (b, c, h, q)

    # 1) intra-chunk (diagonal blocks): attention-like with decay kernel
    Ldec = jnp.exp(_segsum(dAc))                              # (b, c, h, q, q)
    Ldec = Ldec.reshape(b, c, g, hpg, q, q)
    y_diag = jnp.einsum("bcqgn,bckgn,bcghqk,bckghp->bcqghp", Cc, Bc, Ldec, xc)

    # 2) chunk-final states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)           # (b, c, h, q)
    ds = decay_states.reshape(b, c, g, hpg, q)
    states = jnp.einsum("bckgn,bcghk,bckghp->bcghpn", Bc, ds, xc)  # (b,c,g,hpg,p,n)
    states = states.reshape(b, c, h, p, n)

    # 3) inter-chunk recurrence (lax.scan over chunks)
    chunk_decay = jnp.exp(dA_cs[..., -1])                     # (b, c, h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                         # (b,h,p,n), (b,h)
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev                                      # emit state *entering* chunk

    states_t = states.transpose(1, 0, 2, 3, 4)                # (c, b, h, p, n)
    decay_t = chunk_decay.transpose(1, 0, 2)                  # (c, b, h)
    final, prev_states = jax.lax.scan(step, s0, (states_t, decay_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b, c, h, p, n)

    # 4) contribution of carried-in state to each position
    state_decay = jnp.exp(dA_cs)                              # (b, c, h, q)
    sd = state_decay.reshape(b, c, g, hpg, q)
    pv = prev_states.reshape(b, c, g, hpg, p, n)
    y_off = jnp.einsum("bcqgn,bcghpn,bcghq->bcqghp", Cc, pv, sd)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def ssm_block(p, x, cfg: ModelConfig, dtype, initial_state=None, plan=None):
    """Full Mamba2 block forward. x: (B, L, d) -> (B, L, d).

    The SSD scan runs through :func:`repro.kernels.dispatch.dispatch_ssd_scan`
    (``impl = plan.ssm_impl``): the fused Pallas kernel keeps decay matrices
    in VMEM in both passes; the XLA twin is this module's :func:`ssd_scan`.
    Unaligned lengths are padded to the chunk boundary by the dispatcher —
    never collapsed into one whole-sequence chunk with an O(L²) decay matrix.
    """
    from repro.kernels.dispatch import dispatch_ssd_scan  # noqa: PLC0415

    s = cfg.ssm
    di, nh, g, n = ssm_dims(cfg)
    b, l, d = x.shape

    z = x @ p["wz"].astype(dtype)
    xin = x @ p["wx"].astype(dtype)
    Bv = x @ p["wB"].astype(dtype)
    Cv = x @ p["wC"].astype(dtype)
    dt = jax.nn.softplus((x @ p["wdt"].astype(dtype)).astype(jnp.float32)
                         + p["dt_bias"])                      # (b, l, nh)

    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"], dtype))
    Bv = jax.nn.silu(_causal_conv(Bv, p["conv_B"], dtype))
    Cv = jax.nn.silu(_causal_conv(Cv, p["conv_C"], dtype))

    A = -jnp.exp(p["A_log"])                                  # (nh,)
    xh = xin.reshape(b, l, nh, s.head_dim)
    y, _ = dispatch_ssd_scan(
        xh, dt, A, Bv.reshape(b, l, g, n), Cv.reshape(b, l, g, n),
        chunk=s.chunk, impl=plan.ssm_impl if plan is not None else "auto",
        initial_state=initial_state)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, l, di).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z), p["scale"], cfg.rms_eps)
    return y @ p["out_proj"].astype(dtype)


# ---------------------------------------------------------------------------
# decode (recurrent step form)

def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    s = cfg.ssm
    di, nh, g, n = ssm_dims(cfg)
    k = s.d_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k, di), dtype),
        "conv_B": jnp.zeros((batch, k, g * n), dtype),
        "conv_C": jnp.zeros((batch, k, g * n), dtype),
        "state": jnp.zeros((batch, nh, s.head_dim, n), jnp.float32),
    }


def _conv_step(cache_row, x_t, w, dtype):
    """cache_row: (B, K-1, C); x_t: (B, C) -> (out (B, C), new cache)."""
    window = jnp.concatenate([cache_row, x_t[:, None, :]], axis=1)   # (B, K, C)
    out = jnp.einsum("bkc,ck->bc", window.astype(dtype), w.astype(dtype))
    return out, window[:, 1:, :]


def ssm_step(p, x_t, cache, cfg: ModelConfig, dtype) -> Tuple[jax.Array, Dict]:
    """One decode step. x_t: (B, d) -> (y (B, d), cache)."""
    s = cfg.ssm
    di, nh, g, n = ssm_dims(cfg)
    bsz = x_t.shape[0]

    z = x_t @ p["wz"].astype(dtype)
    xin = x_t @ p["wx"].astype(dtype)
    Bv = x_t @ p["wB"].astype(dtype)
    Cv = x_t @ p["wC"].astype(dtype)
    dt = jax.nn.softplus((x_t @ p["wdt"].astype(dtype)).astype(jnp.float32)
                         + p["dt_bias"])                      # (B, nh)

    xin, cx = _conv_step(cache["conv_x"], xin, p["conv_x"], dtype)
    Bv, cb = _conv_step(cache["conv_B"], Bv, p["conv_B"], dtype)
    Cv, cc = _conv_step(cache["conv_C"], Cv, p["conv_C"], dtype)
    xin, Bv, Cv = jax.nn.silu(xin), jax.nn.silu(Bv), jax.nn.silu(Cv)

    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                      # (B, nh)
    xh = xin.reshape(bsz, nh, s.head_dim).astype(jnp.float32)
    Bg = Bv.reshape(bsz, g, n).astype(jnp.float32)
    Cg = Cv.reshape(bsz, g, n).astype(jnp.float32)
    hpg = nh // g

    # state: (B, nh, p, n)
    Bh = jnp.repeat(Bg, hpg, axis=1)                          # (B, nh, n)
    Ch = jnp.repeat(Cg, hpg, axis=1)
    new_state = (cache["state"] * dA[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None], Bh))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(bsz, di).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z), p["scale"], cfg.rms_eps)
    y = y @ p["out_proj"].astype(dtype)
    new_cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc, "state": new_state}
    return y, new_cache
