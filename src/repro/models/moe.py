"""Mixture-of-Experts layer (survey §4.1.5).

This module owns the routing machinery (router, capacity-bounded top-k
dispatch in both one-hot-einsum and MegaBlocks-style scatter form) and the
**dense-dispatch** baseline path: GShard-style dispatch/combine with
sharding left to GSPMD propagation from the expert-weight annotations
(experts tensor-parallel inside each expert).

Expert parallelism (``plan.ep > 1``) lives in the unified block executor
(:func:`repro.train.executor.moe_block_ex`): experts shard over the folded
cp × model expert ring (MoE parallel folding — attention keeps its cp/tp
mapping while the MoE sublayer re-reads the same devices as one flat expert
axis) and the dispatch/combine all-to-alls run through
:func:`repro.kernels.dispatch.dispatch_ep_a2a` (blocking or overlapped ring
ticks, ``plan.ep_impl``); :func:`ep_chunk_ffn` here is the per-chunk expert
compute that seam interleaves with the ticks. Both paths share the router
and the capacity/dropping policy, so they are numerically interchangeable
at no-drop capacity (tested in tests/test_expert_parallel.py).

DeepSeek-MoE fine-grained features: ``num_shared_experts`` always-on experts.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.config import ModelConfig
from .layers import dense_init, split_tree


def init_moe(rng, cfg: ModelConfig):
    e = cfg.moe
    d, de = cfg.d_model, e.d_expert
    r = split_tree(rng, 7)
    p = {
        "router": dense_init(r[0], (d, e.num_experts)),
        "experts": {
            "gate": dense_init(r[1], (e.num_experts, d, de), in_axis=-2),
            "up": dense_init(r[2], (e.num_experts, d, de), in_axis=-2),
            "down": dense_init(r[3], (e.num_experts, de, d), in_axis=-2),
        },
    }
    if e.num_shared_experts:
        ds = de * e.num_shared_experts
        p["shared"] = {
            "gate": dense_init(r[4], (d, ds)),
            "up": dense_init(r[5], (d, ds)),
            "down": dense_init(r[6], (ds, d)),
        }
    return p


# ---------------------------------------------------------------------------
# routing

def router_probs(p, x, cfg: ModelConfig, dtype, batch_axes=(), n_dp: int = 1):
    """x: (N, d) -> (probs (N, E) fp32, aux_loss scalar).

    The Switch-Transformer load-balancing aux reduces its density statistics
    as sums / global-count; inside a shard_map whose tokens are data-sharded
    (the overlap-TP path), pass ``batch_axes``/``n_dp`` and the sums psum
    first, reproducing the global mean the GSPMD path computes.
    """
    e = cfg.moe
    logits = (x @ p["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    density_sum = probs.sum(axis=0)                         # (E,)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), e.num_experts)
    proxy_sum = top1.sum(axis=0)
    if batch_axes:
        density_sum = jax.lax.psum(density_sum, batch_axes)
        proxy_sum = jax.lax.psum(proxy_sum, batch_axes)
    n_tot = probs.shape[0] * n_dp
    aux = (e.num_experts
           * jnp.sum((density_sum / n_tot) * (proxy_sum / n_tot))
           * e.aux_loss_coef)
    return probs, aux


def topk_dispatch(probs, cfg: ModelConfig, capacity: int):
    """Capacity-bounded top-k dispatch tensors.

    Returns (dispatch (N, E, C) bool, combine (N, E, C) fp32).
    Tokens overflowing an expert's capacity are dropped (GShard policy).
    """
    e = cfg.moe
    n, E = probs.shape
    top_p, top_idx = jax.lax.top_k(probs, e.top_k)          # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renormalize

    # position of each (token, slot) in its expert's queue
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.int32)    # (N, k, E)
    flat = onehot.reshape(n * e.top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(n, e.top_k, E)
    pos = (pos_in_expert * onehot).sum(-1)                   # (N, k)
    keep = pos < capacity

    eo = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)       # (N, k, E)
    co = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                        dtype=jnp.float32)                   # (N, k, C) (row of zeros if dropped)
    dispatch = jnp.einsum("nke,nkc->nec", eo, co)            # (N, E, C)
    combine = jnp.einsum("nke,nkc,nk->nec", eo, co, top_p)
    return dispatch, combine


def topk_scatter_dispatch(probs, cfg: ModelConfig, capacity: int):
    """Index-based (MegaBlocks-inspired) dispatch: instead of (N, E, C) one-hot
    dispatch/combine einsums, compute each (token, slot) -> capacity-buffer
    index and move activations with gather/scatter. Identical routing semantics
    to :func:`topk_dispatch` (same drops), ~E·C/k less dispatch-tensor traffic.

    Returns (slot (N, k) int32 in [0, E*C] where E*C = dropped, weights (N, k)).
    """
    e = cfg.moe
    n, E = probs.shape
    top_p, top_idx = jax.lax.top_k(probs, e.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.int32)
    flat = onehot.reshape(n * e.top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(n, e.top_k, E)
    pos = (pos_in_expert * onehot).sum(-1)
    keep = pos < capacity
    slot = jnp.where(keep, top_idx * capacity + pos, E * capacity)
    return slot.astype(jnp.int32), top_p


def _scatter_to_buffers(xf, slot, cfg: ModelConfig, capacity: int):
    """(N, d) tokens -> (E, C, d) expert buffers via scatter (trash row E*C)."""
    e = cfg.moe
    n, d = xf.shape
    buf = jnp.zeros((e.num_experts * capacity + 1, d), xf.dtype)
    buf = buf.at[slot.reshape(-1)].set(
        jnp.repeat(xf, e.top_k, axis=0).reshape(n * e.top_k, d))
    return buf[:-1].reshape(e.num_experts, capacity, d)


def _gather_from_buffers(h, slot, weights, dtype):
    """(E, C, d) expert outputs -> (N, d) combined by routing weights."""
    e_c, d = h.shape[0] * h.shape[1], h.shape[2]
    flat = jnp.concatenate([h.reshape(e_c, d),
                            jnp.zeros((1, d), h.dtype)], axis=0)
    n, k = slot.shape
    out = flat[slot.reshape(-1)].reshape(n, k, d)
    return (out * weights[..., None].astype(dtype)).sum(axis=1)


def _group_sizes_from_dispatch(dispatch):
    """(N, E, C) dispatch tensor -> (E,) int32 real-row count per expert."""
    return jax.lax.stop_gradient(dispatch).sum(axis=(0, 2)).astype(jnp.int32)


def _group_sizes_from_slots(slot, num_experts: int, capacity: int):
    """(N, k) capacity-buffer indices -> (E,) int32 real-row count per expert.
    Valid because the scatter dispatch assigns positions compactly per expert
    (rows [0, count) are exactly the filled ones)."""
    kept = slot < num_experts * capacity
    eo = jax.nn.one_hot(jnp.where(kept, slot // capacity, num_experts),
                        num_experts + 1, dtype=jnp.int32)
    return jax.lax.stop_gradient(eo.sum(axis=(0, 1))[:num_experts])


def _expert_ffn(w, h, dtype, impl: str = "auto", group_sizes=None):
    """h: (E, C, d) -> (E, C, d) through per-expert SwiGLU.

    All three GEMMs go through :func:`dispatch_expert_gemm`
    (``impl = plan.moe_gemm_impl``); ``group_sizes`` masks each expert's
    padding rows out of the compute and the gradients (the fused kernel skips
    fully-padded row tiles — the dropless-MoE FLOP saving).
    """
    from repro.kernels.dispatch import dispatch_expert_gemm  # noqa: PLC0415

    g = dispatch_expert_gemm(h, w["gate"].astype(dtype), group_sizes, impl=impl)
    u = dispatch_expert_gemm(h, w["up"].astype(dtype), group_sizes, impl=impl)
    return dispatch_expert_gemm(jax.nn.silu(g) * u, w["down"].astype(dtype),
                                group_sizes, impl=impl)


def ep_chunk_ffn(w, h, *, dtype, impl: str = "auto"):
    """Per-chunk local-expert SwiGLU for :func:`dispatch_ep_a2a`.

    ``h``: (e_loc, C', d) — one ring tick's row block for this rank's local
    experts. Row-wise and shape-polymorphic in C' (the overlap seam's
    contract: per-peer chunk application must equal the concatenated
    buffer), so no ``group_sizes`` prefix masking — post-a2a rows arrive
    blocked per source peer, and padding rows are zero and drop out of the
    GEMMs numerically. Pass via ``functools.partial(ep_chunk_ffn,
    dtype=..., impl=...)`` so the seam's ``custom_vjp`` sees a static
    hashable callable.
    """
    return _expert_ffn(w, h, dtype, impl, None)


# ---------------------------------------------------------------------------
# dense-dispatch path (baseline)

def moe_dense(p, x, cfg: ModelConfig, dtype, dispatch_mode: str = "einsum",
              gemm_impl: str = "auto"):
    """x: (B, S, d) -> (out, aux_loss). GSPMD-sharded local dispatch."""
    e = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    n = b * s
    capacity = max(int(n * e.top_k / e.num_experts * e.capacity_factor), 1)

    probs, aux = router_probs(p, xf, cfg, dtype)
    if dispatch_mode == "scatter":
        slot, wts = topk_scatter_dispatch(probs, cfg, capacity)
        gs = _group_sizes_from_slots(slot, e.num_experts, capacity)
        h = _scatter_to_buffers(xf, slot, cfg, capacity)
        h = _expert_ffn(p["experts"], h, dtype, gemm_impl, gs)
        out = _gather_from_buffers(h, slot, wts, dtype)
    else:
        dispatch, combine = topk_dispatch(probs, cfg, capacity)
        gs = _group_sizes_from_dispatch(dispatch)
        h = jnp.einsum("nec,nd->ecd", dispatch.astype(dtype), xf)
        h = _expert_ffn(p["experts"], h, dtype, gemm_impl, gs)
        out = jnp.einsum("nec,ecd->nd", combine.astype(dtype), h)

    if e.num_shared_experts:
        sh = jax.nn.silu(xf @ p["shared"]["gate"].astype(dtype)) * (
            xf @ p["shared"]["up"].astype(dtype))
        out = out + sh @ p["shared"]["down"].astype(dtype)
    return out.reshape(b, s, d), aux


def moe_block(p, x, cfg: ModelConfig, dtype, mesh=None, plan=None, batch_axes=("data",)):
    """The GSPMD MoE entry point: dense dispatch, layouts by propagation.

    Expert parallelism no longer routes through here — ``plan.ep > 1``
    always selects the block-executor loss (``train/executor.moe_block_ex``
    via ``train/step.py``), where the folded expert ring and the
    ``dispatch_ep_a2a`` exchange live.
    """
    del mesh, batch_axes  # GSPMD path: placement comes from annotations
    mode = plan.moe_dispatch if plan is not None else "einsum"
    gemm_impl = plan.moe_gemm_impl if plan is not None else "auto"
    return moe_dense(p, x, cfg, dtype, mode, gemm_impl)
