"""Silent-data-corruption defense (survey §8.2) — cheap device-side
integrity checksums cross-checked across replicas.

``plan.integrity = "audit"`` makes the train step compute an **exact**
uint32 checksum of the updated params + grads (bitcast sums, wrap mod 2^32
— a float accumulation would hide low-mantissa bit flips) and compare it
across every mesh axis with a ``pmax``/``pmin`` pair inside ``shard_map``.
Under SPMD all replicas compute the same program on the same (replicated)
values, so any divergence means a device produced different *bits* — the
definition of SDC. The step surfaces ``integrity_div`` (0.0 = healthy) in
its metrics; ``ft/recovery`` turns a nonzero into an ``sdc`` anomaly routed
through the policy table (default: rollback).

Cost: one pass of elementwise bitcasts + sums over params/grads and two
scalar collectives — no redundant compute, the algorithm-level check the
hardware-reliability literature recommends over full duplication. Measured
per family by ``benchmarks.run --only integrity`` (BENCH_integrity.json).

The checksum input passes through the ``integrity.checksum`` fault point
(:mod:`repro.ft.inject`), which is how the chaos tests create a genuinely
replica-divergent value (rank-masked bitflip) to prove detection end to end.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .inject import taint


def _leaf_checksum(x) -> jnp.ndarray:
    """Exact uint32 checksum of one array's bits (sum mod 2^32)."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating) and \
            not jnp.issubdtype(x.dtype, jnp.integer):
        x = x.astype(jnp.float32)
    size = jnp.dtype(x.dtype).itemsize
    if size == 8:
        x = x.astype(jnp.float32) if jnp.issubdtype(x.dtype, jnp.floating) \
            else x.astype(jnp.int32)
        size = 4
    uint = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[size]
    bits = jax.lax.bitcast_convert_type(jnp.ravel(x), uint)
    return jnp.sum(bits.astype(jnp.uint32), dtype=jnp.uint32)


def tree_checksum(tree) -> jnp.ndarray:
    """Exact uint32 checksum of a pytree's bits (order-deterministic)."""
    leaves = [l for l in jax.tree.leaves(tree) if hasattr(l, "dtype")
              or isinstance(l, (int, float))]
    if not leaves:
        return jnp.uint32(0)
    total = jnp.uint32(0)
    for l in leaves:
        total = total + _leaf_checksum(l)
    return total


def replica_divergence(tree, mesh: Optional[object] = None):
    """(checksum, divergence) of ``tree`` across all mesh replicas.

    ``divergence`` is ``float32(max - min)`` of the per-device checksum over
    every mesh axis: exactly 0.0 when all devices hold identical bits, > 0
    under SDC. Without a mesh (or a trivial one) the local checksum is
    returned with divergence 0.0 — there is nothing to cross-check.
    """
    cs = tree_checksum(tree)
    axes = [] if mesh is None else \
        [a for a, n in dict(mesh.shape).items() if int(n) > 1]
    if not axes:
        return cs, jnp.float32(0.0)
    from jax.sharding import PartitionSpec as P   # noqa: PLC0415
    from repro.core.compat import shard_map       # noqa: PLC0415

    def check(c):
        c = taint("integrity.checksum", c)
        mx, mn = c, c
        for a in axes:
            mx = jax.lax.pmax(mx, a)
            mn = jax.lax.pmin(mn, a)
        return mx, (mx - mn).astype(jnp.float32)

    mx, div = shard_map(check, mesh=mesh, in_specs=P(), out_specs=P())(cs)
    return mx, div
