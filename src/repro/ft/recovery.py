"""Checkpoint-based recovery driver (survey §8.3): wraps a training loop with
detect -> rollback -> replay semantics.

On an anomaly the driver restores the latest checkpoint and *replays* from the
restored step. The deterministic data pipeline (batch = f(arch, step)) makes
replay bit-faithful — the property test in tests/test_ft.py asserts the
recovered run matches an uninterrupted one.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.store import CheckpointManager
from .anomaly import Anomaly, Monitor


@dataclasses.dataclass
class RunReport:
    steps_done: int
    anomalies: List[Anomaly]
    restores: int
    losses: List[float]


def run_with_recovery(
    state: Any,
    train_step: Callable[[Any, Dict], Tuple[Any, Dict]],
    get_batch: Callable[[int], Dict],
    n_steps: int,
    ckpt: CheckpointManager,
    monitor: Optional[Monitor] = None,
    ckpt_every: int = 10,
    max_restores: int = 3,
    fault_injector: Optional[Callable[[int, Any], Any]] = None,
    plan=None,
    mesh=None,
) -> Tuple[Any, RunReport]:
    """Run ``n_steps`` with periodic checkpointing and anomaly-driven rollback.

    ``fault_injector(step, state) -> state`` lets tests corrupt the run.
    ``plan``/``mesh`` stamp the ParallelPlan axes into every checkpoint's
    manifest (store.py records them), and each rollback first verifies the
    checkpoint was written under the *same* cp/tp/pp layout — replaying a
    shard-written checkpoint onto a different mesh silently reshards, so the
    driver refuses instead. Restore itself is shard-aware: the restored
    leaves are re-placed with the live state's shardings.
    """
    monitor = monitor or Monitor()
    losses: List[float] = []
    restores = 0
    step = 0
    ckpt.save(step, state, blocking=True, plan=plan, mesh=mesh)

    while step < n_steps:
        cur = state
        if fault_injector is not None:
            cur = fault_injector(step, cur)
        new_state, metrics = train_step(cur, get_batch(step))
        loss = float(metrics["loss"])
        gnorm = float(metrics.get("grad_norm", 0.0))
        anomaly = monitor.record(step, loss, gnorm)

        if anomaly is not None and anomaly.kind in ("nan", "spike"):
            if restores >= max_restores:
                raise RuntimeError(
                    f"giving up after {restores} restores: {anomaly}")
            if plan is not None:
                ckpt.check_plan(plan)          # refuse cross-layout replay
            restore_step, state = ckpt.restore(state)
            step = restore_step
            restores += 1
            del losses[restore_step:]
            continue

        state = new_state
        losses.append(loss)
        step += 1
        if step % ckpt_every == 0:
            ckpt.save(step, state, plan=plan, mesh=mesh)

    ckpt.wait()
    return state, RunReport(step, monitor.anomalies, restores, losses)
