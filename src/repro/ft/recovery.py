"""Anomaly-driven recovery driver (survey §8.3): wraps a training loop with a
detect -> policy -> recover state machine.

Each anomaly kind from :class:`repro.ft.anomaly.Monitor` maps through a
:class:`repro.core.RecoveryPolicy` table to an action:

- **rollback** — restore the latest *intact* checkpoint and replay. The
  deterministic data pipeline (batch = f(arch, step)) makes replay
  bit-faithful; the property test asserts a recovered run matches an
  uninterrupted one. A checkpoint that fails integrity verification
  (:class:`repro.checkpoint.store.CorruptCheckpointError` — flipped bits,
  dropped or truncated shard file, unreadable manifest) is *skipped* and the
  restore falls back to the next-newest checkpoint instead of crashing,
  which is what the keep-last-K GC budget exists for.
- **lr_rescue** — a spike that *recurs at the same step* after a rollback
  means replay alone loops; roll back and damp the optimizer through the bad
  step instead (PaLM-style spike handling): the driver's ``rescue_step`` (a
  twin train step with LR × ``rescue_lr_scale``) when provided, else the
  offending batch is skipped outright (its loss slot records ``nan``).
  The decision is sticky — every later replay over that step takes the same
  path, keeping the run deterministic across rollbacks.
- **remesh** — elastic recovery from host loss / hang (survey §8.3.2): the
  ``remesh`` hook rebuilds the world at reduced size (new mesh, re-jitted
  step, state template on the new layout) and the driver reshard-restores
  the latest checkpoint onto it — params and ZeRO-1 optimizer moments are
  reassembled from the old mesh's shard slices and re-scattered over the
  new data axis — then continues on the shrunken cluster.
- **rebalance** — the fail-slow mitigation (survey §8.1, Malleus-style):
  a confirmed ``straggler`` attribution on a pipeline stage triggers the
  ``rebalance(new_layout)`` hook, which rebuilds the pipelined step under an
  uneven ``ParallelPlan.pp_layout`` chosen by
  :func:`repro.ft.straggler.choose_pp_layout` from the *measured* per-stage
  times — the degraded stage sheds layers instead of the whole run slowing
  to its pace. The driver restores the latest checkpoint through the same
  reshard path a remesh uses (``pp_layout`` is a layout axis in the
  manifest), so the relayout rides the elastic machinery rather than a
  bespoke transfer. A rank that was already rebalanced and is *still*
  attributed (its per-layer cost is unchanged — that is expected, not a
  failure) escalates to ``remesh`` when a hook is wired, else logs and
  continues.
- **ignore** — log and continue (the hang watchdog's default, so slow-step
  jitter never rolls back a healthy run unless asked to).

Two anomaly kinds originate outside the Monitor's statistical detectors
(they enter via :meth:`Monitor.note`):

- **sdc** — with ``plan.integrity = "audit"`` the train step emits
  ``metrics["integrity_div"]``, the cross-replica spread of an exact
  param/grad checksum (:mod:`repro.ft.integrity`); any nonzero value means a
  device produced different bits and routes through ``policy.sdc``
  (default rollback — the state cannot be trusted).
- **ckpt_io** — a checkpoint persist that failed even after the store's
  retry/backoff loop. The run itself is healthy, so ``policy.ckpt_io``
  defaults to ignore (training continues on the older checkpoint cadence);
  ``"rollback"`` forces an immediate restore instead.
- **straggler** — a confirmed fail-slow attribution from the attached
  :class:`repro.ft.straggler.StragglerTimer`: the driver times the batch
  fetch, the jitted step, and checkpoint persists, feeds the timer every
  step, and notes the top confirmed ``(rank, section, class)`` event when
  the statistical detectors stayed quiet. Routed through
  ``policy.straggler`` (default ignore — attribution is always logged; the
  ladder is ignore → rebalance → remesh).

Fault injection for tests rides two hooks: ``fault_injector(step, state)``
(state-level corruption, see :func:`repro.ft.inject.make_injector`) and
``fault_step_fn(step)`` — returning a *faulty compiled twin* of the train
step (built by :func:`repro.ft.inject.trace_with_faults`) to run at that
step, which is how trace-time payload corruption (ring ticks, kernel
outputs, checksum inputs) is scheduled without touching the clean step.

After every restore the Monitor's heartbeat is reset: restore wall-time is
not a step time and must not trip a false hang.

**Tiered restore order** (survey §8.3.1, Gemini/CheckFreq): every restore —
rollback, lr_rescue, resume — tries the hot in-memory tier first when one is
attached (``mem_ckpt``): (1) RAM primary shards (no verification — digested
at save, RAM trusted between save and restore), (2) RAM peer rebuild from
ring-neighbor mirrors (always digest-verified), and only then (3) the disk
walk, newest-intact first with full integrity verification, taking
``restore_resharded`` when the layout changed (remesh). The memory tier is
cleared on remesh (its recorded layouts are stale) and is not consulted for
cross-layout restores — elasticity is the disk tier's job.

**Exit discipline**: the checkpoint manager is flushed (``ckpt.wait()``) in
a ``finally`` on *every* exit path, and when a
:class:`repro.ft.flight.FlightRecorder` is attached its ring is dumped to
JSON on preemption and on any exception exit (``RecoveryExhausted`` carries
``flight_path``), so no failure leaves silently and every failure leaves a
black box.
"""

from __future__ import annotations

import dataclasses
import time
from contextlib import nullcontext
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.checkpoint.store import CheckpointManager, CorruptCheckpointError
from repro.core.config import RecoveryPolicy
from . import inject as _inject
from .anomaly import Anomaly, Monitor
from .preempt import choose_tier, clear_marker, read_marker, write_marker
from .straggler import choose_pp_layout, effective_layout


class RecoveryExhausted(RuntimeError):
    """max_restores spent without clearing the fault; carries the anomaly
    that forced the final (refused) restore."""

    def __init__(self, restores: int, anomaly: Optional[Anomaly]):
        super().__init__(f"giving up after {restores} restores: {anomaly}")
        self.restores = restores
        self.anomaly = anomaly
        # set by run_with_recovery when a flight recorder is attached: the
        # JSON black box dumped on the way out (the autopsy artifact)
        self.flight_path: Optional[str] = None


@dataclasses.dataclass
class RemeshSpec:
    """The post-shrink world a ``remesh`` hook hands back to the driver.

    ``state_template`` must match the checkpoint's tree structure and carry
    the *target* leaf shardings (build it on the new mesh);
    ``shardings`` optionally overrides them per leaf — needed when the
    template's ZeRO-1 moments are freshly-initialized (replicated) but the
    checkpointed ones must land re-scattered over the new data axis.
    """
    train_step: Callable[[Any, Dict], Tuple[Any, Dict]]
    state_template: Any
    shardings: Any = None
    plan: Any = None
    mesh: Any = None
    rescue_step: Optional[Callable[[Any, Dict], Tuple[Any, Dict]]] = None


@dataclasses.dataclass
class RunReport:
    steps_done: int
    anomalies: List[Anomaly]
    restores: int
    losses: List[float]
    remeshes: int = 0
    # pp_layout relayouts applied by the straggler ladder (each is also a
    # restore — the reshard rides the checkpoint machinery)
    rebalances: int = 0
    # (step, anomaly kind, action taken) — the policy audit trail
    actions: List[Tuple[int, str, str]] = dataclasses.field(default_factory=list)
    # corrupt checkpoints skipped by fallback restores
    ckpt_fallbacks: int = 0
    # restores served by the hot in-memory tier (subset of ``restores``);
    # the remainder walked the disk tier
    mem_restores: int = 0
    # graceful preemption exit: the run stopped early at ``preempt_step``
    # after a just-in-time snapshot (resume with ``resume=True``)
    preempted: bool = False
    preempt_step: Optional[int] = None
    # where the flight recorder dumped its JSON (preemption/crash), if at all
    flight_path: Optional[str] = None


def run_with_recovery(
    state: Any,
    train_step: Callable[[Any, Dict], Tuple[Any, Dict]],
    get_batch: Callable[[int], Dict],
    n_steps: int,
    ckpt: CheckpointManager,
    monitor: Optional[Monitor] = None,
    ckpt_every: int = 10,
    max_restores: int = 3,
    fault_injector: Optional[Callable[[int, Any], Any]] = None,
    plan=None,
    mesh=None,
    policy: Optional[RecoveryPolicy] = None,
    rescue_step: Optional[Callable[[Any, Dict], Tuple[Any, Dict]]] = None,
    remesh: Optional[Callable[[], RemeshSpec]] = None,
    straggler=None,
    rebalance: Optional[Callable[[Tuple[int, ...]], RemeshSpec]] = None,
    resume: bool = False,
    fault_step_fn: Optional[Callable[[int], Optional[Callable]]] = None,
    mem_ckpt=None,
    mem_every: int = 1,
    preempt=None,
    flight=None,
) -> Tuple[Any, RunReport]:
    """Run ``n_steps`` with periodic checkpointing and anomaly-driven recovery.

    ``fault_injector(step, state) -> state`` lets tests corrupt the run;
    ``fault_step_fn(step) -> step_fn | None`` swaps in a faulty traced twin
    of the train step for that step (trace-time payload corruption).
    ``plan``/``mesh`` stamp the layout axes into every checkpoint manifest;
    each restore routes through :meth:`CheckpointManager.check_plan` —
    same-layout checkpoints replay shard-to-shard, and with
    ``policy.elastic`` a layout change takes the reshard path instead of
    refusing. Restores skip corrupt checkpoints (newest-intact fallback).
    ``remesh()`` is the elastic hook: called on a hang when
    ``policy.hang == "remesh"``, it returns the shrunken-cluster
    :class:`RemeshSpec` the run continues under.

    ``straggler`` (a :class:`repro.ft.straggler.StragglerTimer`) turns on
    fail-slow attribution: the driver times the batch fetch
    (``data.fetch``), each checkpoint persist (``ckpt.persist``), and the
    jitted step, and calls ``straggler.after_step`` every step — which also
    executes any armed ``slow`` fault's real delay, so injected fail-slow
    costs wall clock. A confirmed attribution is noted as a ``straggler``
    anomaly and routed through ``policy.straggler``. ``rebalance(layout)``
    is the mitigation hook: given the :func:`choose_pp_layout` target it
    returns a :class:`RemeshSpec` for the same mesh with
    ``plan.pp_layout = layout``; the driver reshard-restores onto it
    exactly like a remesh. Without the hook (or for non-stage attributions)
    ``"rebalance"`` degrades to ``"remesh"`` when that hook exists, else to
    ``"ignore"``. ``resume=True`` picks up
    from the latest checkpoint already in ``ckpt`` (resharding onto
    ``state``'s layout if it was written on a different one) instead of
    saving a fresh step-0 checkpoint; a ``PREEMPTED`` marker left by a
    prior graceful preemption is consumed (logged + cleared) on resume.

    Fast-recovery tier (survey §8.3.1): ``mem_ckpt`` (a
    :class:`repro.checkpoint.memory.MemoryCheckpointTier`) snapshots the
    state into host RAM every ``mem_every`` accepted steps, and every
    restore tries it *first* — rollbacks land on the newest RAM snapshot
    (at most ``mem_every - 1`` steps of replay instead of up to
    ``ckpt_every - 1``) and fall back to the verified disk walk when the
    tier can't serve (empty, layout mismatch after remesh, shards lost
    beyond the peer mirrors). A remesh clears it (recorded layouts are
    stale on the new mesh).

    ``preempt`` (a :class:`repro.ft.preempt.PreemptionGuard`) is checked
    between steps: on a preemption notice the driver flushes the in-flight
    async persist, takes a just-in-time blocking snapshot on the tier
    :func:`repro.ft.preempt.choose_tier` picks from the grace budget vs
    measured persist time, writes the ``PREEMPTED`` marker, dumps the
    flight recorder, and returns ``RunReport(preempted=True, ...)``.

    ``flight`` (a :class:`repro.ft.flight.FlightRecorder`) collects the
    per-step black box: the driver logs policy decisions, restores (with
    the serving tier), injected faults that fired, and preemption; it is
    dumped to JSON on preemption and on *any* exception exit — including
    :class:`RecoveryExhausted`, which carries ``flight_path`` — and the
    path lands on the report. The checkpoint manager's background persist
    is flushed (``ckpt.wait()``) in a ``finally`` on every exit path, so a
    failed persist always surfaces as a ``ckpt_io`` anomaly instead of
    dying silently with its thread.
    """
    monitor = monitor or Monitor()
    policy = policy or RecoveryPolicy(max_restores=max_restores)
    policy.validate()
    if flight is not None:
        # one black box for the whole stack: detector, store, and hot tier
        # all log into the driver's recorder unless wired to their own
        if getattr(monitor, "flight", None) is None:
            monitor.flight = flight
        if getattr(ckpt, "flight", None) is None:
            ckpt.flight = flight
        if mem_ckpt is not None and getattr(mem_ckpt, "flight", None) is None:
            mem_ckpt.flight = flight
    if straggler is not None and flight is not None \
            and getattr(straggler.detector, "flight", None) is None:
        straggler.detector.flight = flight
    losses: List[float] = []
    actions: List[Tuple[int, str, str]] = []
    restores = 0
    remeshes = 0
    rebalances = 0
    fallbacks = 0
    mem_restores = 0
    # stages already relayouted by the straggler ladder: a re-attribution of
    # the same rank (its per-layer cost is unchanged) escalates, not loops
    rebalanced_ranks: Set[int] = set()
    spike_counts: Dict[int, int] = {}
    rescue_mode: Dict[int, str] = {}   # step -> "rescue" | "skip", sticky
    step = 0

    def _restore(template, shardings=None, the_plan=None, the_mesh=None):
        """Tiered restore — memory first, then the verified disk walk.

        Tier 1/2: the hot RAM ring (primary shards, then peer rebuild from
        neighbor mirrors — both inside ``mem_ckpt.restore``). Tier 3: walk
        disk checkpoints newest-first, skipping any that fail integrity
        verification (the keep-last-K fallback)."""
        nonlocal fallbacks, mem_restores
        if mem_ckpt is not None:
            try:
                got, tree = mem_ckpt.restore(template, plan=the_plan,
                                             mesh=the_mesh)
            except (CorruptCheckpointError, ValueError, AssertionError) as e:
                # can't serve (empty / lost shards / layout change) — disk
                if flight is not None:
                    flight.record("restore_miss", step, tier="memory",
                                  error=repr(e))
            else:
                mem_restores += 1
                monitor.reset_heartbeat()
                if flight is not None:
                    flight.record("restore", got,
                                  tier=("memory-rebuild"
                                        if mem_ckpt.last_rebuild else "memory"),
                                  rebuilt_shards=mem_ckpt.last_rebuild)
                return got, tree
        candidates = ckpt.steps(newest_first=True)
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {ckpt.dir}")
        last_err: Optional[Exception] = None
        for s in candidates:
            try:
                route = "replay"
                if the_plan is not None or the_mesh is not None:
                    route = ckpt.check_plan(the_plan, step=s, mesh=the_mesh,
                                            elastic=policy.elastic)
                if route == "reshard":
                    got, tree = ckpt.restore_resharded(
                        template, shardings=shardings, step=s)
                else:
                    got, tree = ckpt.restore(template, step=s)
            except CorruptCheckpointError as e:
                fallbacks += 1
                monitor.note("ckpt_corrupt", s, repr(e))
                last_err = e
                continue
            monitor.reset_heartbeat()  # restore wall-time is not a step time
            if flight is not None:
                flight.record("restore", got, tier="disk", route=route)
            return got, tree
        raise last_err                 # every checkpoint on disk is corrupt

    def _sect(name, s):
        """The straggler timer's section context (times + executes armed
        ``slow`` delays), or a no-op when no timer is attached."""
        return (straggler.section(name, s) if straggler is not None
                else nullcontext())

    def _try_save(s, st, blocking=False) -> Optional[Anomaly]:
        """Save, converting an (already retried) persist failure into a
        ``ckpt_io`` anomaly routed through ``policy.ckpt_io``. With async
        persist the failure of save N surfaces at save N+1's fence — the
        anomaly is stamped with the step the failure *surfaced* at."""
        try:
            with _sect("ckpt.persist", s):
                ckpt.save(s, st, blocking=blocking, plan=plan, mesh=mesh)
            return None
        except (OSError, RuntimeError) as e:
            a = monitor.note("ckpt_io", s, repr(e))
            actions.append((s, "ckpt_io", policy.ckpt_io))
            return a

    def _mem_save(s, st):
        if mem_ckpt is not None and s % max(1, mem_every) == 0:
            mem_ckpt.save(s, st, plan=plan, mesh=mesh)

    def _report(**over) -> RunReport:
        base = dict(steps_done=step, anomalies=monitor.anomalies,
                    restores=restores, losses=losses, remeshes=remeshes,
                    rebalances=rebalances, actions=actions,
                    ckpt_fallbacks=fallbacks, mem_restores=mem_restores)
        base.update(over)
        return RunReport(**base)

    if resume and ckpt.latest_step() is not None:
        marker = read_marker(ckpt.dir)
        if marker is not None:
            # consume the graceful-preemption marker: log the handoff and
            # clear it so a later crash isn't misread as another preemption
            if flight is not None:
                flight.record("resume_after_preempt",
                              int(marker.get("step", -1)),
                              tier=marker.get("tier"))
            clear_marker(ckpt.dir)
        step, state = _restore(state, the_plan=plan, the_mesh=mesh)
        losses = [float("nan")] * step     # pre-resume slots are unknown
    else:
        _try_save(step, state, blocking=True)
    _mem_save(step, state)

    try:
        while step < n_steps:
            if preempt is not None and preempt.requested:
                # graceful preemption: flush the in-flight persist first (a
                # background failure must not pass for a durable
                # checkpoint), then a just-in-time blocking snapshot on
                # whichever tier fits the remaining grace budget
                try:
                    ckpt.wait()
                except (OSError, RuntimeError) as e:
                    monitor.note("ckpt_io", step, repr(e))
                    actions.append((step, "ckpt_io", policy.ckpt_io))
                tier = choose_tier(preempt, ckpt, mem_ckpt)
                if tier == "memory":
                    mem_ckpt.save(step, state, plan=plan, mesh=mesh)
                else:
                    _try_save(step, state, blocking=True)
                if flight is not None:
                    flight.record("preempt", step, tier=tier,
                                  signum=preempt.signum,
                                  grace_left=preempt.remaining())
                fp = flight.dump("preempt") if flight is not None else None
                write_marker(ckpt.dir, step, tier, preempt.signum, fp)
                return state, _report(preempted=True, preempt_step=step,
                                      flight_path=fp)

            mode = rescue_mode.get(step)
            if mode == "skip":
                losses.append(float("nan"))  # batch dropped by lr_rescue
                step += 1
                if step % ckpt_every == 0:
                    _try_save(step, state)
                _mem_save(step, state)
                continue

            cur = state
            n_fired = len(_inject.CONTROLLER.fired)
            if fault_injector is not None:
                cur = fault_injector(step, cur)
            fn = (rescue_step if (mode == "rescue" and rescue_step)
                  else train_step)
            if fault_step_fn is not None:
                faulty = fault_step_fn(step)
                if faulty is not None:
                    fn = faulty
            with _sect("data.fetch", step):
                batch = get_batch(step)
            t0 = time.perf_counter()
            new_state, metrics = fn(cur, batch)
            loss = float(metrics["loss"])    # blocks on the device, so the
            gnorm = float(metrics.get("grad_norm", 0.0))  # timing below is
            step_seconds = time.perf_counter() - t0       # real step time
            div = float(metrics.get("integrity_div", 0.0))
            if flight is not None:
                for point, kind, fstep in \
                        _inject.CONTROLLER.fired[n_fired:]:
                    flight.record("fault", step, point=point,
                                  fault_kind=kind, armed_step=fstep)
            anomaly = monitor.record(step, loss, gnorm)
            if div != 0.0:
                # replica checksum divergence outranks the statistical
                # detectors: the step's own outputs cannot be trusted,
                # whatever they look like
                anomaly = monitor.note("sdc", step, f"integrity_div={div}")
            if anomaly is not None and mode == "rescue" \
                    and anomaly.kind == "spike":
                anomaly = None             # the rescue step owns this spike

            # per-step straggler telemetry: ALWAYS fed (armed `slow` faults
            # execute their real delays inside after_step — skipping it would
            # un-inject the fault), but only *noted* as the step's anomaly
            # when the statistical detectors stayed quiet (a nan/spike/hang
            # outranks an attribution of the same symptom)
            ev = None
            if straggler is not None:
                ev = straggler.after_step(step, step_seconds, plan=plan)
            if ev is not None and anomaly is None:
                anomaly = monitor.note(
                    "straggler", step,
                    f"rank={ev.rank} section={ev.section} class={ev.cls} "
                    f"slowdown={ev.slowdown:.2f}x")

            if anomaly is not None:
                if anomaly.kind == "spike":
                    spike_counts[step] = spike_counts.get(step, 0) + 1
                    action = (policy.spike if spike_counts[step] == 1
                              else policy.repeated_spike)
                else:
                    action = getattr(policy, anomaly.kind)
                new_layout = None
                if action == "rebalance":
                    # applicable only to a pipeline-stage attribution with a
                    # hook, a known layout, and a rank not already relayouted
                    # (its per-layer cost won't change — escalate instead)
                    lay = effective_layout(
                        plan, getattr(straggler, "cfg", None))
                    ok = (rebalance is not None and ev is not None
                          and ev.section == "pp.stage" and lay is not None
                          and ev.rank is not None
                          and ev.rank not in rebalanced_ranks)
                    if ok:
                        new_layout = choose_pp_layout(
                            straggler.stage_times(), lay)
                        if new_layout == tuple(lay):
                            action = "ignore"   # measurement says: balanced
                    else:
                        action = "remesh" if remesh is not None else "ignore"
                if action == "remesh" and (anomaly.kind not in
                                           ("hang", "straggler")
                                           or remesh is None):
                    action = "ignore"      # no hook / not escalable: advisory
                actions.append((step, anomaly.kind, action))
                if flight is not None:
                    flight.record("policy", step, anomaly=anomaly.kind,
                                  action=action, detail=anomaly.detail)

                if action in ("rollback", "lr_rescue"):
                    if restores >= policy.max_restores:
                        raise RecoveryExhausted(restores, anomaly)
                    if action == "lr_rescue":
                        rescue_mode[step] = ("rescue" if rescue_step
                                             else "skip")
                    step, state = _restore(state, the_plan=plan,
                                           the_mesh=mesh)
                    restores += 1
                    del losses[step:]
                    continue
                if action == "remesh":
                    if restores >= policy.max_restores:
                        raise RecoveryExhausted(restores, anomaly)
                    spec = remesh()
                    if mem_ckpt is not None:
                        # the world was rebuilt: RAM snapshots recorded on
                        # the old layout are gone with their hosts
                        mem_ckpt.clear()
                    step, state = _restore(spec.state_template,
                                           spec.shardings,
                                           spec.plan, spec.mesh)
                    train_step = spec.train_step
                    plan, mesh = spec.plan, spec.mesh
                    if spec.rescue_step is not None:
                        rescue_step = spec.rescue_step
                    restores += 1
                    remeshes += 1
                    if straggler is not None:
                        straggler.plan = plan
                        straggler.reset()  # old-mesh baselines are stale
                    del losses[step:]
                    continue
                if action == "rebalance":
                    if restores >= policy.max_restores:
                        raise RecoveryExhausted(restores, anomaly)
                    spec = rebalance(new_layout)
                    if mem_ckpt is not None:
                        # RAM snapshots record the old pp_layout; the hot
                        # tier cannot reshard, so don't keep failing on them
                        mem_ckpt.clear()
                    # the saved manifests record the old pp_layout, so
                    # check_plan routes this restore "reshard" — the
                    # relayout IS an elastic reshard, not a refusal
                    step, state = _restore(spec.state_template,
                                           spec.shardings,
                                           spec.plan, spec.mesh)
                    train_step = spec.train_step
                    if spec.plan is not None:
                        plan = spec.plan
                    if spec.mesh is not None:
                        mesh = spec.mesh
                    if spec.rescue_step is not None:
                        rescue_step = spec.rescue_step
                    restores += 1
                    rebalances += 1
                    rebalanced_ranks.add(ev.rank)
                    straggler.plan = plan
                    straggler.reset()      # new regime: re-learn baselines
                    if flight is not None:
                        flight.record("rebalance", step, rank=ev.rank,
                                      layout=list(new_layout))
                    del losses[step:]
                    continue
                # "ignore": fall through and accept the step

            state = new_state
            losses.append(loss)
            step += 1
            if step % ckpt_every == 0:
                a = _try_save(step, state)
                if a is not None and policy.ckpt_io == "rollback":
                    if restores >= policy.max_restores:
                        raise RecoveryExhausted(restores, a)
                    step, state = _restore(state, the_plan=plan,
                                           the_mesh=mesh)
                    restores += 1
                    del losses[step:]
                    continue
            _mem_save(step, state)
    except BaseException as e:
        if flight is not None:
            # the autopsy artifact: dump the black box and pin its path on
            # the exception so the caller can find it without a report
            fp = flight.dump(reason=type(e).__name__,
                             extra={"step": step, "error": repr(e)})
            try:
                e.flight_path = fp
            except Exception:       # exotic exception types w/ slots
                pass
        raise
    finally:
        # flush the background persist on EVERY exit path — normal return,
        # preemption, crash, RecoveryExhausted — so a failed persist
        # surfaces as a ckpt_io anomaly instead of dying with its thread
        try:
            ckpt.wait()
        except (OSError, RuntimeError) as e:
            monitor.note("ckpt_io", step, repr(e))
            actions.append((step, "ckpt_io", policy.ckpt_io))
    return state, _report()
