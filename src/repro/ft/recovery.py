"""Anomaly-driven recovery driver (survey §8.3): wraps a training loop with a
detect -> policy -> recover state machine.

Each anomaly kind from :class:`repro.ft.anomaly.Monitor` maps through a
:class:`repro.core.RecoveryPolicy` table to an action:

- **rollback** — restore the latest checkpoint and replay. The deterministic
  data pipeline (batch = f(arch, step)) makes replay bit-faithful; the
  property test asserts a recovered run matches an uninterrupted one.
- **lr_rescue** — a spike that *recurs at the same step* after a rollback
  means replay alone loops; roll back and damp the optimizer through the bad
  step instead (PaLM-style spike handling): the driver's ``rescue_step`` (a
  twin train step with LR × ``rescue_lr_scale``) when provided, else the
  offending batch is skipped outright (its loss slot records ``nan``).
  The decision is sticky — every later replay over that step takes the same
  path, keeping the run deterministic across rollbacks.
- **remesh** — elastic recovery from host loss / hang (survey §8.3.2): the
  ``remesh`` hook rebuilds the world at reduced size (new mesh, re-jitted
  step, state template on the new layout) and the driver reshard-restores
  the latest checkpoint onto it — params and ZeRO-1 optimizer moments are
  reassembled from the old mesh's shard slices and re-scattered over the
  new data axis — then continues on the shrunken cluster.
- **ignore** — log and continue (the hang watchdog's default, so slow-step
  jitter never rolls back a healthy run unless asked to).

After every restore the Monitor's heartbeat is reset: restore wall-time is
not a step time and must not trip a false hang.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.store import CheckpointManager
from repro.core.config import RecoveryPolicy
from .anomaly import Anomaly, Monitor


@dataclasses.dataclass
class RemeshSpec:
    """The post-shrink world a ``remesh`` hook hands back to the driver.

    ``state_template`` must match the checkpoint's tree structure and carry
    the *target* leaf shardings (build it on the new mesh);
    ``shardings`` optionally overrides them per leaf — needed when the
    template's ZeRO-1 moments are freshly-initialized (replicated) but the
    checkpointed ones must land re-scattered over the new data axis.
    """
    train_step: Callable[[Any, Dict], Tuple[Any, Dict]]
    state_template: Any
    shardings: Any = None
    plan: Any = None
    mesh: Any = None
    rescue_step: Optional[Callable[[Any, Dict], Tuple[Any, Dict]]] = None


@dataclasses.dataclass
class RunReport:
    steps_done: int
    anomalies: List[Anomaly]
    restores: int
    losses: List[float]
    remeshes: int = 0
    # (step, anomaly kind, action taken) — the policy audit trail
    actions: List[Tuple[int, str, str]] = dataclasses.field(default_factory=list)


def run_with_recovery(
    state: Any,
    train_step: Callable[[Any, Dict], Tuple[Any, Dict]],
    get_batch: Callable[[int], Dict],
    n_steps: int,
    ckpt: CheckpointManager,
    monitor: Optional[Monitor] = None,
    ckpt_every: int = 10,
    max_restores: int = 3,
    fault_injector: Optional[Callable[[int, Any], Any]] = None,
    plan=None,
    mesh=None,
    policy: Optional[RecoveryPolicy] = None,
    rescue_step: Optional[Callable[[Any, Dict], Tuple[Any, Dict]]] = None,
    remesh: Optional[Callable[[], RemeshSpec]] = None,
    resume: bool = False,
) -> Tuple[Any, RunReport]:
    """Run ``n_steps`` with periodic checkpointing and anomaly-driven recovery.

    ``fault_injector(step, state) -> state`` lets tests corrupt the run.
    ``plan``/``mesh`` stamp the layout axes into every checkpoint manifest;
    each restore routes through :meth:`CheckpointManager.check_plan` —
    same-layout checkpoints replay shard-to-shard, and with
    ``policy.elastic`` a layout change takes the reshard path instead of
    refusing. ``remesh()`` is the elastic hook: called on a hang when
    ``policy.hang == "remesh"``, it returns the shrunken-cluster
    :class:`RemeshSpec` the run continues under. ``resume=True`` picks up
    from the latest checkpoint already in ``ckpt`` (resharding onto
    ``state``'s layout if it was written on a different one) instead of
    saving a fresh step-0 checkpoint.
    """
    monitor = monitor or Monitor()
    policy = policy or RecoveryPolicy(max_restores=max_restores)
    policy.validate()
    losses: List[float] = []
    actions: List[Tuple[int, str, str]] = []
    restores = 0
    remeshes = 0
    spike_counts: Dict[int, int] = {}
    rescue_mode: Dict[int, str] = {}   # step -> "rescue" | "skip", sticky
    step = 0

    def _restore(template, shardings=None, the_plan=None, the_mesh=None):
        route = "replay"
        if the_plan is not None or the_mesh is not None:
            route = ckpt.check_plan(the_plan, mesh=the_mesh,
                                    elastic=policy.elastic)
        if route == "reshard":
            s, tree = ckpt.restore_resharded(template, shardings=shardings)
        else:
            s, tree = ckpt.restore(template)
        monitor.reset_heartbeat()      # restore wall-time is not a step time
        return s, tree

    if resume and ckpt.latest_step() is not None:
        step, state = _restore(state, the_plan=plan, the_mesh=mesh)
        losses = [float("nan")] * step     # pre-resume slots are unknown
    else:
        ckpt.save(step, state, blocking=True, plan=plan, mesh=mesh)

    while step < n_steps:
        mode = rescue_mode.get(step)
        if mode == "skip":
            losses.append(float("nan"))    # batch dropped by lr_rescue policy
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state, plan=plan, mesh=mesh)
            continue

        cur = state
        if fault_injector is not None:
            cur = fault_injector(step, cur)
        fn = rescue_step if (mode == "rescue" and rescue_step) else train_step
        new_state, metrics = fn(cur, get_batch(step))
        loss = float(metrics["loss"])
        gnorm = float(metrics.get("grad_norm", 0.0))
        anomaly = monitor.record(step, loss, gnorm)
        if anomaly is not None and mode == "rescue" and anomaly.kind == "spike":
            anomaly = None                 # the rescue step owns this spike

        if anomaly is not None:
            if anomaly.kind == "spike":
                spike_counts[step] = spike_counts.get(step, 0) + 1
                action = (policy.spike if spike_counts[step] == 1
                          else policy.repeated_spike)
            else:
                action = getattr(policy, anomaly.kind)
            if action == "remesh" and (anomaly.kind != "hang" or remesh is None):
                action = "ignore"          # no hook / not a hang: advisory only
            actions.append((step, anomaly.kind, action))

            if action in ("rollback", "lr_rescue"):
                if restores >= policy.max_restores:
                    raise RuntimeError(
                        f"giving up after {restores} restores: {anomaly}")
                if action == "lr_rescue":
                    rescue_mode[step] = "rescue" if rescue_step else "skip"
                step, state = _restore(state, the_plan=plan, the_mesh=mesh)
                restores += 1
                del losses[step:]
                continue
            if action == "remesh":
                if restores >= policy.max_restores:
                    raise RuntimeError(
                        f"giving up after {restores} restores: {anomaly}")
                spec = remesh()
                step, state = _restore(spec.state_template, spec.shardings,
                                       spec.plan, spec.mesh)
                train_step = spec.train_step
                plan, mesh = spec.plan, spec.mesh
                if spec.rescue_step is not None:
                    rescue_step = spec.rescue_step
                restores += 1
                remeshes += 1
                del losses[step:]
                continue
            # "ignore": fall through and accept the step

        state = new_state
        losses.append(loss)
        step += 1
        if step % ckpt_every == 0:
            ckpt.save(step, state, plan=plan, mesh=mesh)

    ckpt.wait()
    return state, RunReport(step, monitor.anomalies, restores, losses,
                            remeshes, actions)
