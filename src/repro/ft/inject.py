"""Deterministic fault injection (survey §8.1/§8.2) — the chaos half of the
fault-tolerance stack.

Every recovery path in ``ft/recovery`` is only as trustworthy as the faults
it has been exercised against. This module provides *scheduled, seeded,
replayable* faults at **named fault points** threaded through the real hot
paths, so a failure observed once can be replayed bit-identically:

===================  ========================================================
fault point          where it fires
===================  ========================================================
``ckpt.persist``     :meth:`repro.checkpoint.store.CheckpointManager.save`'s
                     persist write (host side, per attempt)
``ckpt.shard_write`` the final shard file on disk (silent corruption: drop /
                     truncate after a successful-looking write)
``train.step``       the recovery driver's loop, via :func:`make_injector`
                     (state-level corruption before the jitted step)
``tp.ring.tick``     the overlap-TP collective matmuls' ppermute payloads
                     (:mod:`repro.train.tensor_parallel`)
``cp.ring.kv``       ring-attention KV chunks between cp ticks
                     (:mod:`repro.train.executor`)
``cp.ring.state``    the SSD entering-state chain messages (executor)
``kernel.attention`` / ``kernel.expert_gemm`` / ``kernel.ssd``
                     the per-op dispatcher outputs (:mod:`repro.kernels.dispatch`)
``integrity.checksum``  the device-side integrity checksum input
                     (:mod:`repro.ft.integrity`) — the SDC test bed
``pp.stage.tick``    per-stage pipeline tick timing seam (host side, via
                     :mod:`repro.ft.straggler` — ``slow`` faults only)
``data.fetch``       host-side batch fetch in the recovery driver
                     (``slow`` faults, via the straggler timer)
===================  ========================================================

**Adding a new fault point** is two lines: call :func:`register_fault_point`
(name + one-line doc) at import time, then place either ``taint(name, x)``
(device-side, trace-time) or ``io_fault(name, step=...)`` (host-side) at the
seam. ``taint`` is identity unless a matching :class:`FaultSpec` is *armed*
(:func:`armed` / :func:`trace_with_faults`), so the production path pays
nothing — the corruption is baked into a *separate* traced function the test
calls only at the scheduled step.

Determinism: corruption indices/bits derive from ``zlib.crc32`` of
``(point, step, seed)`` — never Python's salted ``hash()`` — so the same
spec replays the same flipped bit on any host.

Fault classes (``FaultSpec.kind``): ``bitflip`` (xor one high-exponent bit
of one element), ``nan`` (poison one element), ``spike`` (scale the whole
payload), ``hang`` (host sleep), ``drop_write`` (shard file vanishes),
``truncate_write`` (shard file cut short), ``persist_exc`` (persist thread
raises), ``slow`` (fail-slow, survey §8.1: a *recurring* host-side delay of
``sleep_s`` per unit of work at one named point, active for ``span``
consecutive steps starting at ``step`` and maskable to one ``rank`` — unlike
``hang``'s one-shot stall, ``slow`` models a degraded device/link/host that
stays degraded; the :mod:`repro.ft.straggler` timer executes the delay
inside the matching timing section via :func:`slow_spec_for`, so the
degradation is real measured wall time, replayable bit-for-bit by step).
"""

from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple

FAULT_KINDS = ("bitflip", "nan", "spike", "hang",
               "drop_write", "truncate_write", "persist_exc", "slow")

# name -> one-line doc. The registry is the contract between injection sites
# and tests: taint()/io_fault() refuse unknown names, so a typo'd fault point
# fails loudly instead of silently never firing.
FAULT_POINTS: Dict[str, str] = {}


def register_fault_point(name: str, doc: str) -> str:
    FAULT_POINTS[name] = doc
    return name


for _n, _d in (
    ("ckpt.persist", "checkpoint persist write, per attempt (host)"),
    ("ckpt.shard_write", "final shard file on disk (drop/truncate)"),
    ("train.step", "recovery-driver loop, state-level (make_injector)"),
    ("tp.ring.tick", "overlap-TP ring ppermute payload"),
    ("cp.ring.kv", "ring-attention KV chunk between cp ticks"),
    ("cp.ring.state", "SSD entering-state chain message"),
    ("ep.a2a.tick", "EP dispatch/combine all-to-all ring payload"),
    ("kernel.attention", "attention dispatcher output"),
    ("kernel.expert_gemm", "expert-GEMM dispatcher output"),
    ("kernel.ssd", "SSD-scan dispatcher output"),
    ("integrity.checksum", "device-side integrity checksum input"),
    ("pp.stage.tick", "per-stage pipeline tick (straggler timer, host)"),
    ("data.fetch", "recovery-driver batch fetch (straggler timer, host)"),
):
    register_fault_point(_n, _d)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: (step, point, seed) -> a deterministic failure.

    ``step`` schedules host-side (``io_fault``) and driver-level
    (``make_injector``) faults; for trace-time ``taint`` points it seeds the
    corruption (the *armed trace* decides when the faulty function runs).
    ``tick`` picks which taint call site fires when a point traces more than
    once (ring ticks / layers); ``tick=None`` fires on *every* trace
    occurrence — the robust choice when jax may trace a seam more than once
    (custom_vjp fwd, scanned layer bodies); ``times`` bounds host-side firings
    (``persist_exc`` with ``times > io_retries`` exhausts the retry loop).
    ``rank``/``axis`` restrict device-side corruption to one mesh rank —
    the only way to create *replica-divergent* state (true SDC) under SPMD,
    where an unmasked corruption computes identically on every replica.
    For ``slow`` faults, ``rank`` instead pins the delay to one rank of the
    timed section (pipeline stage / ring position) and ``span`` keeps the
    fault active for that many consecutive steps — fail-slow is a condition,
    not an event.
    """
    point: str
    kind: str
    step: int = 0
    seed: int = 0
    scale: float = 1e4        # "spike" multiplier
    sleep_s: float = 1.0      # "hang" duration / "slow" per-work-unit delay
    tick: Optional[int] = 0   # which trace occurrence fires (None = all)
    times: int = 1            # host-side max firings
    rank: Optional[int] = None
    axis: Optional[str] = None
    span: int = 1             # "slow": active for steps [step, step + span)

    def __post_init__(self):
        if self.point not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r}; registered: "
                f"{sorted(FAULT_POINTS)}")
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.span < 1:
            raise ValueError(f"span must be >= 1, got {self.span}")

    def key(self) -> int:
        """The deterministic corruption key (crc32, never salted hash())."""
        return zlib.crc32(f"{self.point}:{self.step}:{self.seed}".encode())


class FaultController:
    """Process-wide armed-fault state (thread-safe: the checkpoint persist
    thread consults it concurrently with the main loop)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._specs: List[FaultSpec] = []
        self._trace_counts: Dict[str, int] = {}
        self._io_counts: Dict[Tuple[str, str, int], int] = {}
        self.fired: List[Tuple[str, str, int]] = []   # (point, kind, step)

    def install(self, specs) -> None:
        with self._lock:
            self._specs = list(specs)
            self._trace_counts = {}
            self._io_counts = {}

    def clear(self) -> None:
        self.install(())

    def trace_spec(self, point: str) -> Optional[FaultSpec]:
        """The armed spec for a device-side point, honoring ``tick`` against
        a per-point trace counter; marks it fired."""
        with self._lock:
            n = self._trace_counts.get(point, 0)
            self._trace_counts[point] = n + 1
            for sp in self._specs:
                if sp.kind == "slow":
                    continue    # host-side delay (slow_spec_for), never a
                                # trace-time payload corruption
                if sp.point == point and (sp.tick is None or sp.tick == n):
                    self.fired.append((point, sp.kind, sp.step))
                    return sp
        return None

    def io_spec(self, point: str, step: int) -> Optional[FaultSpec]:
        """The armed spec for a host-side point at ``step`` (``times``-
        bounded); marks it fired."""
        with self._lock:
            for sp in self._specs:
                if sp.point != point or sp.step != step:
                    continue
                if sp.kind == "slow":
                    continue    # executed by the straggler timer's section
                k = (point, sp.kind, sp.step)
                if self._io_counts.get(k, 0) >= sp.times:
                    continue
                self._io_counts[k] = self._io_counts.get(k, 0) + 1
                self.fired.append(k)
                return sp
        return None


CONTROLLER = FaultController()


@contextmanager
def armed(specs):
    """Arm ``specs`` for the duration of the block (and disarm after).

    Device-side ``taint`` points only fire while the *trace* happens inside
    an armed block — arm, trace the faulty twin of the step function, disarm;
    the clean jitted step is untouched.
    """
    CONTROLLER.install(specs)
    try:
        yield CONTROLLER
    finally:
        CONTROLLER.clear()


def corrupt_array(x, spec: FaultSpec):
    """Deterministically corrupt one array per ``spec`` (pure jnp; traceable).

    ``bitflip`` xors a high exponent bit of one element (crc32-chosen index)
    — the classic SDC that turns a weight into a huge value; ``nan`` poisons
    one element; ``spike`` scales the whole payload. With ``rank``/``axis``
    set, only that mesh rank's shard is corrupted (requires tracing inside
    shard_map over ``axis``).
    """
    import jax
    import jax.numpy as jnp

    key = spec.key()
    size = 1
    for d in x.shape:
        size *= int(d)
    idx = key % max(size, 1)
    if spec.kind == "spike":
        bad = x * jnp.asarray(spec.scale, x.dtype)
    elif spec.kind == "nan":
        bad = jnp.ravel(x).at[idx].set(jnp.asarray(float("nan"), x.dtype)
                                       ).reshape(x.shape)
    elif spec.kind == "bitflip":
        uint = {2: jnp.uint16, 4: jnp.uint32}.get(jnp.dtype(x.dtype).itemsize)
        if uint is None or not jnp.issubdtype(x.dtype, jnp.floating):
            bad = x * jnp.asarray(spec.scale, x.dtype)   # non-float fallback
        else:
            nbits = 8 * jnp.dtype(x.dtype).itemsize
            bit = nbits - 2          # highest exponent bit: a loud flip
            bits = jax.lax.bitcast_convert_type(jnp.ravel(x), uint)
            bits = bits.at[idx].set(bits[idx] ^ jnp.asarray(1 << bit, uint))
            bad = jax.lax.bitcast_convert_type(bits, x.dtype).reshape(x.shape)
    else:
        raise ValueError(f"{spec.kind!r} is not a payload-corruption kind")
    if spec.rank is not None and spec.axis is not None:
        on_rank = jax.lax.axis_index(spec.axis) == spec.rank
        bad = jnp.where(on_rank, bad, x)
    return bad


def taint(point: str, x):
    """Device-side fault seam: identity unless ``point`` is armed at trace
    time, in which case the corruption is baked into the traced function.
    Place after the payload is produced (post-ppermute / dispatcher return).
    """
    if point not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {point!r}")
    sp = CONTROLLER.trace_spec(point)
    if sp is None:
        return x
    return corrupt_array(x, sp)


def io_fault(point: str, step: int) -> None:
    """Host-side fault seam: raise/sleep per the armed spec (``drop_write`` /
    ``truncate_write`` are handled by the caller via :func:`io_spec_for` —
    they mutate a file, not control flow)."""
    sp = CONTROLLER.io_spec(point, step)
    if sp is None:
        return
    if sp.kind == "hang":
        time.sleep(sp.sleep_s)
    elif sp.kind == "persist_exc":
        raise InjectedFault(f"injected persist exception at step {step}")
    else:
        raise ValueError(
            f"{sp.kind!r} must be applied by the caller (io_spec_for)")


def io_spec_for(point: str, step: int, kinds) -> Optional[FaultSpec]:
    """Caller-applied host faults (file drop/truncate): the armed spec for
    ``point``/``step`` if its kind is in ``kinds``, else None."""
    with CONTROLLER._lock:
        for sp in CONTROLLER._specs:
            if sp.point == point and sp.step == step and sp.kind in kinds:
                k = (point, sp.kind, sp.step)
                if CONTROLLER._io_counts.get(k, 0) >= sp.times:
                    continue
                CONTROLLER._io_counts[k] = CONTROLLER._io_counts.get(k, 0) + 1
                CONTROLLER.fired.append(k)
                return sp
    return None


def slow_spec_for(point: str, step: int,
                  rank: Optional[int] = None) -> Optional[FaultSpec]:
    """The armed ``slow`` spec covering ``(point, step, rank)``, or None.

    A ``slow`` fault is *windowed*: it matches every step in
    ``[spec.step, spec.step + spec.span)`` (a degraded component stays
    degraded), and when the spec pins a ``rank`` only that rank of the timed
    section sees the delay. Deterministic by construction — whether the delay
    fires is a pure function of (spec, step, rank), so a rollback replay
    through the fault window degrades identically. The caller (the
    :mod:`repro.ft.straggler` timer) executes ``sleep_s`` per unit of work
    inside the matching section; each match is marked in
    ``CONTROLLER.fired``.
    """
    if point not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {point!r}")
    with CONTROLLER._lock:
        for sp in CONTROLLER._specs:
            if sp.kind != "slow" or sp.point != point:
                continue
            if not sp.step <= step < sp.step + sp.span:
                continue
            if sp.rank is not None and sp.rank != rank:
                continue
            CONTROLLER.fired.append((point, "slow", step))
            return sp
    return None


class InjectedFault(RuntimeError):
    """An exception raised by an armed ``persist_exc`` fault."""


def trace_with_faults(fn, *args, specs):
    """Jit-trace ``fn`` with ``specs`` armed and return the faulty compiled
    twin. The arm window covers exactly one trace (the first call), so the
    baked corruption is deterministic and the global controller is clean on
    exit; the caller invokes the twin only at the scheduled step.

    The trace runs through a fresh closure: jax's jit cache is keyed on the
    function object, so jitting ``fn`` directly would silently reuse an
    existing *clean* trace of the same function (and bake no corruption) —
    or worse, leave a faulty executable in the cache for later clean users.
    """
    import jax
    fjit = jax.jit(lambda *a: fn(*a))   # unique identity -> fresh trace
    with armed(specs):
        out = fjit(*args)
    jax.block_until_ready(jax.tree.leaves(out))
    return fjit


def make_injector(specs):
    """A ``run_with_recovery``-compatible ``fault_injector(step, state)`` for
    ``train.step`` faults: state-level bitflip/nan/spike (applied to params)
    and host hangs, scheduled by ``spec.step`` and bounded by ``spec.times``.
    """
    import jax
    specs = [s for s in specs if s.point == "train.step"]
    counts: Dict[int, int] = {}

    def injector(step: int, state):
        for i, sp in enumerate(specs):
            if sp.step != step or counts.get(i, 0) >= sp.times:
                continue
            counts[i] = counts.get(i, 0) + 1
            CONTROLLER.fired.append((sp.point, sp.kind, sp.step))
            if sp.kind == "hang":
                time.sleep(sp.sleep_s)
            else:
                params = jax.tree.map(lambda x: corrupt_array(x, sp),
                                      state.params)
                state = state._replace(params=params)
        return state

    return injector
