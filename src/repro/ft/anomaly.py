"""Anomaly detection (survey §8.2) — statistical monitoring of a training run.

Three detectors feed a :class:`Monitor`:

- **NaN/Inf** in loss or grad-norm (model instability / numerical failure);
- **loss spike**: loss > running-median + k·MAD over a trailing window
  (the classic loss-spike symptom of data corruption or bad restarts);
- **straggler / hang**: a heartbeat watchdog — step wall-times exceeding
  ``hang_factor ×`` the trailing median flag a slow/hung worker (survey §8.1:
  stragglers silently degrade MFU long before anything crashes).

The monitor only *detects*; recovery policy lives in ``repro.ft.recovery``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Deque, List, Optional


@dataclasses.dataclass
class Anomaly:
    kind: str          # "nan" | "spike" | "hang" | "sdc" | "ckpt_io"
                       # | "straggler" (noted by the ft/straggler attribution)
    step: int
    detail: str


class Monitor:
    def __init__(self, window: int = 32, spike_mads: float = 10.0,
                 hang_factor: float = 5.0, min_history: int = 8,
                 hang_min_seconds: float = 1e-3, flight=None):
        self.window = window
        self.spike_mads = spike_mads
        self.hang_factor = hang_factor
        self.min_history = min_history
        # absolute floor below which a slow step is never a "hang" — with
        # sub-ms steps the relative test alone would flag scheduler jitter
        self.hang_min_seconds = hang_min_seconds
        # optional repro.ft.flight.FlightRecorder: every recorded step and
        # every anomaly (statistical or noted) lands in the crash black box
        self.flight = flight
        self.losses: Deque[float] = deque(maxlen=window)
        self.times: Deque[float] = deque(maxlen=window)
        self.anomalies: List[Anomaly] = []
        self._last_beat: Optional[float] = None
        # the first interval after start / restore / remesh includes JIT
        # compile (or restore replay) wall-time; letting it into the window
        # would inflate the trailing median and mask real slowdowns until it
        # scrolls out — it is discarded, not just exempted from the hang test
        self._skip_next_interval = True

    @staticmethod
    def _median(xs) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def record(self, step: int, loss: float, grad_norm: float,
               now: Optional[float] = None) -> Optional[Anomaly]:
        """Feed one step's metrics; returns an Anomaly if detected."""
        now = time.time() if now is None else now
        out: Optional[Anomaly] = None

        if not math.isfinite(loss) or not math.isfinite(grad_norm):
            out = Anomaly("nan", step,
                          f"loss={loss} grad_norm={grad_norm}")
        elif len(self.losses) >= self.min_history:
            med = self._median(self.losses)
            mad = self._median([abs(l - med) for l in self.losses]) + 1e-12
            if loss > med + self.spike_mads * mad and loss > med * 1.5:
                out = Anomaly("spike", step,
                              f"loss={loss:.4f} median={med:.4f} mad={mad:.4f}")

        if self._last_beat is not None:
            dt = now - self._last_beat
            if self._skip_next_interval:
                # compile/restore wall-time, not a step time: discard
                self._skip_next_interval = False
            else:
                hung = False
                if len(self.times) >= self.min_history:
                    med_t = self._median(self.times)
                    if dt > self.hang_factor * med_t \
                            and dt > self.hang_min_seconds:
                        hung = True
                        out = out or Anomaly(
                            "hang", step,
                            f"step_time={dt:.3f}s median={med_t:.3f}s")
                if not hung:
                    self.times.append(dt)  # only healthy wall-times enter the
                                           # window, mirroring the loss window
        self._last_beat = now

        if out is None and math.isfinite(loss):
            self.losses.append(loss)     # only healthy points enter the window
        if out:
            self.anomalies.append(out)
        if self.flight is not None:
            self.flight.record("step", step, loss=loss, grad_norm=grad_norm)
            if out:
                self.flight.record("anomaly", step, anomaly=out.kind,
                                   detail=out.detail)
        return out

    def note(self, kind: str, step: int, detail: str = "") -> Anomaly:
        """Record an externally-detected anomaly (integrity-checksum
        divergence -> "sdc", exhausted persist retries -> "ckpt_io"): the
        statistical detectors above can't see these, but they belong in the
        same audit trail and policy routing."""
        a = Anomaly(kind, step, detail)
        self.anomalies.append(a)
        if self.flight is not None:
            self.flight.record("anomaly", step, anomaly=kind, detail=detail)
        return a

    def reset_heartbeat(self, now: Optional[float] = None) -> None:
        """Restart the hang watchdog clock (call after a checkpoint restore —
        restore wall-time is not a step time and must not trip a hang).

        The *next* interval is discarded too: after a remesh/rebalance the
        first step re-JITs, and after any restore the first beat straddles
        replay bookkeeping — compile spikes must never enter ``times``."""
        self._last_beat = time.time() if now is None else now
        self._skip_next_interval = True
