"""Fail-slow defense (survey §8.1): per-rank straggler attribution and
Malleus-style pipeline rebalancing.

A fail-slow component — one degraded device, NIC, or host — silently drags
every collective down long before anything crashes, and the hang watchdog's
single global wall-clock test can only say "a step was slow", not *who* or
*why*. This module adds the missing layer:

- :class:`StragglerTimer` — lightweight host-side timing telemetry around the
  jitted step plus named sections (pipeline stage ticks, TP/CP ring segments,
  kernel dispatch, data fetch, checkpoint persist), each mapped to a
  component class in :data:`SECTION_CLASSES`;
- :class:`StragglerDetector` — a sliding-window relative-slowdown detector:
  rank-resolved sections compare each rank against the *median of its peers
  at the same step* (normalized by expected work share, so an intentionally
  uneven ``pp_layout`` is not a false positive), global sections against
  their own trailing-window median; ``confirm`` consecutive slow
  observations raise a :class:`Straggler` event attributing
  ``(rank, component, class ∈ {compute, comm, host-io})``, logged to the
  flight recorder;
- :func:`choose_pp_layout` — the mitigation: re-partition layers-per-stage
  from measured per-stage times (Malleus-style uneven pipelining), minimizing
  the pipeline's bottleneck stage time given the degradation. The recovery
  driver applies it via ``RecoveryPolicy.straggler = "rebalance"`` and a
  checkpoint reshard restore (``ParallelPlan.pp_layout`` is a layout axis).

Measurement model: in a multi-host deployment every rank's host runs this
timer and reports ``(rank, section, seconds)`` into the detector. In this
single-process SPMD container there is one host clock, so host-measurable
sections (data fetch, checkpoint persist, the jitted step itself) are timed
for real, while per-stage / per-ring-rank shares are *modeled* from the
measured step wall time and the plan's partition — and any armed ``slow``
fault (:func:`repro.ft.inject.slow_spec_for`) sleeps *inside* the matching
section for its rank, so injected fail-slow degrades real wall-clock
throughput end to end and the detector sees exactly what a per-host timer
would.

Interplay with the hang watchdog: a large injected/real slowdown can also
trip :class:`repro.ft.anomaly.Monitor`'s hang test (it is the same wall
time); the driver gives statistical anomalies priority, so tune
``hang_min_seconds`` above the expected fail-slow delay when the straggler
ladder should own the response.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from contextlib import contextmanager
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.config import ParallelPlan, RecoveryPolicy
from . import inject as _inject

# section -> component class of the attribution triple
SECTION_CLASSES: Dict[str, str] = {
    "step.compute": "compute",     # the jitted step's own wall time
    "pp.stage": "compute",         # per-pipeline-stage tick share
    "kernel.dispatch": "compute",  # fused-kernel dispatch seam
    "tp.ring": "comm",             # overlap-TP collective-matmul ring
    "cp.ring": "comm",             # context-parallel KV / SSD-state ring
    "ep.a2a": "comm",              # expert-parallel dispatch/combine a2a ring
    "data.fetch": "host-io",       # host batch synthesis / loading
    "ckpt.persist": "host-io",     # checkpoint snapshot + persist
}

# section -> the ft/inject fault points whose armed `slow` specs the timer
# polls (and sleeps for) inside that section
SECTION_POINTS: Dict[str, Tuple[str, ...]] = {
    "step.compute": ("train.step",),
    "pp.stage": ("pp.stage.tick",),
    "kernel.dispatch": ("kernel.attention", "kernel.expert_gemm",
                        "kernel.ssd"),
    "tp.ring": ("tp.ring.tick",),
    "cp.ring": ("cp.ring.kv", "cp.ring.state"),
    "ep.a2a": ("ep.a2a.tick",),
    "data.fetch": ("data.fetch",),
    "ckpt.persist": ("ckpt.persist",),
}


@dataclasses.dataclass
class Straggler:
    """One confirmed fail-slow attribution: *who* (rank), *where* (section),
    *what kind* (compute | comm | host-io), and *how bad* (slowdown ratio
    vs the peer/trailing baseline, per unit of expected work)."""
    rank: Optional[int]    # section rank (pipeline stage / ring position);
                           # None for global sections (step, data, ckpt)
    section: str
    cls: str               # "compute" | "comm" | "host-io"
    step: int
    slowdown: float        # dt / baseline, work-normalized
    detail: str = ""


def effective_layout(plan: Optional[ParallelPlan],
                     cfg=None) -> Optional[Tuple[int, ...]]:
    """The layers-per-stage tuple a plan implies, or None without a pipeline.

    ``plan.pp_layout`` when set; else the even ``n_layers / pp`` split (needs
    ``cfg``); None when ``pp <= 1`` or the split is unknowable.
    """
    if plan is None or getattr(plan, "pp", 1) <= 1:
        return None
    if getattr(plan, "pp_layout", None):
        return tuple(plan.pp_layout)
    if cfg is None or cfg.n_layers % plan.pp != 0:
        return None
    return (cfg.n_layers // plan.pp,) * plan.pp


def choose_pp_layout(stage_seconds: Dict[int, float],
                     layout: Tuple[int, ...]) -> Tuple[int, ...]:
    """Malleus-style uneven re-partition from measured per-stage times.

    ``stage_seconds[r]`` is stage ``r``'s measured tick time under
    ``layout``; its per-layer cost is ``t_r / layout[r]`` (a degraded stage
    is slow *per unit of work*, so shedding layers genuinely shortens its
    tick). Layers are then re-assigned greedily — each next layer goes to the
    stage whose resulting load is smallest — which minimizes the bottleneck
    stage time (the pipeline's steady-state period) under the one-layer-per-
    stage floor. Deterministic: ties break on the lowest stage index.
    """
    pp = len(layout)
    n_layers = sum(layout)
    if pp < 2 or not stage_seconds:
        return tuple(layout)
    fallback = sum(stage_seconds.values()) / len(stage_seconds)
    cost = [max(stage_seconds.get(r, fallback), 1e-12) / max(layout[r], 1)
            for r in range(pp)]
    new = [1] * pp
    for _ in range(n_layers - pp):
        r = min(range(pp), key=lambda i: ((new[i] + 1) * cost[i], i))
        new[r] += 1
    return tuple(new)


class StragglerDetector:
    """Sliding-window relative-slowdown detector with per-rank attribution.

    Two observation modes:

    - :meth:`observe_group` — rank-resolved sections (pipeline stages, ring
      positions): each rank's time is normalized by its expected work share
      (``weights``) and compared against the *median of its peers at the
      same step*. Robust to global noise (compile, host jitter hits every
      rank equally) and to intentionally uneven layouts.
    - :meth:`observe` — global single-series sections (the step itself, data
      fetch, checkpoint persist): compared against the series' own
      trailing-window median, with the first post-:meth:`reset` step
      discarded (compile/restore time must not poison the baseline — the
      same hygiene as ``Monitor``'s heartbeat).

    A rank/section must be slow ``confirm`` times *in a row* before an event
    is emitted (detection latency = ``confirm`` steps, measured by
    ``bench_straggler``); the streak then restarts, so a persistent straggler
    re-fires every ``confirm`` steps and the recovery ladder gets repeated
    escalation chances. Raw (un-normalized) times are kept per
    ``(section, rank)`` for :meth:`recent` — the rebalancer wants the
    *degraded* stage times, so history is recorded slow or not.
    """

    def __init__(self, window: int = 16, factor: float = 2.0,
                 confirm: int = 3, min_seconds: float = 5e-3,
                 min_history: int = 4, flight=None):
        self.window = window
        self.factor = factor
        self.confirm = confirm
        self.min_seconds = min_seconds
        self.min_history = min_history
        self.flight = flight
        self.events: List[Straggler] = []
        self._hist: Dict[Tuple[str, Optional[int]], Deque[float]] = {}
        self._streak: Dict[Tuple[str, Optional[int]], int] = {}
        # first observed step after construction/reset is discarded for the
        # own-history series (JIT compile / restore wall time)
        self._grace_pending = True
        self._grace_step: Optional[int] = None

    @staticmethod
    def _median(xs) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def _record(self, section: str, rank: Optional[int], dt: float) -> None:
        key = (section, rank)
        if key not in self._hist:
            self._hist[key] = deque(maxlen=self.window)
        self._hist[key].append(dt)

    def _emit(self, section: str, rank: Optional[int], step: int,
              dt: float, baseline: float) -> Optional[Straggler]:
        """Streak bookkeeping for one slow observation; event on confirm."""
        key = (section, rank)
        self._streak[key] = self._streak.get(key, 0) + 1
        if self._streak[key] < self.confirm:
            return None
        self._streak[key] = 0
        slowdown = dt / max(baseline, 1e-12)
        ev = Straggler(
            rank=rank, section=section, cls=SECTION_CLASSES[section],
            step=step, slowdown=slowdown,
            detail=f"{dt * 1e3:.1f}ms vs baseline {baseline * 1e3:.1f}ms")
        self.events.append(ev)
        if self.flight is not None:
            self.flight.record("straggler", step, rank=rank, section=section,
                               component_class=ev.cls,
                               slowdown=round(slowdown, 3))
        return ev

    def observe_group(self, section: str, step: int,
                      rank_seconds: Dict[int, float],
                      weights: Optional[Dict[int, float]] = None
                      ) -> Optional[Straggler]:
        """Feed one step's rank-resolved section times; cross-rank detection.

        ``weights[r]`` is rank r's expected work share (layers on the stage,
        1.0 for symmetric rings): detection compares *work-normalized* times,
        so an uneven-by-design ``pp_layout`` stays quiet while a degraded
        rank — slow per unit of work — stands out whatever the layout.
        """
        out: Optional[Straggler] = None
        norm = {r: dt / max((weights or {}).get(r, 1.0), 1e-12)
                for r, dt in rank_seconds.items()}
        for rank in sorted(rank_seconds):
            self._record(section, rank, rank_seconds[rank])
            peers = [v for r, v in norm.items() if r != rank]
            if not peers:
                continue
            base = self._median(peers)
            dt = norm[rank]
            if base > 0.0 and dt > self.factor * base \
                    and dt - base > self.min_seconds:
                ev = self._emit(section, rank, step, dt, base)
                out = out or ev
            else:
                self._streak[(section, rank)] = 0
        return out

    def observe(self, section: str, rank: Optional[int], seconds: float,
                step: int) -> Optional[Straggler]:
        """Feed one observation of a single-series section; own-history
        detection against the trailing-window median."""
        if self._grace_pending:
            self._grace_pending = False
            self._grace_step = step
        if step == self._grace_step:
            return None     # compile/restore step: not a baseline sample
        key = (section, rank)
        hist = self._hist.get(key)
        if hist is None or len(hist) < self.min_history:
            self._record(section, rank, seconds)
            return None
        base = self._median(hist)
        if base > 0.0 and seconds > self.factor * base \
                and seconds - base > self.min_seconds:
            return self._emit(section, rank, step, seconds, base)
        self._streak[key] = 0
        self._record(section, rank, seconds)  # only healthy samples enter
        return None                           # the own-history baseline

    def recent(self, section: str, k: Optional[int] = None
               ) -> Dict[Optional[int], float]:
        """Median of the trailing ``k`` (default ``confirm``) raw times per
        rank of ``section`` — the *current-regime* times (for a just-
        confirmed straggler these are the degraded values, which is what the
        rebalancer must plan against; a full-window median would still be
        dominated by healthy pre-fault samples)."""
        k = k if k is not None else self.confirm
        out: Dict[Optional[int], float] = {}
        for (sec, rank), hist in self._hist.items():
            if sec == section and hist:
                out[rank] = self._median(list(hist)[-k:])
        return out

    def reset(self) -> None:
        """Forget all baselines and streaks (call after a restore, rebalance,
        or remesh — the old regime's times are stale) and re-arm the first-
        step grace (the next step re-JITs)."""
        self._hist.clear()
        self._streak.clear()
        self._grace_pending = True
        self._grace_step = None


class StragglerTimer:
    """Host-side telemetry feeding a :class:`StragglerDetector`.

    Usage (the recovery driver wires this up):

    - wrap host-I/O work in :meth:`section` (``data.fetch`` around the batch
      fetch, ``ckpt.persist`` around saves);
    - call :meth:`after_step` once per accepted step with the jitted step's
      measured wall time — it fans the step out into per-stage and per-ring
      shares (modeled from the plan's partition in this single-process
      container; real per-host timers in a fleet), executes any armed
      ``slow`` fault's delay inside the matching section (so injected
      fail-slow is real wall time, work-proportional: a slow *stage* sleeps
      ``sleep_s`` per layer it currently holds — shedding layers via
      rebalance genuinely shortens its tick), feeds the detector, and
      returns the highest-priority confirmed :class:`Straggler` (stage >
      rings > host-I/O > whole-step), if any;
    - :meth:`stage_times` hands the rebalancer the current-regime per-stage
      times; :meth:`reset` clears baselines after any restore/relayout.
    """

    def __init__(self, cfg=None, plan: Optional[ParallelPlan] = None,
                 detector: Optional[StragglerDetector] = None,
                 policy: Optional[RecoveryPolicy] = None, flight=None):
        if detector is None:
            pol = policy or RecoveryPolicy()
            detector = StragglerDetector(
                window=pol.straggler_window, factor=pol.straggler_factor,
                confirm=pol.straggler_confirm,
                min_seconds=pol.straggler_min_seconds, flight=flight)
        elif flight is not None and detector.flight is None:
            detector.flight = flight
        self.cfg = cfg
        self.plan = plan
        self.detector = detector
        self._pending: List[Straggler] = []

    def _slow_sleep(self, section: str, step: int, rank: Optional[int],
                    units: float = 1.0) -> float:
        """Execute (and return) the armed ``slow`` delay for this section's
        rank at this step: ``sleep_s`` per unit of work."""
        for point in SECTION_POINTS[section]:
            sp = _inject.slow_spec_for(point, step, rank)
            if sp is not None:
                delay = sp.sleep_s * units
                time.sleep(delay)
                return delay
        return 0.0

    @contextmanager
    def section(self, name: str, step: int, rank: Optional[int] = None):
        """Time a host-side section (``data.fetch`` / ``ckpt.persist``),
        executing any armed ``slow`` delay inside it; a confirmed event is
        queued and surfaced by the next :meth:`after_step`."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._slow_sleep(name, step, rank)
            dt = time.perf_counter() - t0
            ev = self.detector.observe(name, rank, dt, step)
            if ev is not None:
                self._pending.append(ev)

    def after_step(self, step: int, step_seconds: float,
                   plan: Optional[ParallelPlan] = None
                   ) -> Optional[Straggler]:
        """Per-step telemetry fan-out; returns the top confirmed event."""
        plan = plan if plan is not None else self.plan
        events: List[Optional[Straggler]] = []

        layout = effective_layout(plan, self.cfg)
        if layout is not None:
            total = sum(layout)
            shares: Dict[int, float] = {}
            for r, n_l in enumerate(layout):
                extra = self._slow_sleep("pp.stage", step, r, units=n_l)
                shares[r] = step_seconds * (n_l / total) + extra
            events.append(self.detector.observe_group(
                "pp.stage", step, shares,
                weights={r: float(n_l) for r, n_l in enumerate(layout)}))

        for section, size in (("tp.ring", getattr(plan, "tp", 1) or 1),
                              ("cp.ring", getattr(plan, "cp", 1) or 1)):
            if plan is not None and size > 1:
                shares = {}
                for r in range(size):
                    extra = self._slow_sleep(section, step, r)
                    shares[r] = step_seconds / size + extra
                events.append(
                    self.detector.observe_group(section, step, shares))

        events.extend(self._pending)
        self._pending = []

        step_ev = self.detector.observe("step.compute", None, step_seconds,
                                        step)
        events.append(step_ev)
        k_extra = self._slow_sleep("kernel.dispatch", step, None)
        k_ev = self.detector.observe("kernel.dispatch", None,
                                     step_seconds + k_extra, step)
        if step_ev is None:
            # only attribute to the dispatch seam when the step series itself
            # stayed quiet (a whole-step slowdown is not a kernel's fault)
            events.append(k_ev)

        for ev in events:
            if ev is not None:
                return ev
        return None

    def stage_times(self) -> Dict[int, float]:
        """Current-regime per-stage tick times for :func:`choose_pp_layout`."""
        return {r: t for r, t in self.detector.recent("pp.stage").items()
                if r is not None}

    def reset(self) -> None:
        self.detector.reset()
        self._pending = []
