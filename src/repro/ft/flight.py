"""Crash flight recorder (survey §8.1/§8.2, MegaScale-style) — a bounded
ring buffer of structured per-step events dumped to JSON for post-mortem
attribution.

At cluster scale the expensive half of a failure is rarely the restart — it
is the hours spent reconstructing *which* rank/step broke and what the run
did about it. The flight recorder is the always-on answer: every component
of the fault-tolerance stack logs into one bounded ring
(:class:`FlightRecorder`), and the ring is dumped to a parseable JSON file
the moment something goes wrong:

- :class:`repro.ft.anomaly.Monitor` logs a ``"step"`` event per recorded
  step (loss, grad-norm, wall-time) and an ``"anomaly"`` event per
  detection (statistical or externally noted);
- :func:`repro.ft.recovery.run_with_recovery` logs ``"policy"`` decisions
  (anomaly kind → action), ``"restore"`` events (which tier served it:
  memory / memory-rebuild / disk), ``"fault"`` events for every injected
  fault that fired (:mod:`repro.ft.inject`), and ``"preempt"`` events;
- :class:`repro.checkpoint.store.CheckpointManager` and
  :class:`repro.checkpoint.memory.MemoryCheckpointTier` log checkpoint/tier
  events (saves, persist failures, GC evictions, verify-before-evict skips).

The ring is bounded (``maxlen``, knob ``RecoveryPolicy.flight_len``) so a
month-long run carries a constant-size black box. ``dump()`` writes
atomically (tmp + ``os.replace``) and sanitizes values, so it is safe to
call from an exception handler mid-crash; the dump path is carried on
:class:`repro.ft.recovery.RunReport` (and on ``RecoveryExhausted``) so the
autopsy artifact is one attribute away from the failure it describes.
"""

from __future__ import annotations

import json
import os
import time
from collections import deque
from pathlib import Path
from typing import Any, Dict, Optional


def _jsonable(v: Any) -> Any:
    """Best-effort JSON sanitization — a crash dump must never crash."""
    if v is None or isinstance(v, (bool, int, str)):
        return v
    if isinstance(v, float):
        # json rejects nothing here (nan/inf serialize as tokens some
        # parsers refuse) — stringify non-finite floats for portability
        return v if v == v and v not in (float("inf"), float("-inf")) \
            else repr(v)
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    try:
        return float(v)          # numpy / jax scalars
    except (TypeError, ValueError):
        return repr(v)


class FlightRecorder:
    """Bounded ring of structured events + atomic JSON dump.

    ``record(kind, step, **data)`` appends one event (cheap: a dict into a
    deque; safe from the checkpoint persist thread — deque appends are
    atomic under the GIL). ``dump(reason=...)`` writes the whole ring plus
    run-level context to ``path`` (constructor default, overridable per
    call) and returns the path written.
    """

    def __init__(self, maxlen: int = 256, path: Optional[str] = None):
        self.maxlen = int(maxlen)
        self.path = str(path) if path is not None else None
        self.events: deque = deque(maxlen=self.maxlen)
        self.dumped_path: Optional[str] = None
        self._t0 = time.time()

    def record(self, kind: str, step: int, **data: Any) -> None:
        self.events.append({"t": time.time() - self._t0, "kind": kind,
                            "step": int(step), **data})

    def dump(self, reason: str, path: Optional[str] = None,
             extra: Optional[Dict[str, Any]] = None) -> Optional[str]:
        """Atomically write the ring to JSON; returns the path (None when no
        path is configured anywhere). Never raises — a failing black-box
        write must not mask the crash being recorded."""
        out = path or self.path
        if out is None:
            return None
        payload = {
            "reason": reason,
            "wall_time": time.time(),
            "run_seconds": time.time() - self._t0,
            "n_events": len(self.events),
            "maxlen": self.maxlen,
            "extra": _jsonable(extra or {}),
            "events": [_jsonable(e) for e in self.events],
        }
        try:
            out_p = Path(out)
            out_p.parent.mkdir(parents=True, exist_ok=True)
            tmp = out_p.with_name(out_p.name + ".tmp")
            tmp.write_text(json.dumps(payload, indent=1))
            os.replace(tmp, out_p)
        except OSError:
            return self.dumped_path
        self.dumped_path = str(out)
        return self.dumped_path
