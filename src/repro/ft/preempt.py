"""Preemption-aware graceful shutdown (survey §8 / cloud-native spot
fleets, arXiv 2604.17227).

Spot and preemptible capacity is only usable for training if a preemption
notice turns into a *resumable* run instead of a killed one. The cloud
delivers the notice as a signal (SIGTERM, or SIGUSR1 from a scheduler)
with a grace window before the host is reclaimed; this module turns that
into a clean between-steps exit:

- :class:`PreemptionGuard` installs signal handlers (context manager —
  previous handlers restored on exit) that do nothing but set a flag and
  timestamp; all real work happens on the training thread, because a
  signal handler interrupting a JAX dispatch must not touch the runtime.
- :func:`repro.ft.recovery.run_with_recovery` checks the flag between
  steps. On preemption it flushes the in-flight async snapshot
  (``ckpt.wait()``), takes a just-in-time blocking snapshot, writes a
  ``PREEMPTED`` marker (:func:`write_marker`), dumps the flight recorder,
  and returns a report with ``preempted=True`` — so ``--resume`` continues
  bit-identically from the JIT snapshot.
- Tier choice is budget-driven: the guard's remaining grace
  (:meth:`PreemptionGuard.remaining`) is compared against the checkpoint
  manager's *measured* snapshot+persist seconds (with headroom). Disk wins
  whenever it fits — it survives the process. The memory tier is the
  fallback when the grace window is too short for disk I/O: on a real
  fleet the peer-mirrored RAM copy survives on neighbor hosts
  (:mod:`repro.checkpoint.memory`), so a sub-second RAM snapshot is still
  a recoverable checkpoint; in this single-process reproduction that path
  is exercised for timing but durability comes from disk.

The marker file makes the exit legible to the relauncher: ``--resume``
reads it (:func:`read_marker`), logs the preemption step, and clears it
(:func:`clear_marker`) once the run is re-established.
"""

from __future__ import annotations

import json
import os
import signal
import time
from pathlib import Path
from typing import Any, Dict, Optional

MARKER_NAME = "PREEMPTED"


class PreemptionGuard:
    """Flag-setting SIGTERM/SIGUSR1 handler with a grace-deadline clock.

    Use as a context manager around the training loop::

        with PreemptionGuard(grace=30.0) as guard:
            run_with_recovery(..., preempt=guard)

    ``requested`` flips True in the handler (async-signal-safe: assignment
    only); ``remaining()`` counts down the grace budget from the moment the
    signal landed. ``signals=()`` (or installing in a non-main thread,
    where CPython forbids ``signal.signal``) degrades to a manually
    triggerable flag — :meth:`trigger` — which tests use for deterministic
    in-process preemption.
    """

    def __init__(self, grace: float = 30.0,
                 signals=(signal.SIGTERM, signal.SIGUSR1)):
        self.grace = float(grace)
        self.signals = tuple(signals)
        self.requested = False
        self.signum: Optional[int] = None
        self.at_time: Optional[float] = None
        self._prev: Dict[int, Any] = {}

    def _handler(self, signum, frame):  # noqa: ARG002 - signal signature
        if not self.requested:          # first notice starts the clock
            self.requested = True
            self.signum = signum
            self.at_time = time.time()

    def trigger(self, signum: int = signal.SIGTERM) -> None:
        """Set the flag without a real signal (deterministic tests)."""
        self._handler(signum, None)

    def install(self) -> "PreemptionGuard":
        for s in self.signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except (ValueError, OSError):   # non-main thread / exotic signum
                continue
        return self

    def uninstall(self) -> None:
        for s, prev in self._prev.items():
            try:
                signal.signal(s, prev)
            except (ValueError, OSError):
                continue
        self._prev.clear()

    def __enter__(self) -> "PreemptionGuard":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    def remaining(self) -> float:
        """Seconds of grace left (``grace`` when no signal has landed)."""
        if self.at_time is None:
            return self.grace
        return max(0.0, self.grace - (time.time() - self.at_time))


def choose_tier(guard: PreemptionGuard, ckpt, mem=None,
                headroom: float = 0.8) -> str:
    """``"disk"`` or ``"memory"`` for the just-in-time snapshot.

    Disk whenever the manager's measured snapshot+persist time fits inside
    ``headroom`` × the remaining grace (durability beats speed), or when no
    memory tier exists, or when nothing has been measured yet (first
    checkpoint — no basis to distrust disk). Memory only when measurements
    say disk will blow the deadline.
    """
    if mem is None:
        return "disk"
    est = ckpt.snapshot_seconds + ckpt.d2h_seconds + ckpt.persist_seconds
    if est <= 0.0 or est <= headroom * guard.remaining():
        return "disk"
    return "memory"


def marker_path(directory) -> Path:
    return Path(directory) / MARKER_NAME


def write_marker(directory, step: int, tier: str,
                 signum: Optional[int] = None,
                 flight_path: Optional[str] = None) -> Path:
    """Atomically drop the ``PREEMPTED`` marker next to the checkpoints."""
    p = marker_path(directory)
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = {"step": int(step), "tier": tier, "signum": signum,
               "flight": flight_path, "time": time.time()}
    tmp = p.with_name(p.name + ".tmp")
    tmp.write_text(json.dumps(payload))
    os.replace(tmp, p)
    return p


def read_marker(directory) -> Optional[Dict[str, Any]]:
    """The marker's payload, or None when absent/unreadable."""
    p = marker_path(directory)
    try:
        return json.loads(p.read_text())
    except (OSError, json.JSONDecodeError, UnicodeDecodeError):
        return None


def clear_marker(directory) -> None:
    marker_path(directory).unlink(missing_ok=True)
