from repro.core.config import RecoveryPolicy
from .anomaly import Anomaly, Monitor
from .recovery import RemeshSpec, RunReport, run_with_recovery

__all__ = ["Anomaly", "Monitor", "RecoveryPolicy", "RemeshSpec",
           "RunReport", "run_with_recovery"]
