"""Fault tolerance (survey §8): detection, recovery, and chaos testing.

- :mod:`repro.ft.anomaly` — statistical detectors (nan/inf, spike, hang)
  plus externally-noted kinds (sdc, ckpt_io);
- :mod:`repro.ft.recovery` — the policy-table recovery driver, restoring
  memory-tier-first (:mod:`repro.checkpoint.memory`) with a verified disk
  walk as the fallback;
- :mod:`repro.ft.preempt` — SIGTERM/SIGUSR1 preemption guard: just-in-time
  snapshot within a grace budget, ``PREEMPTED`` marker, clean resumable
  exit;
- :mod:`repro.ft.flight` — the crash flight recorder: a bounded ring of
  per-step events dumped to JSON on preemption/crash/RecoveryExhausted;
- :mod:`repro.ft.inject` — deterministic seeded fault injection at named
  fault points (the registry is ``inject.FAULT_POINTS``; see that module's
  docstring for how to add a point);
- :mod:`repro.ft.integrity` — device-side SDC checksums cross-checked
  across replicas (``plan.integrity = "audit"``);
- :mod:`repro.ft.straggler` — fail-slow defense: per-rank/per-component
  straggler attribution from host-side timing telemetry, and Malleus-style
  uneven pipeline rebalancing (:func:`choose_pp_layout` →
  ``ParallelPlan.pp_layout``) as the mitigation.
"""

from repro.core.config import RecoveryPolicy
from .anomaly import Anomaly, Monitor
from .flight import FlightRecorder
from .preempt import (PreemptionGuard, clear_marker, read_marker,
                      write_marker)
from .recovery import (RecoveryExhausted, RemeshSpec, RunReport,
                       run_with_recovery)
from .straggler import (Straggler, StragglerDetector, StragglerTimer,
                        choose_pp_layout, effective_layout)

__all__ = ["Anomaly", "FlightRecorder", "Monitor", "PreemptionGuard",
           "RecoveryExhausted", "RecoveryPolicy", "RemeshSpec", "RunReport",
           "Straggler", "StragglerDetector", "StragglerTimer",
           "choose_pp_layout", "clear_marker", "effective_layout",
           "read_marker", "run_with_recovery", "write_marker"]
