"""Fault tolerance (survey §8): detection, recovery, and chaos testing.

- :mod:`repro.ft.anomaly` — statistical detectors (nan/inf, spike, hang)
  plus externally-noted kinds (sdc, ckpt_io);
- :mod:`repro.ft.recovery` — the policy-table recovery driver;
- :mod:`repro.ft.inject` — deterministic seeded fault injection at named
  fault points (the registry is ``inject.FAULT_POINTS``; see that module's
  docstring for how to add a point);
- :mod:`repro.ft.integrity` — device-side SDC checksums cross-checked
  across replicas (``plan.integrity = "audit"``).
"""

from repro.core.config import RecoveryPolicy
from .anomaly import Anomaly, Monitor
from .recovery import (RecoveryExhausted, RemeshSpec, RunReport,
                       run_with_recovery)

__all__ = ["Anomaly", "Monitor", "RecoveryExhausted", "RecoveryPolicy",
           "RemeshSpec", "RunReport", "run_with_recovery"]
