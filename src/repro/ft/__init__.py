from .anomaly import Anomaly, Monitor
from .recovery import RunReport, run_with_recovery

__all__ = ["Anomaly", "Monitor", "RunReport", "run_with_recovery"]
