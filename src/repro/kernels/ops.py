"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the model layers use when ``plan.use_pallas`` style
flags are enabled (on real TPU hardware; the CPU container exercises them in
interpret mode through the tests and benchmarks).
"""

from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention as _flash
from .grouped_gemm import expert_gemm as _expert_gemm
from .ssd_scan import ssd_chunk_scan as _ssd


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
                    block_q=128, block_k=128, interpret=True):
    """(B, Hq, S, hd) attention; GQA via kv-head broadcast in the index map."""
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, block_q=block_q, block_k=block_k,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "block_c", "block_f", "block_d", "interpret"))
def expert_gemm(x, w, *, block_c=128, block_f=128, block_d=256, interpret=True):
    """(E, C, d) × (E, d, f) -> (E, C, f) per-expert GEMM."""
    return _expert_gemm(x, w, block_c=block_c, block_f=block_f,
                        block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=True):
    """Fused Mamba2 SSD: (B,H,L,P) inputs -> (y, final_state); the intra-chunk
    decay matrices and the running state stay in VMEM."""
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
