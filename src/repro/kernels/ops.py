"""Jit'd public wrappers around the Pallas kernels.

These are the entry points model layers reach through the dispatch layer
(``repro.kernels.dispatch``, driven by ``ParallelPlan.attn_impl``). On real
TPU hardware they compile; the CPU container exercises them in interpret mode
(``interpret=None`` auto-detects the backend).
"""

from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention as _flash
from .grouped_gemm import expert_gemm as _expert_gemm
from .ssd_scan import ssd_chunk_scan as _ssd


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "q_offset", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
                    q_offset=0, block_q=128, block_k=128, interpret=None):
    """(B, Hq, S, hd) attention; GQA via kv-head broadcast in the index map.

    Differentiable: ``jax.grad`` through this runs the FlashAttention-2-style
    dq / dkv Pallas kernels (see flash_attention.py).
    """
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, q_offset=q_offset, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "block_c", "block_f", "block_d", "interpret"))
def expert_gemm(x, w, *, block_c=128, block_f=128, block_d=256, interpret=True):
    """(E, C, d) × (E, d, f) -> (E, C, f) per-expert GEMM."""
    return _expert_gemm(x, w, block_c=block_c, block_f=block_f,
                        block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=True):
    """Fused Mamba2 SSD: (B,H,L,P) inputs -> (y, final_state); the intra-chunk
    decay matrices and the running state stay in VMEM."""
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
