"""Jit'd public wrappers around the Pallas kernels.

These are the entry points model layers reach through the dispatch layer
(``repro.kernels.dispatch``, driven by ``ParallelPlan.attn_impl`` /
``moe_gemm_impl`` / ``ssm_impl``). On real TPU hardware they compile; the CPU
container exercises them in interpret mode (``interpret=None`` auto-detects
the backend for every op). All three are differentiable — ``jax.grad``
through them runs the custom-VJP Pallas backward kernels.
"""

from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention as _flash
from .grouped_gemm import expert_gemm as _expert_gemm
from .ssd_scan import ssd_chunk_scan as _ssd


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "q_offset", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
                    q_offset=0, block_q=128, block_k=128, interpret=None):
    """(B, Hq, S, hd) attention; GQA via kv-head broadcast in the index map.

    Differentiable: ``jax.grad`` through this runs the FlashAttention-2-style
    dq / dkv Pallas kernels (see flash_attention.py).
    """
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, q_offset=q_offset, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "block_c", "block_f", "block_d", "interpret"))
def expert_gemm(x, w, group_sizes=None, *, block_c=128, block_f=128,
                block_d=256, interpret=None):
    """(E, C, d) × (E, d, f) -> (E, C, f) per-expert GEMM; ``group_sizes``
    masks each expert's padding rows out of the output and both gradients.

    Differentiable: the backward runs two more grouped GEMMs (dx = dy·wᵀ,
    dw = xᵀ·dy) through the same tiled kernel (see grouped_gemm.py).
    """
    return _expert_gemm(x, w, group_sizes, block_c=block_c, block_f=block_f,
                        block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    """Fused Mamba2 SSD: (B,H,L,P) inputs -> (y, final_state); the intra-chunk
    decay matrices and the running state stay in VMEM.

    Differentiable: the forward saves only per-chunk entering states and the
    backward kernel recomputes the decay/score tiles (see ssd_scan.py).
    """
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
