"""Mamba2 SSD chunk scan — fused, differentiable Pallas TPU kernel.

§Perf pair B localized mamba2/zamba2's residual memory term to the SSD
intra-chunk intermediates: the pure-JAX ``ssd_scan`` materializes per-chunk
decay matrices ``L = exp(segsum(dA))`` of shape (b, c, h, q, q) plus carried
states to HBM every layer and every pass. This kernel fuses the whole chunk
pipeline — decay computation, intra-chunk "attention" (C·Bᵀ ∘ L)·x, carried-
state contribution, and the inter-chunk state recurrence — so only x/dt/B/C
stream in and y streams out; L and the running state never leave VMEM, in
either pass.

Layout (TPU adaptation — same pattern as flash_attention.py):

- grid = (batch, heads, n_chunks) with the chunk dim minor: the (p, n) running
  state lives in VMEM scratch across chunk steps (the recurrence the GPU
  implementation does with a separate kernel launch + global memory round
  trip).
- B/C are per-group; the index_map maps head -> group (h // heads_per_group),
  so grouped state projections are never repeated in HBM.
- VMEM working set per step ≈ x(q·p) + B,C(q·n) + L(q·q) + state(p·n)
  ≈ 128·(64+128+128+128)·4 ≈ 230 KB — far under budget, with q=chunk=128
  MXU-aligned.

Backward follows the FlashAttention-2 recipe (PAPERS.md): the forward
additionally saves only the state *entering* each chunk — an (nc, p, n) strip
per (batch, head), the logsumexp analogue — and a reversed-grid backward
kernel recomputes the decay matrix ``L`` and the intra-chunk scores tile by
tile in VMEM to produce ``dx/ddt/dA/dB/dC``:

- grid = (batch, heads, n_chunks) sweeping chunks *last to first* (the index
  maps flip the chunk coordinate); the state cotangent ``dS`` rides across
  steps in VMEM scratch, seeded by the final-state cotangent, propagated by
  ``dS_in = exp(cs[-1])·dS_out + (dy ∘ exp(cs))ᵀ·C``.
- per-chunk, all (q, q) quantities (L, scores, dscores) are recomputed from
  the streamed-in x/dt/B/C, never written to HBM.
- the kernel emits ``dda`` (cotangent of the per-step log-decay ``dt·A``)
  alongside ``ddt``; outside, ``dA_h = Σ dda·dt`` and the per-head dB/dC are
  group-summed (the GQA trick from the attention backward).

``jax.custom_vjp`` ties the two kernels together, so ``jax.grad`` through
:func:`ssd_chunk_scan` never materializes a (b, c, h, q, q) decay tensor.

``interpret=None`` auto-detects the backend: compiled on TPU, interpreter
everywhere else.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_attention import resolve_interpret


def _chunk_decay(dt, a):
    """Shared per-chunk decay math: (da, cs, L) with L strictly in registers/VMEM."""
    da = dt * a                                   # (q,) log-decays
    cs = jnp.cumsum(da)                           # (q,)
    q = cs.shape[0]
    li = cs[:, None] - cs[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    # mask *before* exp: the masked (upper) entries hold positive log-decays
    # that could overflow fp32 for long chunks / large dt·|A|
    L = jnp.exp(jnp.where(tri, li, -jnp.inf))
    return da, cs, L


# ---------------------------------------------------------------------------
# forward


def _fwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, *refs,
                n_chunks: int):
    # refs = (enter_ref?, state_out_ref, state_ref): the entering-states
    # residual output only exists when the VJP will need it — forward-only
    # calls (eval/decode) skip that extra HBM write entirely
    enter_ref = refs[0] if len(refs) == 3 else None
    state_out_ref, state_ref = refs[-2], refs[-1]
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (q, p)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (q,)
    a = a_ref[0]                                  # scalar A (negative)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (q, n)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (q, n)

    xd = x * dt[:, None]
    _, cs, L = _chunk_decay(dt, a)

    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot(scores, xd, preferred_element_type=jnp.float32)

    # carried-state contribution: y += exp(cs) * C @ state  (state: (p, n))
    state = state_ref[...]
    if enter_ref is not None:
        enter_ref[0, 0, 0] = state.astype(enter_ref.dtype)  # backward residual
    y = y + jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state recurrence: state' = exp(cs[-1])·state + Σ_q exp(cs[-1]-cs)·xdᵀB
    decay_states = jnp.exp(cs[-1] - cs)           # (q,)
    state_new = (state * jnp.exp(cs[-1])
                 + jax.lax.dot_general(xd * decay_states[:, None], bmat,
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_ref[...] = state_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_new.astype(state_out_ref.dtype)


def _ssd_forward(x, dt, A, Bm, Cm, chunk, interpret, save_enters: bool):
    """Returns (y (B,H,L,P) fp32, entering states (B,H,nc,P,N) fp32 or None,
    final_state (B,H,P,N) fp32). ``save_enters`` is True only under the VJP —
    forward-only calls skip the residual's HBM write."""
    b, h, l, p = x.shape
    g, n = Bm.shape[1], Bm.shape[3]
    assert l % chunk == 0, (l, chunk)
    assert h % g == 0
    hpg = h // g
    nc = l // chunk
    grid = (b, h, nc)

    out_specs = [
        pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
        pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, l, p), jnp.float32),
        jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
    ]
    if save_enters:
        out_specs.insert(1, pl.BlockSpec(
            (1, 1, 1, p, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)))
        out_shape.insert(1, jax.ShapeDtypeStruct((b, h, nc, p, n),
                                                 jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_fwd_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, g_=hpg: (bi, hi // g_, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, g_=hpg: (bi, hi // g_, ci, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    if save_enters:
        return outs[0], outs[1], outs[2]
    return outs[0], None, outs[1]


# ---------------------------------------------------------------------------
# backward


def _bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, enter_ref, dy_ref,
                dsf_ref, dx_ref, ddt_ref, dda_ref, db_ref, dc_ref,
                dstate_ref):
    ci = pl.program_id(2)   # reversed sweep: index maps flip to chunk nc-1-ci

    @pl.when(ci == 0)
    def _init():
        # seed with the final-state cotangent
        dstate_ref[...] = dsf_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # (q, p)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (q,)
    a = a_ref[0]
    bmat = b_ref[0, 0].astype(jnp.float32)       # (q, n)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (q, n)
    sin = enter_ref[0, 0, 0].astype(jnp.float32)  # (p, n) state entering chunk
    dy = dy_ref[0, 0].astype(jnp.float32)        # (q, p)
    ds_out = dstate_ref[...]                      # (p, n) cotangent of S_out

    xd = x * dt[:, None]
    _, cs, L = _chunk_decay(dt, a)
    exp_cs = jnp.exp(cs)
    decay_states = jnp.exp(cs[-1] - cs)           # (q,)

    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    scores = cb * L

    # --- intra-chunk "attention" term: y_diag = scores @ xd
    dscores = jax.lax.dot_general(dy, xd, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (q, q)
    dxd = jax.lax.dot_general(scores, dy, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)      # (q, p)
    dcb = dscores * L

    # --- carried-state term: y_off = exp(cs) ∘ (C @ sinᵀ)
    y_off = exp_cs[:, None] * jax.lax.dot_general(
        cmat, sin, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                            # (q, p)
    dy_e = dy * exp_cs[:, None]
    dc = (jax.lax.dot(dy_e, sin, preferred_element_type=jnp.float32)
          + jax.lax.dot(dcb, bmat, preferred_element_type=jnp.float32))

    # --- state-recurrence term: S_out = exp(cs[-1])·sin + Σ ds_i·xd_i⊗B_i
    xd_ds = jax.lax.dot(xd, ds_out, preferred_element_type=jnp.float32)  # (q, n)
    dxd = dxd + decay_states[:, None] * jax.lax.dot_general(
        bmat, ds_out, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    db = (decay_states[:, None] * xd_ds
          + jax.lax.dot_general(dcb, cmat, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))

    # --- cotangent of the cumulative log-decays cs
    G = dscores * scores                           # dL ∘ L, zero above diagonal
    dcs = G.sum(axis=1) - G.sum(axis=0)
    dcs = dcs + (dy * y_off).sum(axis=-1)          # exp(cs) factor in y_off
    t = decay_states * (xd_ds * bmat).sum(axis=-1)  # exp(cs[-1]-cs) factor
    dcs = dcs - t
    # the two cs[-1] contributions (Σt from decay_states, exp(cs[-1])·sin term)
    # land on every entry of the reverse cumsum below, so fold them into the
    # total instead of scattering into index q-1
    last = t.sum() + jnp.exp(cs[-1]) * (ds_out * sin).sum()

    # cs = cumsum(da)  =>  dda_i = Σ_{j>=i} dcs_j  (+ last, which sits at j=q-1)
    dda = (dcs.sum() + last) - jnp.cumsum(dcs) + dcs

    ddt = dda * a + (dxd * x).sum(axis=-1)
    dx = dxd * dt[:, None]

    # propagate the state cotangent to the previous chunk
    dstate_ref[...] = (jnp.exp(cs[-1]) * ds_out
                       + jax.lax.dot_general(dy_e, cmat, (((0,), (0,)), ((), ())),
                                             preferred_element_type=jnp.float32))

    dx_ref[0, 0] = dx.astype(dx_ref.dtype)
    ddt_ref[0, 0] = ddt.astype(ddt_ref.dtype)
    dda_ref[0, 0] = dda.astype(dda_ref.dtype)
    db_ref[0, 0] = db.astype(db_ref.dtype)
    dc_ref[0, 0] = dc.astype(dc_ref.dtype)


def _ssd_backward(chunk, interpret, res, g):
    x, dt, A, Bm, Cm, enters = res
    dy, dsf = g
    b, h, l, p = x.shape
    grp, n = Bm.shape[1], Bm.shape[3]
    hpg = h // grp
    nc = l // chunk
    grid = (b, h, nc)
    rev = nc - 1   # index maps sweep chunks last -> first

    dx, ddt, dda, db, dc = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci, 0)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, g_=hpg, r=rev: (bi, hi // g_, r - ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, g_=hpg, r=rev: (bi, hi // g_, r - ci, 0)),
            pl.BlockSpec((1, 1, 1, p, n),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci, 0)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rev: (bi, hi, r - ci, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, l), jnp.float32),
            jax.ShapeDtypeStruct((b, h, l), jnp.float32),
            jax.ShapeDtypeStruct((b, h, l, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, l, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, enters,
      dy.astype(jnp.float32), dsf.astype(jnp.float32))

    # per-head B/C gradients -> group-sum onto the shared projection (GQA trick)
    dB = db.reshape(b, grp, hpg, l, n).sum(axis=2).astype(Bm.dtype)
    dC = dc.reshape(b, grp, hpg, l, n).sum(axis=2).astype(Cm.dtype)
    # da = dt·A  =>  dA_h = Σ_{b,l} dda·dt (cheap elementwise reduction in XLA)
    dA = jnp.einsum("bhl,bhl->h", dda, dt.astype(jnp.float32)).astype(A.dtype)
    return dx.astype(x.dtype), ddt.astype(dt.dtype), dA, dB, dC


# ---------------------------------------------------------------------------
# custom_vjp plumbing


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, A, Bm, Cm, chunk, interpret):
    y, _, state = _ssd_forward(x, dt, A, Bm, Cm, chunk, interpret,
                               save_enters=False)
    return y, state


def _ssd_fwd(x, dt, A, Bm, Cm, chunk, interpret):
    y, enters, state = _ssd_forward(x, dt, A, Bm, Cm, chunk, interpret,
                                    save_enters=True)
    # named for selective remat (models.families.REMAT_SAVE_NAMES): the
    # per-chunk entering states are the only activation-sized residual the
    # fused backward consumes
    y = checkpoint_name(y, "ssd_out")
    enters = checkpoint_name(enters, "ssd_state")
    return (y, state), (x, dt, A, Bm, Cm, enters)


_ssd.defvjp(_ssd_fwd, _ssd_backward)


def ssd_chunk_scan(
    x: jax.Array,        # (B, H, L, P)
    dt: jax.Array,       # (B, H, L)
    A: jax.Array,        # (H,) negative decay rates
    Bm: jax.Array,       # (B, G, L, N)
    Cm: jax.Array,       # (B, G, L, N)
    *,
    chunk: int = 128,
    interpret: Optional[bool] = None,   # None -> compiled on TPU, interpreted elsewhere
):
    """Fused differentiable SSD. Returns (y (B, H, L, P) fp32,
    final_state (B, H, P, N) fp32)."""
    return _ssd(x, dt, A, Bm, Cm, int(chunk), resolve_interpret(interpret))
