"""Mamba2 SSD chunk scan — fused Pallas TPU kernel.

§Perf pair B localized mamba2/zamba2's residual memory term to the SSD
intra-chunk intermediates: the pure-JAX ``ssd_scan`` materializes per-chunk
decay matrices ``L = exp(segsum(dA))`` of shape (b, c, h, q, q) plus carried
states to HBM every layer and every pass. This kernel fuses the whole chunk
pipeline — decay computation, intra-chunk "attention" (C·Bᵀ ∘ L)·x, carried-
state contribution, and the inter-chunk state recurrence — so only x/dt/B/C
stream in and y streams out; L and the running state never leave VMEM.

Layout (TPU adaptation — same pattern as flash_attention.py):

- grid = (batch, heads, n_chunks) with the chunk dim minor: the (p, n) running
  state lives in VMEM scratch across chunk steps (the recurrence the GPU
  implementation does with a separate kernel launch + global memory round
  trip).
- B/C are per-group; the index_map maps head -> group (h // heads_per_group),
  so grouped state projections are never repeated in HBM.
- VMEM working set per step ≈ x(q·p) + B,C(q·n) + L(q·q) + state(p·n)
  ≈ 128·(64+128+128+128)·4 ≈ 230 KB — far under budget, with q=chunk=128
  MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (q, p)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (q,)
    a = a_ref[0]                                  # scalar A (negative)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (q, n)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (q, n)

    xd = x * dt[:, None]
    da = dt * a                                   # (q,) log-decays
    cs = jnp.cumsum(da)                           # (q,)

    # intra-chunk decay kernel: L[i, j] = exp(cs[i] - cs[j]) for i >= j
    q = cs.shape[0]
    li = cs[:, None] - cs[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    L = jnp.where(tri, jnp.exp(li), 0.0)

    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot(scores, xd, preferred_element_type=jnp.float32)

    # carried-state contribution: y += exp(cs) * C @ state  (state: (p, n))
    state = state_ref[...]
    y = y + jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state recurrence: state' = exp(cs[-1])·state + Σ_q exp(cs[-1]-cs)·xdᵀB
    decay_states = jnp.exp(cs[-1] - cs)           # (q,)
    state_new = (state * jnp.exp(cs[-1])
                 + jax.lax.dot_general(xd * decay_states[:, None], bmat,
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_ref[...] = state_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_new.astype(state_out_ref.dtype)


def ssd_chunk_scan(
    x: jax.Array,        # (B, H, L, P)
    dt: jax.Array,       # (B, H, L)
    A: jax.Array,        # (H,) negative decay rates
    Bm: jax.Array,       # (B, G, L, N)
    Cm: jax.Array,       # (B, G, L, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y (B, H, L, P) fp32, final_state (B, H, P, N) fp32)."""
    b, h, l, p = x.shape
    g, n = Bm.shape[1], Bm.shape[3]
    assert l % chunk == 0, (l, chunk)
    assert h % g == 0
    hpg = h // g
    nc = l // chunk
    grid = (b, h, nc)

    y, state = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, g_=hpg: (bi, hi // g_, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, g_=hpg: (bi, hi // g_, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return y, state
