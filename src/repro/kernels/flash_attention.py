"""FlashAttention forward — Pallas TPU kernel (survey §5.1.1, TPU adaptation).

The CUDA FlashAttention organizes around SMs, warps and shared memory; the TPU
version (DESIGN.md §2) organizes around the grid + BlockSpec machinery:

- grid = (batch, q_heads, S/block_q, T/block_k); the KV-block dim is minor, so
  for a fixed query tile the kernel sweeps KV tiles sequentially while online-
  softmax state (m, l, acc) lives in VMEM scratch across grid steps —
  the TPU equivalent of the CUDA inner loop over KV tiles in shared memory.
- BlockSpec index_maps implement GQA natively: query head h reads KV head
  h // group, so repeated KV never materializes in HBM.
- block shapes default to 128 (MXU-aligned); the last dim (head_dim) is kept
  whole inside VMEM (128/256 for all assigned archs).
- causal + sliding-window + logit-softcap masks are computed from global tile
  offsets with iota, and fully-masked tiles exit early via ``pl.when``.

VMEM working set per step ≈ q(128·hd) + k,v(128·hd) + scores(128·128) + acc —
well under the ~16 MB budget for hd ≤ 256.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            block_q: int, block_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile-level skip: causal / window can rule out whole tiles
    relevant = jnp.bool_(True)
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window > 0:
        # oldest key in tile must be within reach of at least one query in it
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (rows < seq_q) & (cols < seq_k)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                 # (B, Hq, S, hd)
    k: jax.Array,                 # (B, Hkv, T, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,       # CPU container: validate in interpret mode
) -> jax.Array:
    b, hq, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else hd ** -0.5

    block_q = min(block_q, s)
    block_k = min(block_k, t)
    s_pad = -(-s // block_q) * block_q
    t_pad = -(-t // block_k) * block_k
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))

    grid = (b, hq, s_pad // block_q, t_pad // block_k)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, block_q=block_q, block_k=block_k,
            seq_q=s, seq_k=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, h, qi, ki: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s_pad, hd), q.dtype),
        scratch_shapes=_scratch(block_q, hd),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :]


def _scratch(block_q: int, hd: int):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((block_q,), jnp.float32),          # m
        pltpu.VMEM((block_q,), jnp.float32),          # l
        pltpu.VMEM((block_q, hd), jnp.float32),       # acc
    ]
