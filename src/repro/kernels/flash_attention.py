"""FlashAttention — differentiable Pallas TPU kernel (survey §5.1.1).

The CUDA FlashAttention organizes around SMs, warps and shared memory; the TPU
version (DESIGN.md §2) organizes around the grid + BlockSpec machinery:

- forward grid = (batch, q_heads, S/block_q, T/block_k); the KV-block dim is
  minor, so for a fixed query tile the kernel sweeps KV tiles sequentially
  while online-softmax state (m, l, acc) lives in VMEM scratch across grid
  steps — the TPU equivalent of the CUDA inner loop over KV tiles in shared
  memory.
- BlockSpec index_maps implement GQA natively: query head h reads KV head
  h // group, so repeated KV never materializes in HBM.
- block shapes default to 128 (MXU-aligned); the last dim (head_dim) is kept
  whole inside VMEM (128/256 for all assigned archs).
- causal + sliding-window + logit-softcap masks are computed from global tile
  offsets with iota (``q_offset`` shifts query positions for chunked prefill),
  and fully-masked tiles exit early via ``pl.when``.

Backward follows FlashAttention-2's one-write/two-reads split (PAPERS.md
"FlashAttention2"): the forward additionally emits the per-row logsumexp
``lse = m + log l`` (one extra S-sized vector per head instead of the O(S·T)
probability matrix), and two kernels recompute tiled scores from it:

- ``_dq_kernel``  — grid (..., S/bq, T/bk), KV minor: accumulates dq for a
  fixed query tile across KV tiles in VMEM scratch (one write per q row).
- ``_dkv_kernel`` — grid (..., T/bk, S/bq), Q minor: accumulates dk and dv for
  a fixed KV tile across query tiles (one write per k row).

Each recomputes p = exp(s - lse) and ds = p * (dO·Vᵀ - Δ) with
Δ = rowsum(dO ∘ O) (computed once in XLA before the kernels — cheap,
elementwise). GQA gradients are emitted per query head and group-summed
outside the kernel. ``jax.custom_vjp`` ties the three kernels together, so
``jax.grad`` through :func:`flash_attention` never materializes score
matrices in HBM.

VMEM working set per step ≈ q(128·hd) + k,v(128·hd) + scores(128·128) + acc —
well under the ~16 MB budget for hd ≤ 256.

``interpret=None`` auto-detects the backend: compiled on TPU, interpreter
everywhere else (CPU containers validate correctness through the same code
path).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.experimental import pallas as pl

NEG_INF = -1e30


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> backend detection: compiled on TPU, interpreter elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _tile_relevant(q_start, k_start, *, causal: bool, window: int,
                   q_offset: int, block_q: int, block_k: int):
    """Whole-tile skip: causal / sliding-window can rule out (q, k) tile pairs."""
    relevant = jnp.bool_(True)
    if causal:
        relevant = k_start <= q_offset + q_start + block_q - 1
    if window > 0:
        # oldest key in tile must be within reach of at least one query in it
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_offset + q_start - window)
    return relevant


def _tile_mask(q_start, k_start, *, causal: bool, window: int, q_offset: int,
               block_q: int, block_k: int, seq_q: int, seq_k: int):
    """(block_q, block_k) boolean mask from global tile offsets."""
    rows_l = q_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    cols = k_start + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    rows_g = q_offset + rows_l
    mask = (rows_l < seq_q) & (cols < seq_k)
    if causal:
        mask &= cols <= rows_g
    if window > 0:
        mask &= (rows_g - cols) < window
    return mask


# ---------------------------------------------------------------------------
# forward


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                scale: float, causal: bool, window: int, softcap: float,
                q_offset: int, block_q: int, block_k: int,
                seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(_tile_relevant(q_start, k_start, causal=causal, window=window,
                            q_offset=q_offset, block_q=block_q,
                            block_k=block_k))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)

        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          q_offset=q_offset, block_q=block_q, block_k=block_k,
                          seq_q=seq_q, seq_k=seq_k)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def _fwd_scratch(block_q: int, hd: int):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((block_q,), jnp.float32),          # m
        pltpu.VMEM((block_q,), jnp.float32),          # l
        pltpu.VMEM((block_q, hd), jnp.float32),       # acc
    ]


def _pad_seq(x, axis: int, target: int):
    if x.shape[axis] == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pads)


def _flash_forward(q, k, v, causal, window, softcap, scale, q_offset,
                   block_q, block_k, interpret):
    """Returns (o (B,Hq,S,hd), lse (B,Hq,S) fp32)."""
    b, hq, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv

    block_q = min(block_q, s)
    block_k = min(block_k, t)
    s_pad = -(-s // block_q) * block_q
    t_pad = -(-t // block_k) * block_k
    q = _pad_seq(q, 2, s_pad)
    k = _pad_seq(k, 2, t_pad)
    v = _pad_seq(v, 2, t_pad)

    grid = (b, hq, s_pad // block_q, t_pad // block_k)

    out, lse = pl.pallas_call(
        functools.partial(
            _fwd_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, q_offset=q_offset, block_q=block_q,
            block_k=block_k, seq_q=s, seq_k=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda bi, h, qi, ki: (bi, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, s_pad, hd), q.dtype),
            jax.ShapeDtypeStruct((b, hq, s_pad), jnp.float32),
        ],
        scratch_shapes=_fwd_scratch(block_q, hd),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :], lse[:, :, :s]


# ---------------------------------------------------------------------------
# backward


def _recompute_ds(q, k, v, do, lse, delta, mask, *, scale: float,
                  softcap: float):
    """Shared tile math of both backward kernels.

    Returns (p, ds_raw), both (block_q, block_k) fp32, where p is the
    normalized probability tile and ds_raw = dL/d(q·kᵀ·scale) before the
    scale factor is re-applied to dq/dk.
    """
    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    if softcap:
        th = jnp.tanh(s_raw / softcap)
        s_c = softcap * th
    else:
        s_c = s_raw
    # where() before exp: lse of fully-masked rows is a huge negative number,
    # exp(s - lse) would overflow before the mask could zero it
    p = jnp.exp(jnp.where(mask, s_c - lse[:, None], NEG_INF))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    if softcap:
        ds = ds * (1.0 - th * th)
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref, acc_ref,
               *, scale: float, causal: bool, window: int, softcap: float,
               q_offset: int, block_q: int, block_k: int,
               seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(_tile_relevant(q_start, k_start, causal=causal, window=window,
                            q_offset=q_offset, block_q=block_q,
                            block_k=block_k))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          q_offset=q_offset, block_q=block_q, block_k=block_k,
                          seq_q=seq_q, seq_k=seq_k)
        _, ds = _recompute_ds(q, k, v, do, lse_ref[0, 0], dl_ref[0, 0], mask,
                              scale=scale, softcap=softcap)
        acc_ref[...] += jax.lax.dot(
            ds, k, preferred_element_type=jnp.float32) * scale

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, scale: float, causal: bool, window: int, softcap: float,
                q_offset: int, block_q: int, block_k: int,
                seq_q: int, seq_k: int):
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(_tile_relevant(q_start, k_start, causal=causal, window=window,
                            q_offset=q_offset, block_q=block_q,
                            block_k=block_k))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          q_offset=q_offset, block_q=block_q, block_k=block_k,
                          seq_q=seq_q, seq_k=seq_k)
        p, ds = _recompute_ds(q, k, v, do, lse_ref[0, 0], dl_ref[0, 0], mask,
                              scale=scale, softcap=softcap)
        # contract the query dim: pᵀ·do and dsᵀ·q without explicit transposes
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _flash_backward(causal, window, softcap, scale, q_offset, block_q,
                    block_k, interpret, res, g):
    q, k, v, o, lse = res
    do = g.astype(jnp.float32)
    delta = jnp.sum(do * o.astype(jnp.float32), axis=-1)      # (B, Hq, S)
    return flash_attention_bwd(
        q, k, v, do, lse, delta, causal=causal, window=window,
        softcap=softcap, scale=scale, q_offset=q_offset, block_q=block_q,
        block_k=block_k, interpret=interpret)


def flash_attention_bwd(q, k, v, do, lse, delta, *, causal, window, softcap,
                        scale, q_offset, block_q, block_k, interpret):
    """Backward kernels against an externally supplied softmax statistic.

    This is the lse-merging chunk entry of the backward: ``lse``/``delta`` may
    come from a *larger* softmax than (k, v) — ring context parallelism passes
    the globally merged logsumexp and Δ = rowsum(dO ∘ O_global) while (k, v)
    is one ring chunk, and the emitted (dq, dk, dv) are exactly that chunk's
    contribution to the global attention gradient. ``_flash_backward`` (the
    single-device custom-VJP rule) is the degenerate one-chunk case.

    Layouts are head-major: q/do (B, Hq, S, hd); k/v (B, Hkv, T, hd);
    lse/delta (B, Hq, S) fp32. Returns (dq, dk, dv) with dk/dv group-summed
    back onto the shared KV heads.
    """
    b, hq, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    group = hq // hkv

    block_q = min(block_q, s)
    block_k = min(block_k, t)
    s_pad = -(-s // block_q) * block_q
    t_pad = -(-t // block_k) * block_k
    qp = _pad_seq(q, 2, s_pad)
    dop = _pad_seq(do, 2, s_pad)
    lsep = _pad_seq(lse, 2, s_pad)
    deltap = _pad_seq(delta, 2, s_pad)
    kp = _pad_seq(k, 2, t_pad)
    vp = _pad_seq(v, 2, t_pad)

    kwargs = dict(scale=scale, causal=causal, window=window, softcap=softcap,
                  q_offset=q_offset, block_q=block_q, block_k=block_k,
                  seq_q=s, seq_k=t)
    from jax.experimental.pallas import tpu as pltpu

    q_spec = pl.BlockSpec((1, 1, block_q, hd),
                          lambda bi, h, i, j: (bi, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, hd),
                           lambda bi, h, i, j, g=group: (bi, h // g, j, 0))
    row_spec = pl.BlockSpec((1, 1, block_q), lambda bi, h, i, j: (bi, h, i))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **kwargs),
        grid=(b, hq, s_pad // block_q, t_pad // block_k),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, h, i, j: (bi, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s_pad, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    # dk/dv grids put the query-tile dim minor so the accumulators carry; the
    # q-side specs therefore index with the *minor* grid coordinate
    q_spec_t = pl.BlockSpec((1, 1, block_q, hd),
                            lambda bi, h, i, j: (bi, h, j, 0))
    kv_spec_t = pl.BlockSpec((1, 1, block_k, hd),
                             lambda bi, h, i, j, g=group: (bi, h // g, i, 0))
    row_spec_t = pl.BlockSpec((1, 1, block_q), lambda bi, h, i, j: (bi, h, j))
    dkv_out = pl.BlockSpec((1, 1, block_k, hd),
                           lambda bi, h, i, j: (bi, h, i, 0))

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **kwargs),
        grid=(b, hq, t_pad // block_k, s_pad // block_q),
        in_specs=[q_spec_t, kv_spec_t, kv_spec_t, q_spec_t, row_spec_t,
                  row_spec_t],
        out_specs=[dkv_out, dkv_out],
        out_shape=[jax.ShapeDtypeStruct((b, hq, t_pad, hd), jnp.float32),
                   jax.ShapeDtypeStruct((b, hq, t_pad, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    # GQA: gradients were emitted per query head; sum each group back onto
    # its shared KV head
    dk = dk[:, :, :t].reshape(b, hkv, group, t, hd).sum(axis=2)
    dv = dv[:, :, :t].reshape(b, hkv, group, t, hd).sum(axis=2)
    return (dq[:, :, :s].astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


# ---------------------------------------------------------------------------
# custom_vjp plumbing


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash(q, k, v, causal, window, softcap, scale, q_offset, block_q,
           block_k, interpret):
    o, _ = _flash_forward(q, k, v, causal, window, softcap, scale, q_offset,
                          block_q, block_k, interpret)
    return o


def _flash_fwd(q, k, v, causal, window, softcap, scale, q_offset, block_q,
               block_k, interpret):
    o, lse = _flash_forward(q, k, v, causal, window, softcap, scale, q_offset,
                            block_q, block_k, interpret)
    # named for selective remat (models.families.REMAT_SAVE_NAMES): saving
    # (out, lse) lets jax.checkpoint keep exactly the backward's residuals
    # instead of re-running the forward kernel
    o = checkpoint_name(o, "flash_out")
    lse = checkpoint_name(lse, "flash_lse")
    return o, (q, k, v, o, lse)


_flash.defvjp(_flash_fwd, _flash_backward)


def flash_attention(
    q: jax.Array,                 # (B, Hq, S, hd)
    k: jax.Array,                 # (B, Hkv, T, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,            # global position of q[.., 0, ..] (chunked prefill)
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,   # None -> compiled on TPU, interpreted elsewhere
) -> jax.Array:
    """Fused differentiable attention. Mask parameters must be static."""
    hd = q.shape[-1]
    scale = float(scale) if scale is not None else hd ** -0.5
    return _flash(q, k, v, bool(causal), int(window), float(softcap), scale,
                  int(q_offset), int(block_q), int(block_k),
                  resolve_interpret(interpret))


def flash_attention_lse(
    q: jax.Array,                 # (B, Hq, S, hd)
    k: jax.Array,                 # (B, Hkv, T, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
):
    """Forward kernel that also returns the per-row logsumexp.

    The lse-merging entry for chunked softmax (ring context parallelism,
    survey §4.1.4): partial attention over one KV chunk returns
    ``(o_c, lse_c)`` and chunks merge exactly via
    ``lse = log Σ_c exp(lse_c)``, ``o = Σ_c exp(lse_c - lse) · o_c``.
    Fully-masked rows report ``lse ≈ NEG_INF`` (finite), so they drop out of
    the merge without producing NaNs. Not differentiable — ring attention owns
    the custom VJP and calls :func:`flash_attention_bwd` per chunk with the
    *merged* statistics. Returns (o (B, Hq, S, hd), lse (B, Hq, S) fp32).
    """
    hd = q.shape[-1]
    scale = float(scale) if scale is not None else hd ** -0.5
    return _flash_forward(q, k, v, bool(causal), int(window), float(softcap),
                          scale, int(q_offset), int(block_q), int(block_k),
                          resolve_interpret(interpret))
