"""Pure-jnp oracles for every Pallas kernel (the allclose + gradient reference)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import attention_direct


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale: Optional[float] = None):
    """(B, Hq, S, hd) layout oracle (kernels use head-major layout)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = attention_direct(qt, kt, vt, causal=causal, window=window,
                           softcap=softcap, scale=scale)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def expert_gemm_ref(x, w, group_sizes=None):
    """x: (E, C, d), w: (E, d, f) -> (E, C, f) batched per-expert GEMM.
    ``group_sizes`` (E,) zeroes each expert's padding rows (same semantics as
    the kernel's row masking)."""
    if group_sizes is not None:
        rows = jnp.arange(x.shape[1])[None, :, None]
        x = jnp.where(rows < group_sizes[:, None, None], x, 0)
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(x, dt, A, B, C, chunk):
    from repro.models.ssm import ssd_scan
    return ssd_scan(x, dt, A, B, C, chunk)
