"""Kernel dispatch — pick an implementation per call site, per op.

This is the architecture hook for every fused kernel: model code calls the
per-op dispatcher (:func:`dispatch_attention` via ``repro.models.layers``,
:func:`dispatch_expert_gemm` via ``repro.models.moe._expert_ffn``,
:func:`dispatch_ssd_scan` via ``repro.models.ssm.ssm_block``) with the
matching ``ParallelPlan`` knob (``attn_impl`` / ``moe_gemm_impl`` /
``ssm_impl``), and the dispatcher decides, per call site, whether the fused
Pallas kernel or the XLA twin runs. Shared rules (:func:`_resolve_choice`):

- ``impl="xla"``    — always the pure-XLA twin (also the gradient oracle).
- ``impl="pallas"`` — the fused kernel whenever its static preconditions hold
  (attention: compile-time mask params; SSD: no initial state); XLA otherwise.
- ``impl="auto"``   — Pallas iff running on a TPU backend and the
  preconditions hold. Off-TPU the Pallas interpreter validates correctness
  but is orders of magnitude slower, so auto never selects it — tests and
  benchmarks opt in with ``impl="pallas"``.

Every fused kernel here is differentiable (``jax.custom_vjp`` recompute
backwards), so the dispatchers sit on the training path, not just prefill.

Layout contracts: model code uses batch-major layouts ((B, S, H, hd) for
attention, (B, L, H, P) for SSD); the kernels use head-major. The dispatchers
own the transposes, plus the boundary padding for unaligned lengths (KV to the
block boundary for blockwise attention, the sequence to the chunk boundary for
SSD — never a silent fall-back to a quadratic whole-sequence path).

Ring context parallelism (``train/executor.py``) gets two extra attention
entries: :func:`dispatch_attention_lse` (per-chunk forward that also returns
the logsumexp — the lse-merging chunked-softmax tile) and
:func:`dispatch_attention_chunk_bwd` (per-chunk backward against the globally
merged (lse, Δ)); :func:`select_cp_impl` resolves ``ParallelPlan.cp_impl``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.inject import taint
from repro.models import layers as _layers
from .flash_attention import (_pad_seq, flash_attention, flash_attention_bwd,
                              flash_attention_lse, resolve_interpret)
from .grouped_gemm import expert_gemm
from .ssd_scan import ssd_chunk_scan

IMPLS = ("auto", "xla", "pallas")


def _tainted(point: str):
    """Route a dispatcher's primary output through a named fault point
    (ft/inject): identity unless a FaultSpec is armed at trace time, so the
    production path is untouched while chaos tests can corrupt any fused-op
    output (tuple returns taint their first element)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            out = fn(*args, **kwargs)
            if isinstance(out, tuple):
                return (taint(point, out[0]),) + out[1:]
            return taint(point, out)
        return wrapper
    return deco


def _is_static(x) -> bool:
    return isinstance(x, (int, np.integer))


def _resolve_choice(impl: str, *, knob: str, explicit_ok: bool,
                    auto_ok: bool) -> str:
    """Shared auto|xla|pallas resolution. ``explicit_ok`` gates an explicit
    ``"pallas"`` request (hard preconditions); ``auto_ok`` additionally gates
    ``"auto"`` (soft preferences like lane-friendly shapes)."""
    if impl not in IMPLS:
        raise ValueError(f"{knob} must be one of {IMPLS}, got {impl!r}")
    if impl == "xla":
        return "xla"
    if impl == "pallas":
        return "pallas" if explicit_ok else "xla"
    if explicit_ok and auto_ok and jax.default_backend() == "tpu":
        return "pallas"
    return "xla"


def select_impl(impl: str, *, head_dim: int, window, q_offset) -> str:
    """Resolve the attention impl. Traced mask params (gemma2 local/global
    alternation scans the window as layer metadata) force XLA since Pallas
    masks are compile-time."""
    static = _is_static(window) and _is_static(q_offset)
    return _resolve_choice(
        impl, knob="attn_impl", explicit_ok=static,
        auto_ok=head_dim % 8 == 0 and head_dim <= 256)


TP_IMPLS = ("auto", "gspmd", "overlap")


def select_tp_impl(impl: str) -> str:
    """Resolve ``ParallelPlan.tp_impl`` (survey §4.1.2/§5.2).

    ``"gspmd"`` leaves tensor parallelism to XLA's SPMD partitioner (blocking
    all-reduce after every row GEMM, full-size activations between blocks).
    ``"overlap"`` selects the explicit ``shard_map`` ring path
    (:mod:`repro.train.tensor_parallel`): collective matmuls + sequence-sharded
    activations. ``"auto"`` picks overlap on TPU backends — the ring's
    ``ppermute`` steps compile to async DMAs there, so the per-tick partial
    GEMMs actually hide the transfer — and gspmd elsewhere (on CPU the ring
    is semantically identical but the ticks serialize).
    """
    if impl not in TP_IMPLS:
        raise ValueError(f"tp_impl must be one of {TP_IMPLS}, got {impl!r}")
    if impl == "auto":
        return "overlap" if jax.default_backend() == "tpu" else "gspmd"
    return impl


def dispatch_tp_matmul(x, w, *, impl: str = "auto"):
    """One ring-tick partial GEMM of the collective matmuls.

    ``x``: (..., k) activation tile (one sequence chunk), ``w``: (k, f) weight
    shard. Every partial product of the overlap-TP rings funnels through here
    so the tile GEMM stays a single dispatch point: today it is always the XLA
    dot (bitwise twin of the GSPMD path's local matmul — required by the
    overlap-vs-gspmd equivalence tests); a fused Pallas tile GEMM can slot in
    behind the same signature without touching the ring schedules. The fused
    attention / expert-GEMM / SSD kernels are reached separately — the TP
    layer bodies call :func:`dispatch_attention` / :func:`dispatch_expert_gemm`
    / :func:`dispatch_ssd_scan` on the gathered tiles, so ``tp_impl="overlap"``
    composes with ``attn_impl/moe_gemm_impl/ssm_impl = "pallas"``.
    """
    del impl  # reserved for a fused tile-GEMM kernel
    return jnp.matmul(x, w)


CP_IMPLS = ("auto", "gather", "ring")


def select_cp_impl(impl: str, *, family: str = "dense", window: int = 0,
                   local_global_alternating: bool = False) -> str:
    """Resolve ``ParallelPlan.cp_impl`` (survey §4.1.4, long-context training).

    ``"gather"`` all-gathers K/V over the ``cp`` axis (contiguous sequence
    chunks, Megatron-SP-style): every rank holds the full KV but only its
    query chunk — exact, simple, O(S) KV memory per device. ``"ring"`` keeps
    KV sharded too and ``ppermute``s chunks around the cp ring with zigzag
    causal load balancing — no device ever holds the full context, the
    long-context regime ring attention exists for. ``"auto"`` picks ring
    whenever its static preconditions hold:

    - full causal attention only (sliding windows / gemma2 local-global
      alternation make the ring's static per-pair mask cases traced — gather
      handles them);
    - the SSM family always resolves to ``"ring"``: its cp execution is the
      per-chunk entering-state chain (there is no KV to gather), and the
      zigzag remark doesn't apply (SSD per-position work is uniform, so the
      layout stays contiguous).
    """
    if impl not in CP_IMPLS:
        raise ValueError(f"cp_impl must be one of {CP_IMPLS}, got {impl!r}")
    from repro.core.config import Family  # noqa: PLC0415 (import cycle)
    if family == Family.SSM:
        return "ring"
    ring_ok = not window and not local_global_alternating
    if impl == "ring" and not ring_ok:
        raise ValueError(
            "cp_impl='ring' needs full causal attention (no sliding window / "
            "local-global alternation); use cp_impl='gather'")
    if impl == "auto":
        return "ring" if ring_ok else "gather"
    return impl


def dispatch_attention_lse(q, k, v, *, impl: str = "auto", causal: bool = True,
                           window=0, softcap: float = 0.0, q_offset=0,
                           block_size: int = 1024,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: Optional[bool] = None):
    """Chunk attention that also returns the merged-softmax statistic.

    Batch-major twin of the plain dispatcher: q (B, S, Hq, hd), k/v
    (B, T, Hkv, hd) -> (o (B, S, Hq, hd), lse (B, S, Hq) fp32). This is the
    inner tile of ring context parallelism — per-chunk (o, lse) pairs merge
    exactly across the cp ring (see ``train/executor.py``).
    """
    choice = select_impl(impl, head_dim=q.shape[-1], window=window,
                         q_offset=q_offset)
    if choice == "pallas":
        o, lse = flash_attention_lse(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=int(window),
            softcap=softcap, scale=scale, q_offset=int(q_offset),
            block_q=block_q, block_k=block_k,
            interpret=resolve_interpret(interpret))
        return o.transpose(0, 2, 1, 3), lse.transpose(0, 2, 1)
    t = k.shape[1]
    if t <= 2 * block_size:
        return _layers.attention_direct_lse(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale)
    if t % block_size:
        t_pad = -(-t // block_size) * block_size
        return _layers.attention_blockwise(
            q, _pad_seq(k, 1, t_pad), _pad_seq(v, 1, t_pad), causal=causal,
            window=window, softcap=softcap, q_offset=q_offset,
            block_size=block_size, scale=scale, kv_len=t, return_lse=True)
    return _layers.attention_blockwise(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_size=block_size, scale=scale,
        return_lse=True)


def dispatch_attention_chunk_bwd(q, k, v, do, lse, delta, *,
                                 impl: str = "auto", causal: bool = True,
                                 softcap: float = 0.0, q_offset=0,
                                 scale: Optional[float] = None,
                                 block_q: int = 128, block_k: int = 128,
                                 interpret: Optional[bool] = None):
    """One KV chunk's (dq, dk, dv) against the globally merged (lse, delta).

    Batch-major: q/do (B, S, Hq, hd), k/v (B, T, Hkv, hd), lse/delta
    (B, S, Hq). Routes to the FlashAttention-2 backward kernels
    (:func:`repro.kernels.flash_attention.flash_attention_bwd`) or the XLA
    twin (:func:`repro.models.layers.attention_chunk_grads`).
    """
    choice = select_impl(impl, head_dim=q.shape[-1], window=0,
                         q_offset=q_offset)
    if choice == "pallas":
        hd = q.shape[-1]
        dq, dk, dv = flash_attention_bwd(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), do.transpose(0, 2, 1, 3).astype(
                jnp.float32),
            lse.transpose(0, 2, 1), delta.transpose(0, 2, 1),
            causal=causal, window=0, softcap=softcap,
            scale=float(scale) if scale is not None else hd ** -0.5,
            q_offset=int(q_offset), block_q=block_q, block_k=block_k,
            interpret=resolve_interpret(interpret))
        return (dq.transpose(0, 2, 1, 3), dk.transpose(0, 2, 1, 3),
                dv.transpose(0, 2, 1, 3))
    return _layers.attention_chunk_grads(
        q, k, v, do, lse, delta, causal=causal, window=0, softcap=softcap,
        q_offset=q_offset, scale=scale)


def select_gemm_impl(impl: str) -> str:
    """Resolve the expert-GEMM impl (the kernel pads every dim, so an explicit
    "pallas" is always honored)."""
    return _resolve_choice(impl, knob="moe_gemm_impl", explicit_ok=True,
                           auto_ok=True)


def select_ssd_impl(impl: str, *, has_initial_state: bool = False) -> str:
    """Resolve the SSD impl. The fused kernel starts from a zero state, so a
    caller-supplied initial state falls back to the XLA scan."""
    return _resolve_choice(impl, knob="ssm_impl",
                           explicit_ok=not has_initial_state, auto_ok=True)


# ---------------------------------------------------------------------------
# attention


@_tainted("kernel.attention")
def dispatch_attention(q, k, v, *, impl: str = "auto", causal: bool = True,
                       window=0, softcap: float = 0.0, q_offset=0,
                       block_size: int = 1024,
                       scale: Optional[float] = None,
                       block_q: int = 128, block_k: int = 128,
                       interpret: Optional[bool] = None):
    """q: (B, S, Hq, hd), k/v: (B, T, Hkv, hd) -> (B, S, Hq, hd)."""
    choice = select_impl(impl, head_dim=q.shape[-1], window=window,
                         q_offset=q_offset)
    if choice == "pallas":
        out = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=int(window),
            softcap=softcap, scale=scale, q_offset=int(q_offset),
            block_q=block_q, block_k=block_k,
            interpret=resolve_interpret(interpret))
        return out.transpose(0, 2, 1, 3)

    t = k.shape[1]
    if t <= 2 * block_size:
        return _layers.attention_direct(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale)
    if t % block_size:
        # pad KV to the block boundary and mask the tail — never drop to the
        # O(S·T) direct path just because the context length is unaligned
        t_pad = -(-t // block_size) * block_size
        return _layers.attention_blockwise(
            q, _pad_seq(k, 1, t_pad), _pad_seq(v, 1, t_pad), causal=causal,
            window=window, softcap=softcap, q_offset=q_offset,
            block_size=block_size, scale=scale, kv_len=t)
    return _layers.attention_blockwise(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_size=block_size, scale=scale)


# ---------------------------------------------------------------------------
# MoE expert GEMM


@_tainted("kernel.expert_gemm")
def dispatch_expert_gemm(x, w, group_sizes=None, *, impl: str = "auto",
                         block_c: int = 128, block_f: int = 128,
                         block_d: int = 256,
                         interpret: Optional[bool] = None):
    """x: (E, C, d) × w: (E, d, f) -> (E, C, f); ``group_sizes`` (E,) marks the
    real rows per expert (padding rows are masked out of outputs and grads)."""
    choice = select_gemm_impl(impl)
    if choice == "pallas":
        return expert_gemm(x, w, group_sizes, block_c=block_c,
                           block_f=block_f, block_d=block_d,
                           interpret=resolve_interpret(interpret))
    if group_sizes is not None:
        rows = jnp.arange(x.shape[1])[None, :, None]
        x = jnp.where(rows < jax.lax.stop_gradient(group_sizes)[:, None, None],
                      x, 0)
    return jnp.einsum("ecd,edf->ecf", x, w)


# ---------------------------------------------------------------------------
# EP dispatch/combine all-to-all (expert parallelism, survey §4.1.5)


EP_IMPLS = ("auto", "blocking", "overlap")


def select_ep_impl(impl: str) -> str:
    """Resolve ``ParallelPlan.ep_impl`` (survey §4.1.5/§5.2).

    ``"blocking"`` runs one ``lax.all_to_all`` before and one after the
    expert GEMM — the whole token exchange is exposed on the critical path.
    ``"overlap"`` decomposes each all-to-all into ``ppermute`` ring ticks
    interleaved with per-peer expert-GEMM chunks: every tick computes the
    chunk it already holds while the next is in flight. ``"auto"`` resolves
    to overlap everywhere — unlike the TP ring (where the gspmd baseline is
    a different layout), the EP ring is semantically identical to the
    blocking a2a on every backend, and its ticks compile to async DMAs on
    TPU.
    """
    if impl not in EP_IMPLS:
        raise ValueError(f"ep_impl must be one of {EP_IMPLS}, got {impl!r}")
    return "overlap" if impl == "auto" else impl


def _ep_a2a_blocking(fn, axis, size, w, h):
    """GShard-style exposed exchange: dispatch a2a → expert fn → combine a2a.

    Plain traced (autodiff goes straight through ``lax.all_to_all``), so it
    doubles as the gradient oracle for the custom-VJP overlap ring.
    """
    e, c, d = h.shape
    e_loc = e // size
    hr = h.reshape(size, e_loc, c, d)
    hx = taint("ep.a2a.tick", jax.lax.all_to_all(
        hr, axis, split_axis=0, concat_axis=0, tiled=False))
    # hx[j] = peer j's token chunk for my local experts; block rows per
    # source peer so fn sees one (e_loc, size·C, d) buffer
    hs = hx.transpose(1, 0, 2, 3).reshape(e_loc, size * c, d)
    y = fn(w, hs)
    yr = y.reshape(e_loc, size, c, -1).transpose(1, 0, 2, 3)
    out = jax.lax.all_to_all(yr, axis, split_axis=0, concat_axis=0,
                             tiled=False)
    return out.reshape(e, c, out.shape[-1])


def _ep_overlap_ticks(fn, axis, size, w, h):
    """The shared overlap ring schedule: tick t processes the chunk from
    source peer (r - t) mod N while shipping the next one."""
    n = size
    e, c, d = h.shape
    e_loc = e // n
    r = jax.lax.axis_index(axis)
    hr = h.reshape(n, e_loc, c, d)
    # t = 0: my own chunk, no communication
    chunk0 = jax.lax.dynamic_slice_in_dim(hr, r, 1, axis=0)[0]
    y0 = fn(w, chunk0)
    out = jnp.zeros((n, e_loc, c, y0.shape[-1]), y0.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, y0[None], r, axis=0)
    for t in range(1, n):
        perm_t = [(i, (i + t) % n) for i in range(n)]
        perm_back = [(i, (i - t) % n) for i in range(n)]
        # ship the chunk destined for peer (r+t); receive, from peer (r-t),
        # the chunk it dispatched to my experts
        send = jax.lax.dynamic_slice_in_dim(hr, (r + t) % n, 1, axis=0)[0]
        recv = taint("ep.a2a.tick",
                     jax.lax.ppermute(send, axis, perm_t))
        y = fn(w, recv)
        # return the result to its source; symmetrically receive my chunk's
        # result back from peer (r+t)
        yb = jax.lax.ppermute(y, axis, perm_back)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, yb[None], (r + t) % n, axis=0)
    return out.reshape(e, c, out.shape[-1])


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ep_a2a_overlap(fn, axis, size, w, h):
    return _ep_overlap_ticks(fn, axis, size, w, h)


def _ep_overlap_fwd(fn, axis, size, w, h):
    out = _ep_overlap_ticks(fn, axis, size, w, h)
    # residuals are the *inputs* only — the backward re-runs the dispatch
    # ring to recover the received chunks (remat over the wire, same policy
    # as the tp/cp rings: keep O(E·C) live, trade a second ring of ticks)
    return out, (w, h)


def _ep_overlap_bwd(fn, axis, size, res, dout):
    w, h = res
    n = size
    e, c, d = h.shape
    e_loc = e // n
    r = jax.lax.axis_index(axis)
    hr = h.reshape(n, e_loc, c, d)
    dr = dout.reshape(n, e_loc, c, dout.shape[-1])

    # t = 0: my own chunk's VJP, no communication
    chunk0 = jax.lax.dynamic_slice_in_dim(hr, r, 1, axis=0)[0]
    dy0 = jax.lax.dynamic_slice_in_dim(dr, r, 1, axis=0)[0]
    _, vjp = jax.vjp(fn, w, chunk0)
    dw, dchunk = vjp(dy0)
    dh = jnp.zeros_like(hr)
    dh = jax.lax.dynamic_update_slice_in_dim(dh, dchunk[None], r, axis=0)
    for t in range(1, n):
        perm_t = [(i, (i + t) % n) for i in range(n)]
        perm_back = [(i, (i - t) % n) for i in range(n)]
        # recompute the chunk my experts saw at forward tick t (dispatch
        # direction), and ship the matching output cotangent the same way:
        # source (r-t)'s dout slot for peer r travels the t-step ring too
        recv = jax.lax.ppermute(
            jax.lax.dynamic_slice_in_dim(hr, (r + t) % n, 1, axis=0)[0],
            axis, perm_t)
        dy = jax.lax.ppermute(
            jax.lax.dynamic_slice_in_dim(dr, (r + t) % n, 1, axis=0)[0],
            axis, perm_t)
        _, vjp = jax.vjp(fn, w, recv)
        dw_t, dchunk = vjp(dy)
        dw = jax.tree_util.tree_map(jnp.add, dw, dw_t)
        # dchunk is d/d(source (r-t)'s dispatch buffer for me): ship it back
        # along the combine direction; receive my own chunk's gradient from
        # peer (r+t)
        dback = jax.lax.ppermute(dchunk, axis, perm_back)
        dh = jax.lax.dynamic_update_slice_in_dim(
            dh, dback[None], (r + t) % n, axis=0)
    return dw, dh.reshape(e, c, d)


_ep_a2a_overlap.defvjp(_ep_overlap_fwd, _ep_overlap_bwd)


def dispatch_ep_a2a(fn, w, h, *, axis, size: int, impl: str = "auto"):
    """The EP dispatch → expert-compute → combine exchange, one seam.

    ``h``: (E, C, d) per-rank dispatch buffers for all E *global* experts
    (E divisible by ``size``; each rank owns the e_loc = E/size experts of
    its ring slot, blocked contiguously). ``fn(w, chunk)`` applies the local
    experts to a ``(e_loc, C', d)`` row block and must be row-wise (per-row
    independent, shape-polymorphic in C') so per-peer chunk application
    equals the concatenated buffer — pass a hashable static callable (e.g. a
    ``functools.partial`` of a module-level function); it is traced inside a
    ``custom_vjp`` on the overlap path. ``axis`` is the mesh axis (or axis
    tuple, for the folded cp×model ring) the exchange runs over. Returns the
    combined (E, C, f) buffer in dispatch order.
    """
    choice = select_ep_impl(impl)
    if size == 1:
        return fn(w, h)
    if h.shape[0] % size:
        raise ValueError(
            f"global expert dim {h.shape[0]} must divide ep ring size {size}")
    if choice == "blocking":
        return _ep_a2a_blocking(fn, axis, size, w, h)
    return _ep_a2a_overlap(fn, axis, size, w, h)


# ---------------------------------------------------------------------------
# Mamba2 SSD chunk scan


@_tainted("kernel.ssd")
def dispatch_ssd_scan(x, dt, A, B, C, *, chunk: int, impl: str = "auto",
                      initial_state=None,
                      interpret: Optional[bool] = None):
    """Model layout: x (B, L, H, P), dt (B, L, H), A (H,), B/C (B, L, G, N).
    Returns (y (B, L, H, P) fp32, final_state (B, H, P, N) fp32).

    Unaligned lengths are padded to the chunk boundary with ``dt = 0`` steps
    (decay exp(0)=1, zero input: the state rides through unchanged), never
    collapsed into one whole-sequence chunk with an O(L²) decay matrix.
    """
    from repro.models.ssm import ssd_scan  # noqa: PLC0415 (import cycle)

    b, l, h, p = x.shape
    chunk = min(int(chunk), l)
    l_pad = -(-l // chunk) * chunk
    if l_pad != l:
        x = _pad_seq(x, 1, l_pad)
        dt = _pad_seq(dt, 1, l_pad)
        B = _pad_seq(B, 1, l_pad)
        C = _pad_seq(C, 1, l_pad)

    choice = select_ssd_impl(impl, has_initial_state=initial_state is not None)
    if choice == "pallas":
        y, state = ssd_chunk_scan(
            x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
            B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3), chunk=chunk,
            interpret=resolve_interpret(interpret))
        y = y.transpose(0, 2, 1, 3)
    else:
        y, state = ssd_scan(x, dt, A, B, C, chunk=chunk,
                            initial_state=initial_state)
    return y[:, :l], state
