"""Kernel dispatch — pick an attention implementation per call site.

This is the architecture hook for every fused kernel: model code calls
:func:`dispatch_attention` (via ``repro.models.layers.attention``) with
``impl = plan.attn_impl`` and the dispatcher decides, per call site, whether
the fused Pallas kernel or the XLA twins run. Rules:

- ``impl="xla"``    — always the pure-XLA twins: ``attention_direct`` for
  short KV, ``attention_blockwise`` otherwise (KV padded to the block
  boundary when the length doesn't divide, so long unaligned contexts never
  fall back to the quadratic path).
- ``impl="pallas"`` — the fused flash kernel whenever the mask parameters are
  static; traced masks (gemma2 local/global alternation scans the window as
  layer metadata) fall back to XLA since Pallas masks are compile-time.
- ``impl="auto"``   — Pallas iff running on a TPU backend with static mask
  parameters and a lane-friendly head_dim; XLA otherwise. Off-TPU the Pallas
  interpreter validates correctness but is orders of magnitude slower, so
  auto never selects it — tests and benchmarks opt in with ``impl="pallas"``.

Layouts: model code uses (B, S, H, hd); the kernel uses head-major
(B, H, S, hd). The dispatcher owns the transposes.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.models import layers as _layers
from .flash_attention import _pad_seq, flash_attention, resolve_interpret

IMPLS = ("auto", "xla", "pallas")


def _is_static(x) -> bool:
    return isinstance(x, (int, np.integer))


def select_impl(impl: str, *, head_dim: int, window, q_offset) -> str:
    """Resolve "auto"/"pallas"/"xla" to the implementation that will run."""
    if impl not in IMPLS:
        raise ValueError(f"attn_impl must be one of {IMPLS}, got {impl!r}")
    if impl == "xla":
        return "xla"
    static = _is_static(window) and _is_static(q_offset)
    if impl == "pallas":
        return "pallas" if static else "xla"
    if (static and jax.default_backend() == "tpu"
            and head_dim % 8 == 0 and head_dim <= 256):
        return "pallas"
    return "xla"


def dispatch_attention(q, k, v, *, impl: str = "auto", causal: bool = True,
                       window=0, softcap: float = 0.0, q_offset=0,
                       block_size: int = 1024,
                       scale: Optional[float] = None,
                       block_q: int = 128, block_k: int = 128,
                       interpret: Optional[bool] = None):
    """q: (B, S, Hq, hd), k/v: (B, T, Hkv, hd) -> (B, S, Hq, hd)."""
    choice = select_impl(impl, head_dim=q.shape[-1], window=window,
                         q_offset=q_offset)
    if choice == "pallas":
        out = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=int(window),
            softcap=softcap, scale=scale, q_offset=int(q_offset),
            block_q=block_q, block_k=block_k,
            interpret=resolve_interpret(interpret))
        return out.transpose(0, 2, 1, 3)

    t = k.shape[1]
    if t <= 2 * block_size:
        return _layers.attention_direct(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale)
    if t % block_size:
        # pad KV to the block boundary and mask the tail — never drop to the
        # O(S·T) direct path just because the context length is unaligned
        t_pad = -(-t // block_size) * block_size
        return _layers.attention_blockwise(
            q, _pad_seq(k, 1, t_pad), _pad_seq(v, 1, t_pad), causal=causal,
            window=window, softcap=softcap, q_offset=q_offset,
            block_size=block_size, scale=scale, kv_len=t)
    return _layers.attention_blockwise(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, block_size=block_size, scale=scale)
