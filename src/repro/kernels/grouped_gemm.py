"""Per-expert batched GEMM — Pallas TPU kernel (survey §4.1.5, MegaBlocks-style).

MoE expert compute is `(E, C, d) × (E, d, f) -> (E, C, f)`: one GEMM per expert
over its capacity buffer. On GPU MegaBlocks lowers this to block-sparse GEMM
over ragged groups; the TPU adaptation (DESIGN.md §2) keeps the fixed-capacity
layout (which the GShard dispatch already produces) and tiles each expert's
GEMM on the MXU:

- grid = (E, C/block_c, f/block_f, d/block_d) with the contraction dim minor,
  accumulating into a VMEM scratch tile across d-steps;
- block shapes 128-aligned; weights stream through VMEM one (block_d, block_f)
  tile at a time so arbitrarily large experts never exceed the VMEM budget.

An optional ``group_sizes`` argument masks padding rows (tokens beyond an
expert's actual load), saving the dominant fraction of FLOPs when experts are
imbalanced — the dropless-MoE motivation, adapted to fixed capacity.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_dsteps: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)       # (bc, bd)
    w = w_ref[0].astype(jnp.float32)       # (bd, bf)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(di == n_dsteps - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_gemm(
    x: jax.Array,                 # (E, C, d)
    w: jax.Array,                 # (E, d, f)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 256,
    interpret: bool = True,
) -> jax.Array:
    e, c, d = x.shape
    f = w.shape[-1]
    assert w.shape == (e, d, f), (x.shape, w.shape)

    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)

    def pad_to(a, dim, blk):
        rem = (-a.shape[dim]) % blk
        if rem == 0:
            return a
        pads = [(0, 0)] * a.ndim
        pads[dim] = (0, rem)
        return jnp.pad(a, pads)

    xp = pad_to(pad_to(x, 1, block_c), 2, block_d)
    wp = pad_to(pad_to(w, 1, block_d), 2, block_f)
    cp, dp, fp = xp.shape[1], xp.shape[2], wp.shape[2]
    grid = (e, cp // block_c, fp // block_f, dp // block_d)

    out = pl.pallas_call(
        functools.partial(_kernel, n_dsteps=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:, :c, :f]
