"""Per-expert batched GEMM — differentiable Pallas TPU kernel (survey §4.1.5,
MegaBlocks-style).

MoE expert compute is `(E, C, d) × (E, d, f) -> (E, C, f)`: one GEMM per expert
over its capacity buffer. On GPU MegaBlocks lowers this to block-sparse GEMM
over ragged groups; the TPU adaptation (DESIGN.md §2) keeps the fixed-capacity
layout (which the GShard dispatch already produces) and tiles each expert's
GEMM on the MXU:

- grid = (E, C/block_c, f/block_f, d/block_d) with the contraction dim minor,
  accumulating into a VMEM scratch tile across d-steps;
- block shapes 128-aligned; weights stream through VMEM one (block_d, block_f)
  tile at a time so arbitrarily large experts never exceed the VMEM budget.

``group_sizes`` (an ``(E,)`` int32 array) marks how many leading rows of each
expert's capacity buffer hold real tokens. Row tiles whose start index is past
the expert's load are skipped entirely (``pl.when`` on the whole tile) and the
straddling tile is masked at the output write — the dropless-MoE FLOP saving,
adapted to fixed capacity. ``group_sizes=None`` keeps every row.

Backward (the FlashAttention-2 analogue for GEMMs): ``jax.custom_vjp`` runs two
more grouped GEMMs through the same tiled kernel —

- ``dx = dy · wᵀ``   row-masked by ``group_sizes`` (padding rows get zero grad);
- ``dw = xᵀ · dy``   with ``group_sizes`` masking the *contraction* dim instead
  (padding rows must not contribute to weight gradients), via the kernel's
  ``mask="contract"`` mode that zeroes weight-tile rows past the group size and
  skips fully-padded contraction tiles.

``interpret=None`` auto-detects the backend like flash_attention: compiled on
TPU, interpreter everywhere else.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_attention import resolve_interpret

MASK_MODES = ("rows", "contract")


def _kernel(gs_ref, x_ref, w_ref, o_ref, acc_ref, *, n_dsteps: int,
            block_r: int, block_k: int, mask: str):
    ri = pl.program_id(1)
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gs = gs_ref[0]
    # whole-tile skip: row tiles past the expert's load ("rows") or contraction
    # tiles made of padding rows ("contract") contribute nothing
    relevant = (ri * block_r < gs) if mask == "rows" else (di * block_k < gs)

    @pl.when(relevant)
    def _compute():
        x = x_ref[0].astype(jnp.float32)       # (br, bk)
        w = w_ref[0].astype(jnp.float32)       # (bk, bf)
        if mask == "contract":
            # zero the padding rows of the weight tile (global contraction
            # index >= group size); zeroing either operand's slice suffices
            kidx = di * block_k + jax.lax.broadcasted_iota(
                jnp.int32, w.shape, 0)
            w = jnp.where(kidx < gs, w, 0.0)
        acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(di == n_dsteps - 1)
    def _finish():
        acc = acc_ref[...]
        if mask == "rows":
            ridx = ri * block_r + jax.lax.broadcasted_iota(
                jnp.int32, acc.shape, 0)
            acc = jnp.where(ridx < gs, acc, 0.0)
        o_ref[0] = acc.astype(o_ref.dtype)


def _grouped_gemm(x, w, gs, *, mask: str, block_r: int, block_co: int,
                  block_k: int, interpret: bool):
    """(E, R, K) × (E, K, F) -> (E, R, F), masked by per-expert ``gs``."""
    assert mask in MASK_MODES, mask
    e, r, k = x.shape
    f = w.shape[-1]
    assert w.shape == (e, k, f), (x.shape, w.shape)

    block_r = min(block_r, r)
    block_co = min(block_co, f)
    block_k = min(block_k, k)

    def pad_to(a, dim, blk):
        rem = (-a.shape[dim]) % blk
        if rem == 0:
            return a
        pads = [(0, 0)] * a.ndim
        pads[dim] = (0, rem)
        return jnp.pad(a, pads)

    xp = pad_to(pad_to(x, 1, block_r), 2, block_k)
    wp = pad_to(pad_to(w, 1, block_k), 2, block_co)
    rp, kp, fp = xp.shape[1], xp.shape[2], wp.shape[2]
    grid = (e, rp // block_r, fp // block_co, kp // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, n_dsteps=grid[3], block_r=block_r,
                          block_k=block_k, mask=mask),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ei, ri, fi, di: (ei,)),
            pl.BlockSpec((1, block_r, block_k),
                         lambda ei, ri, fi, di: (ei, ri, di)),
            pl.BlockSpec((1, block_k, block_co),
                         lambda ei, ri, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_r, block_co),
                               lambda ei, ri, fi, di: (ei, ri, fi)),
        out_shape=jax.ShapeDtypeStruct((e, rp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_r, block_co), jnp.float32)],
        interpret=interpret,
    )(gs, xp, wp)
    return out[:, :r, :f]


# ---------------------------------------------------------------------------
# custom_vjp plumbing


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _gemm(x, w, gs, block_c, block_f, block_d, interpret):
    return _grouped_gemm(x, w, gs, mask="rows", block_r=block_c,
                         block_co=block_f, block_k=block_d,
                         interpret=interpret)


def _gemm_fwd(x, w, gs, block_c, block_f, block_d, interpret):
    out = _gemm(x, w, gs, block_c, block_f, block_d, interpret)
    # named for selective remat (models.families.REMAT_SAVE_NAMES)
    out = checkpoint_name(out, "expert_gemm_out")
    return out, (x, w, gs)


def _gemm_bwd(block_c, block_f, block_d, interpret, res, g):
    x, w, gs = res
    # dx = dy · wᵀ — row-masked: padding rows never reached the output, so
    # their cotangent is zero (also skips their tiles entirely)
    dx = _grouped_gemm(g, w.transpose(0, 2, 1), gs, mask="rows",
                       block_r=block_c, block_co=block_d, block_k=block_f,
                       interpret=interpret)
    # dw = xᵀ · dy — contraction-masked: only real rows contribute to the
    # weight gradient
    dw = _grouped_gemm(x.transpose(0, 2, 1), g, gs, mask="contract",
                       block_r=block_d, block_co=block_f, block_k=block_c,
                       interpret=interpret)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


_gemm.defvjp(_gemm_fwd, _gemm_bwd)


def expert_gemm(
    x: jax.Array,                 # (E, C, d)
    w: jax.Array,                 # (E, d, f)
    group_sizes: Optional[jax.Array] = None,   # (E,) int32 real rows per expert
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 256,
    interpret: Optional[bool] = None,   # None -> compiled on TPU, interpreted elsewhere
) -> jax.Array:
    """Fused differentiable per-expert GEMM; see module docstring."""
    e, c, _ = x.shape
    if group_sizes is None:
        gs = jnp.full((e,), c, jnp.int32)
    else:
        gs = jax.lax.stop_gradient(group_sizes).astype(jnp.int32)
    return _gemm(x, w, gs, int(block_c), int(block_f), int(block_d),
                 resolve_interpret(interpret))
