"""Pallas TPU kernels for the compute hot spots the survey optimizes:

- flash_attention (survey §5.1.1) — online-softmax tiled attention, now fully
  differentiable: the forward emits per-row logsumexp and ``jax.custom_vjp``
  ties it to FlashAttention-2-style dq / dkv recompute kernels, so the train
  step backprops through the fused kernel without materializing O(S·T) scores.
- grouped_gemm / expert_gemm (survey §4.1.5) — MoE per-expert GEMM
  (forward-only; porting onto the custom-VJP pattern is a ROADMAP open item)
- ssd_chunk_scan (Mamba2 SSD) — fused chunked state-space scan (§Perf pair B;
  forward-only, same open item)

Dispatch (``dispatch.py``): model layers call attention through
``dispatch_attention`` with ``impl = ParallelPlan.attn_impl``:

- ``"xla"``    — the pure-jnp twins in models/layers.py (direct for short KV,
  blockwise with boundary padding otherwise); kept as the gradient oracle.
- ``"pallas"`` — the fused kernel (interpret mode off-TPU); falls back to XLA
  when mask params are traced (gemma2 local/global alternation).
- ``"auto"``   — pallas only on TPU backends with static masks and
  lane-friendly head_dim; XLA everywhere else.

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
tests sweep shapes/dtypes/grads and assert allclose in interpret mode.
"""

from .dispatch import dispatch_attention, select_impl
from .ops import expert_gemm, flash_attention, ssd_chunk_scan
from . import ref

__all__ = ["dispatch_attention", "expert_gemm", "flash_attention",
           "select_impl", "ssd_chunk_scan", "ref"]
