"""Pallas TPU kernels for the compute hot spots the survey optimizes:

- flash_attention (survey §5.1.1) — online-softmax tiled attention
- grouped_gemm / expert_gemm (survey §4.1.5) — MoE per-expert GEMM
- ssd_chunk_scan (Mamba2 SSD) — fused chunked state-space scan (§Perf pair B)

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
tests sweep shapes/dtypes and assert allclose in interpret mode.
"""

from .ops import expert_gemm, flash_attention, ssd_chunk_scan
from . import ref

__all__ = ["expert_gemm", "flash_attention", "ssd_chunk_scan", "ref"]
