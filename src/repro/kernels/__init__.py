"""Pallas TPU kernels for the compute hot spots the survey optimizes — all
three now fully differentiable and plan-selectable:

- flash_attention (survey §5.1.1) — online-softmax tiled attention; the
  forward emits per-row logsumexp and ``jax.custom_vjp`` ties it to
  FlashAttention-2-style dq / dkv recompute kernels.
- grouped_gemm / expert_gemm (survey §4.1.5) — MoE per-expert GEMM with
  ``group_sizes`` padding-row masking (tile skip for imbalanced experts); the
  backward runs two more grouped GEMMs (dx = dy·wᵀ, dw = xᵀ·dy) through the
  same tiled kernel.
- ssd_chunk_scan (Mamba2 SSD, §Perf pair B) — fused chunked state-space scan;
  the forward saves only per-chunk entering states and a reversed-grid
  backward kernel recomputes the decay/score tiles in VMEM, so the
  (b, c, h, q, q) decay tensor never hits HBM in either pass.

Dispatch (``dispatch.py``): model layers reach each kernel through its per-op
dispatcher with the matching :class:`~repro.core.config.ParallelPlan` knob —
``dispatch_attention``/``attn_impl``, ``dispatch_expert_gemm``/
``moe_gemm_impl``, ``dispatch_ssd_scan``/``ssm_impl``. Shared rules:

- ``"xla"``    — the pure-jnp twins (models/layers.py attention,
  masked einsum, models/ssm.py ssd_scan); kept as the gradient oracles.
- ``"pallas"`` — the fused kernel (interpret mode off-TPU); falls back to XLA
  only when hard preconditions fail (traced mask params, SSD initial state).
- ``"auto"``   — pallas only on TPU backends; XLA everywhere else.

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
tests sweep shapes/dtypes/grads and assert allclose in interpret mode.
"""

from .dispatch import (
    dispatch_attention,
    dispatch_expert_gemm,
    dispatch_ssd_scan,
    select_gemm_impl,
    select_impl,
    select_ssd_impl,
)
from .ops import expert_gemm, flash_attention, ssd_chunk_scan
from . import ref

__all__ = ["dispatch_attention", "dispatch_expert_gemm", "dispatch_ssd_scan",
           "expert_gemm", "flash_attention", "select_gemm_impl",
           "select_impl", "select_ssd_impl", "ssd_chunk_scan", "ref"]
