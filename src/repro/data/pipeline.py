"""Deterministic synthetic data pipeline.

Real LLM pretraining reads packed token shards from a parallel filesystem
(survey §3.3.2); in this container there is no corpus, so the pipeline
synthesizes a *deterministic* token stream — batch contents are a pure function
of (arch, step), which gives reproducible loss curves, honest multi-epoch
behaviour for the fault-tolerance recovery tests (replay from checkpoint
produces bit-identical batches), and zero I/O bottlenecks.

The generator is intentionally structured (a noisy order-2 Markov chain over a
small state space embedded in the full vocab) so models actually *learn* — loss
decreases — which the example drivers and anomaly-detection tests rely on.
"""

from __future__ import annotations

import concurrent.futures
from typing import Dict, Optional

import numpy as np

from repro.core.config import Family, InputShape, ModelConfig


class SyntheticDataset:
    def __init__(self, cfg: ModelConfig, shape: InputShape, seed: int = 0,
                 n_states: int = 64):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.n_states = min(n_states, cfg.vocab)
        # fixed random transition structure (the "language")
        r = np.random.default_rng(seed + 1)
        self.table = r.integers(0, self.n_states,
                                size=(self.n_states, self.n_states))
        # flat view for single-gather transition lookup in _tokens
        self._flat_table = np.ascontiguousarray(self.table).reshape(-1)

    def _tokens(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        """Markov token stream; bit-identical to :meth:`_tokens_loop`.

        All randomness is drawn up front: PCG64 fills a C-order array with
        the same doubles as the equivalent sequence of per-row calls, so
        hoisting ``rng.random((seq - 1, batch))`` out of the recurrence
        preserves every batch ever generated. The order-2 recurrence itself
        is inherently sequential over t, but the remaining per-t work is a
        single flat gather + masked copy."""
        out = rng.integers(0, self.n_states, size=(batch, seq + 1))
        if seq >= 2:
            # overwrite with markov structure 90% of the time
            masks = rng.random((seq - 1, batch)) < 0.9
            flat, n = self._flat_table, self.n_states
            for t in range(2, seq + 1):
                nxt = flat[out[:, t - 1] * n + out[:, t - 2]]
                np.copyto(out[:, t], nxt, where=masks[t - 2])
        return out.astype(np.int32)

    def _tokens_loop(self, rng: np.random.Generator, batch: int,
                     seq: int) -> np.ndarray:
        """Reference implementation (the original per-step RNG loop); kept
        for the bit-identity regression test against :meth:`_tokens`."""
        out = rng.integers(0, self.n_states, size=(batch, seq + 1))
        for t in range(2, seq + 1):
            nxt = self.table[out[:, t - 1], out[:, t - 2]]
            mask = rng.random(batch) < 0.9
            out[:, t] = np.where(mask, nxt, out[:, t])
        return out.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a global step — tokens, labels + family-specific frontends."""
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng((self.seed, step))
        toks = self._tokens(rng, shape.global_batch, shape.seq_len)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == Family.AUDIO:
            batch["frames"] = rng.standard_normal(
                (shape.global_batch, cfg.enc_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == Family.VLM and cfg.vision_tokens:
            n = cfg.vision_tokens
            batch["vision_embeds"] = rng.standard_normal(
                (shape.global_batch, n, cfg.d_model)).astype(np.float32)
            pos = np.stack([rng.choice(shape.seq_len, size=n, replace=False)
                            for _ in range(shape.global_batch)])
            batch["vision_pos"] = np.sort(pos, axis=-1).astype(np.int32)
        return batch


class Prefetcher:
    """One-batch-ahead prefetch on a background thread.

    Batch synthesis is pure host work (``batch = f(arch, step)``), so it can
    overlap the device step: after serving step ``s`` the next batch is
    already cooking for ``s + 1``. Random access stays correct — a request
    for a step with no matching prefetch in flight is synthesized
    synchronously (rollback replays jump backwards; determinism is the
    dataset's, the prefetcher only changes *when* work happens, never what).

    Use as a drop-in ``get_batch``::

        with Prefetcher(ds) as pf:
            run_with_recovery(..., get_batch=pf.batch, ...)
    """

    def __init__(self, dataset, lookahead: int = 1):
        self.dataset = dataset
        self.lookahead = max(0, int(lookahead))
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="data-prefetch")
        self._pending: Dict[int, concurrent.futures.Future] = {}

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        fut = self._pending.pop(step, None)
        out = fut.result() if fut is not None else self.dataset.batch(step)
        for s in range(step + 1, step + 1 + self.lookahead):
            if s not in self._pending:
                self._pending[s] = self._pool.submit(self.dataset.batch, s)
        return out

    def close(self) -> None:
        for fut in self._pending.values():
            fut.cancel()
        self._pending.clear()
        self._pool.shutdown(wait=False)

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
