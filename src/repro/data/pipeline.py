"""Deterministic synthetic data pipeline.

Real LLM pretraining reads packed token shards from a parallel filesystem
(survey §3.3.2); in this container there is no corpus, so the pipeline
synthesizes a *deterministic* token stream — batch contents are a pure function
of (arch, step), which gives reproducible loss curves, honest multi-epoch
behaviour for the fault-tolerance recovery tests (replay from checkpoint
produces bit-identical batches), and zero I/O bottlenecks.

The generator is intentionally structured (a noisy order-2 Markov chain over a
small state space embedded in the full vocab) so models actually *learn* — loss
decreases — which the example drivers and anomaly-detection tests rely on.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.config import Family, InputShape, ModelConfig


class SyntheticDataset:
    def __init__(self, cfg: ModelConfig, shape: InputShape, seed: int = 0,
                 n_states: int = 64):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.n_states = min(n_states, cfg.vocab)
        # fixed random transition structure (the "language")
        r = np.random.default_rng(seed + 1)
        self.table = r.integers(0, self.n_states,
                                size=(self.n_states, self.n_states))

    def _tokens(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = rng.integers(0, self.n_states, size=(batch, seq + 1))
        # overwrite with markov structure 90% of the time
        for t in range(2, seq + 1):
            nxt = self.table[out[:, t - 1], out[:, t - 2]]
            mask = rng.random(batch) < 0.9
            out[:, t] = np.where(mask, nxt, out[:, t])
        return out.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a global step — tokens, labels + family-specific frontends."""
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng((self.seed, step))
        toks = self._tokens(rng, shape.global_batch, shape.seq_len)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == Family.AUDIO:
            batch["frames"] = rng.standard_normal(
                (shape.global_batch, cfg.enc_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == Family.VLM and cfg.vision_tokens:
            n = cfg.vision_tokens
            batch["vision_embeds"] = rng.standard_normal(
                (shape.global_batch, n, cfg.d_model)).astype(np.float32)
            pos = np.stack([rng.choice(shape.seq_len, size=n, replace=False)
                            for _ in range(shape.global_batch)])
            batch["vision_pos"] = np.sort(pos, axis=-1).astype(np.int32)
        return batch
