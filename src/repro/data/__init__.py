from .pipeline import Prefetcher, SyntheticDataset

__all__ = ["Prefetcher", "SyntheticDataset"]
