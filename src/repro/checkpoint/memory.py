"""Hot in-memory checkpoint tier with peer redundancy (survey §8.3.1,
Gemini / CheckFreq style).

The disk tier (:mod:`repro.checkpoint.store`) makes checkpoints *durable*;
this module makes the common-case restore *fast*. A
:class:`MemoryCheckpointTier` keeps a host-RAM ring of the last ``keep``
snapshots — same shard/manifest/digest schema as the disk tier (reusing its
``_flatten_with_names`` / ``_leaf_shards`` / ``_checksum`` / ``_crc32``
machinery), so a memory-tier entry is byte-equivalent to what the disk
persist would have written — and the recovery driver
(:func:`repro.ft.recovery.run_with_recovery`) restores **memory-tier first**,
falling back to the integrity-verified disk walk only when the hot tier
cannot serve (no entry, layout mismatch after a remesh, or shards lost
beyond repair).

Peer redundancy (the Gemini trick): RAM checkpoints die with their host, so
a bare in-memory ring protects against software faults (NaN rollback, SDC
rollback) but not machine loss. Each snapshot's shards are therefore
assigned a *home* group ``g`` (round-robin over ``groups`` logical
host-groups) and every group's shard buffers are additionally mirrored onto
its ring neighbor ``(g+1) % groups``. Losing one whole group
(:meth:`lose_group`, the simulated host failure) still leaves every shard
available — primaries on the survivors plus the lost group's bytes on its
neighbor's mirror — so :meth:`restore` rebuilds the full tree from RAM
without touching disk. Mirror-served shards are always digest-verified
(sha256-prefix + CRC32 + dtype/shape) before use; primary-served shards
skip re-verification by default — they were digested at save time and RAM
is assumed fault-free between save and restore, which is what makes the hot
path ~an order of magnitude faster than the verified disk walk.

On a real multi-host fleet the mirror exchange is a ring ``ppermute`` of
shard buffers across host groups (each host sends its shard bytes one hop
around the data-parallel ring while receiving its neighbor's); in this
single-process reproduction the rotation happens host-side with owned numpy
copies, which preserves the redundancy *semantics* — the mirror is a
physically distinct buffer that survives ``lose_group`` — while staying
runnable on one host.

Tiered restore order (driver's view):

1. memory tier, primary shards (fast path, no re-verify);
2. memory tier, peer rebuild (neighbor mirrors, digest-verified);
3. disk walk newest-first, skipping corrupt checkpoints (verified), via
   :meth:`CheckpointManager.restore` / ``restore_resharded`` for remesh.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from .store import (CorruptCheckpointError, _checksum, _crc32,
                    _flatten_with_names, _leaf_shards, _plan_meta,
                    layout_diffs)


class MemoryCheckpointTier:
    """Host-RAM ring of the last ``keep`` snapshots with ring-neighbor
    shard mirroring.

    ``groups`` is the number of logical host-groups in the redundancy ring
    (on a fleet: one per host; here: a partition of the shard set). With
    ``peer_redundancy=False`` the mirror copies are skipped — half the RAM,
    no tolerance to :meth:`lose_group`.
    """

    def __init__(self, keep: int = 2, peer_redundancy: bool = True,
                 groups: int = 2, flight=None):
        self.keep = max(1, int(keep))
        self.peer_redundancy = bool(peer_redundancy)
        self.groups = max(1, int(groups))
        self.flight = flight
        self._ring: deque = deque(maxlen=self.keep)
        self.snapshot_seconds = 0.0   # last save() wall time
        self.restore_seconds = 0.0    # last restore() wall time
        self.last_rebuild = 0         # shards served from mirrors last restore

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, plan=None, mesh=None) -> None:
        """Snapshot ``tree`` into the RAM ring (blocking host copy).

        Builds the same manifest the disk tier would (per-shard key, global
        index slices, sha256-prefix, CRC32, dtype/shape) plus a ``home``
        group per shard, then rotates each group's buffers onto its ring
        neighbor's mirror. The ring's ``maxlen`` evicts the oldest entry.
        """
        t0 = time.time()
        named = _flatten_with_names(tree)
        primary: Dict[int, Dict[str, np.ndarray]] = \
            {g: {} for g in range(self.groups)}
        shard_meta: List[List[Dict[str, Any]]] = []
        counter = 0
        for i, (_, x) in enumerate(named):
            shards = _leaf_shards(x, copy=True)
            metas = []
            for j, (idx, a) in enumerate(shards):
                key = f"a{i}" if len(shards) == 1 else f"a{i}_s{j}"
                home = counter % self.groups
                counter += 1
                primary[home][key] = a
                metas.append({"key": key, "index": idx,
                              "checksum": _checksum(a), "crc32": _crc32(a),
                              "dtype": str(a.dtype),
                              "shape": [int(d) for d in a.shape],
                              "home": home})
            shard_meta.append(metas)
        manifest = {
            "step": int(step),
            "names": [n for n, _ in named],
            "shapes": [[int(d) for d in np.shape(x)] for _, x in named],
            "dtypes": [m[0]["dtype"] for m in shard_meta],
            "shards": shard_meta,
            "plan": _plan_meta(plan),
            "mesh_axes": dict(mesh.shape) if mesh is not None else None,
            "time": time.time(),
        }
        mirror: Dict[int, Dict[str, np.ndarray]] = \
            {g: {} for g in range(self.groups)}
        if self.peer_redundancy and self.groups > 1:
            # ring rotation: group g's bytes also live on (g+1) % groups —
            # host-side stand-in for the fleet's ring ppermute of shard
            # buffers (owned copies, so they survive lose_group(g))
            for g in range(self.groups):
                dst = (g + 1) % self.groups
                for key, a in primary[g].items():
                    mirror[dst][key] = np.array(a, copy=True)
        self._ring.append({"manifest": manifest, "primary": primary,
                           "mirror": mirror})
        self.snapshot_seconds = time.time() - t0
        if self.flight is not None:
            self.flight.record("ckpt.persist", step, tier="memory",
                               seconds=self.snapshot_seconds,
                               groups=self.groups,
                               mirrored=self.peer_redundancy)

    # -- introspection ------------------------------------------------------

    def steps(self, newest_first: bool = False) -> List[int]:
        out = sorted(e["manifest"]["step"] for e in self._ring)
        return out[::-1] if newest_first else out

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def clear(self) -> None:
        """Drop every entry — required after a remesh (recorded layouts no
        longer match) and on preemption exit (RAM dies with the process)."""
        self._ring.clear()

    def _entry(self, step: Optional[int]) -> Dict[str, Any]:
        if not self._ring:
            raise CorruptCheckpointError("memory tier is empty")
        if step is None:
            return self._ring[-1]
        for e in self._ring:
            if e["manifest"]["step"] == step:
                return e
        raise CorruptCheckpointError(f"step {step} not in memory tier "
                                     f"(have {self.steps()})")

    # -- fault simulation ---------------------------------------------------

    def lose_group(self, g: int) -> int:
        """Simulate losing host-group ``g``: drop its primary shards *and*
        the mirror bytes it was holding for its neighbor, across every ring
        entry. Returns the number of shard buffers destroyed."""
        lost = 0
        for e in self._ring:
            lost += len(e["primary"].get(g, {}))
            lost += len(e["mirror"].get(g, {}))
            e["primary"][g] = {}
            e["mirror"][g] = {}
        if self.flight is not None:
            self.flight.record("mem.lost_group",
                               self.latest_step() or -1,
                               group=int(g), shards_lost=lost)
        return lost

    # -- restore ------------------------------------------------------------

    def _fetch(self, e: Dict[str, Any], m: Dict[str, Any],
               verify: bool) -> np.ndarray:
        """One shard's bytes: primary first, neighbor mirror on miss.

        Mirror hits are always digest-verified — rebuilt bytes crossed a
        (simulated) network hop and a host loss, so they must prove
        themselves; primary hits trust the save-time digests unless
        ``verify`` asks otherwise.
        """
        home = m.get("home", 0)
        a = e["primary"].get(home, {}).get(m["key"])
        from_mirror = False
        if a is None:
            a = e["mirror"].get((home + 1) % self.groups, {}).get(m["key"])
            from_mirror = True
            if a is None:
                raise CorruptCheckpointError(
                    f"shard {m['key']} lost from memory tier (home group "
                    f"{home} and its mirror both gone)")
        if verify or from_mirror:
            if _checksum(a) != m["checksum"] or _crc32(a) != m["crc32"]:
                raise CorruptCheckpointError(
                    f"memory-tier digest mismatch for shard {m['key']}")
            if str(a.dtype) != m["dtype"] or list(a.shape) != m["shape"]:
                raise CorruptCheckpointError(
                    f"memory-tier dtype/shape mismatch for shard {m['key']}")
        if from_mirror:
            self.last_rebuild += 1
        return a

    def restore(self, tree_like: Any, step: Optional[int] = None,
                plan=None, mesh=None, verify: bool = False
                ) -> Tuple[int, Any]:
        """Restore into the structure of ``tree_like``; returns (step, tree).

        Raises :class:`CorruptCheckpointError` when the tier cannot serve
        (empty, step missing, shards lost beyond the mirror) and
        ``ValueError`` on a layout mismatch (e.g. after a remesh) — the
        recovery driver catches both and falls to the disk walk.
        ``self.last_rebuild`` reports how many shards came from peer
        mirrors (0 ⇒ pure fast path).
        """
        t0 = time.time()
        self.last_rebuild = 0
        e = self._entry(step)
        man = e["manifest"]
        diffs = layout_diffs(man, plan, mesh)
        if diffs:
            raise ValueError(
                f"memory-tier layout mismatch (recorded != requested): "
                f"{diffs} — remesh restores go through the disk tier")
        named = _flatten_with_names(tree_like)
        assert [n for n, _ in named] == man["names"], \
            "memory checkpoint tree structure mismatch"
        leaves = []
        for metas, shape, dt, (_, l) in zip(
                man["shards"], man["shapes"], man["dtypes"], named):
            if len(metas) == 1:
                full = self._fetch(e, metas[0], verify)
            else:
                full = np.zeros(shape, dtype=np.dtype(dt))
                for m in metas:
                    sl = tuple(slice(a, b) for a, b in m["index"])
                    full[sl] = self._fetch(e, m, verify)
            arr = jax.numpy.asarray(full, dtype=getattr(l, "dtype", None)
                                    or full.dtype)
            if isinstance(l, jax.Array) and getattr(l, "committed", False):
                arr = jax.device_put(arr, l.sharding)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(tree_like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        self.restore_seconds = time.time() - t0
        if self.flight is not None:
            self.flight.record("mem.restore", man["step"],
                               rebuilt_shards=self.last_rebuild,
                               seconds=self.restore_seconds)
        return man["step"], tree
