"""Checkpointing (survey §8.3) — shard-aware save, async snapshots, and
elastic cross-mesh restore.

Persistent checkpoints follow the snapshot/persist split of §8.3.1:

- ``snapshot``: device -> host copy (the only phase that can stall training);
- ``persist``: host -> disk write, runs on a background thread
  (snapshot-stall checkpointing à la Check-N-Run/MegaScale).

With ``async_snapshot=True`` the snapshot itself is double-buffered
(§8.3.1 snapshot-stall elimination): ``save`` only *dispatches* a device-side
clone of the state (one jitted copy per tree layout, asynchronously executed,
sharding-preserving) and returns; the device->host copy and the disk write
both run on the background thread against the clone. The clone is what makes
this safe — the training loop is free to donate the live state's buffers into
the next step while the copy drains (``np.asarray`` of a CPU shard is a
zero-copy *view* of the device buffer, so snapshotting the live state without
a clone would race donation). Cost: transiently one extra copy of the state
in device memory (the classic double buffer). ``wait()`` is the completion
fence — ``save`` calls it first, so at most one snapshot+persist is in
flight — and any failure on the background thread (full disk, revoked
directory) is re-raised at the next ``save()``/``wait()`` instead of dying
silently with the thread.

Layout: one ``.npz`` per checkpoint plus a JSON manifest carrying the step,
the flattened tree structure and integrity checksums.

Shard-aware (survey §3.3.1: a designated worker per group writes its shard):
the snapshot phase walks ``jax.Array.addressable_shards`` and copies each
*unique* device shard to host instead of gathering the full array — under
cp/tp/ZeRO meshes the device→host copy moves 1/shards of the bytes and the
replicated copy never materializes. The manifest records each shard's
global-index slices plus the :class:`repro.core.config.ParallelPlan` axes
(``tp``/``cp``/``pp``/``dp_shard``/``zero_stage``/impl knobs) and mesh axis
sizes.

Restore is **elastic** (survey §8.3.2, the cloud-native resumable-on-a-
different-cluster gap): because the manifest records every shard's global
index slices, a checkpoint written on one mesh can be reassembled into full
arrays and *re-sliced* onto any other layout — fewer hosts after a failure,
more after repair. :meth:`CheckpointManager.check_plan` is the router:
``"replay"`` when the requested ParallelPlan layout axes and mesh axis sizes
match the recorded ones (fast shard-to-shard :meth:`restore`), ``"reshard"``
when they differ and ``elastic=True`` (take
:meth:`restore_resharded`, which re-places every leaf — params *and* the
ZeRO-1 optimizer moment shards, which land re-scattered over the new data
axis — with explicitly computed target shardings). A mismatch without
``elastic`` still refuses, because silently replaying a shard-written
checkpoint onto a different layout is the §8 failure mode this module
exists to prevent.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
import zipfile
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CorruptCheckpointError(IOError):
    """A checkpoint on disk failed integrity verification (checksum/CRC32
    mismatch, unreadable manifest, missing or truncated shard file).

    Subclasses ``IOError`` so callers matching the historical checksum
    failure keep working; ``ft/recovery`` catches it specifically to fall
    back to the newest *intact* checkpoint instead of crashing.
    """


def _inject():
    """The fault-injection module, or None before repro.ft is importable.

    Lazy by necessity: ``repro.ft.__init__`` imports ``ft.recovery`` which
    imports this module — a top-level import here would cycle.
    """
    try:
        from repro.ft import inject  # noqa: PLC0415
        return inject
    except ImportError:              # pragma: no cover - partial installs
        return None

# the ParallelPlan fields recorded in the manifest (impl/schedule knobs ride
# along for forensics) ...
PLAN_AXES = ("tp", "tp_impl", "cp", "cp_impl", "dp_shard", "zero_stage",
             "ep", "ep_impl", "pp", "pp_schedule", "pp_layout")
# ... and the subset check_plan actually compares: only the axes that change
# how saved state maps onto devices. A pure schedule/impl change
# (gpipe→1f1b, gather→ring) is replay-safe — restore reassembles full
# arrays and re-places them — so it must not be refused. pp_layout IS
# compared: a Malleus rebalance changes which layers live on which stage,
# so under elastic restore it routes "reshard", never a refusal.
PLAN_LAYOUT_AXES = ("tp", "cp", "dp_shard", "zero_stage", "ep", "pp",
                    "pp_layout")


def _plan_meta(plan) -> Optional[Dict[str, Any]]:
    if plan is None:
        return None
    d = dataclasses.asdict(plan)
    # tuples (pp_layout) JSON-round-trip as lists; normalize at record time
    # so manifest-vs-plan comparisons in layout_diffs stay type-stable
    return {k: list(d[k]) if isinstance(d[k], tuple) else d[k]
            for k in PLAN_AXES if k in d}


def layout_diffs(manifest: Dict[str, Any], plan, mesh=None
                 ) -> Dict[str, Tuple[Any, Any]]:
    """Layout-axis differences between a manifest and a requested plan/mesh.

    Empty dict ⇒ shard-to-shard replay is safe. Shared by
    :meth:`CheckpointManager.check_plan` (disk tier) and
    :class:`repro.checkpoint.memory.MemoryCheckpointTier` (hot tier), so
    both tiers route replay/reshard/refuse with identical rules.
    """
    recorded = manifest.get("plan")
    diffs: Dict[str, Tuple[Any, Any]] = {}
    if recorded is not None and plan is not None:
        want = _plan_meta(plan)
        rec = dict(recorded)
        # manifests written before ep became an integer degree recorded the
        # legacy bool: False means "no EP" (degree 1); True (GSPMD expert
        # sharding) has no degree equivalent and never replays onto the new
        # folded layouts (Python would otherwise equate True == 1)
        if isinstance(rec.get("ep"), bool):
            rec["ep"] = 1 if rec["ep"] is False else "legacy-gspmd-ep"
        diffs = {k: (rec[k], want[k]) for k in PLAN_LAYOUT_AXES
                 if k in rec and k in want and rec[k] != want[k]}
    rec_mesh = manifest.get("mesh_axes")
    if mesh is not None and rec_mesh is not None:
        want_mesh = {k: int(v) for k, v in dict(mesh.shape).items()}
        if {k: int(v) for k, v in rec_mesh.items()} != want_mesh:
            diffs["mesh_axes"] = (rec_mesh, want_mesh)
    return diffs


def _index_json(index: Tuple[slice, ...], shape) -> List[List[int]]:
    """A shard's global-index slices as JSON: [[start, stop], ...]."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def _leaf_shards(x, copy: bool = True) -> List[Tuple[List[List[int]], np.ndarray]]:
    """Unique (index, host copy) pairs for one leaf.

    jax.Arrays snapshot per addressable shard (replicas deduped by index);
    anything else (numpy, python scalars) is a single whole-array shard.
    ``copy=True`` forces an owned host buffer — ``np.asarray`` of a CPU
    shard is a zero-copy view of the device buffer, which a later donation
    of that buffer would invalidate under the persist thread. Snapshots of a
    manager-owned clone pass ``copy=False`` (the clone outlives the persist).
    """
    if isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer):
        if not x.is_fully_addressable:
            # multi-process meshes: this process sees only its own shards;
            # recording a partial shard list and zero-filling the rest at
            # restore would be silent corruption — fail loudly (the
            # multi-host per-writer layout is future work)
            raise ValueError(
                "sharded checkpoint save requires fully-addressable arrays; "
                "multi-process meshes need a per-host writer rank")
        seen: Dict[Tuple, Tuple[List[List[int]], np.ndarray]] = {}
        for sh in x.addressable_shards:
            idx = _index_json(tuple(sh.index), x.shape)
            key = tuple(map(tuple, idx))
            if key not in seen:
                host = np.asarray(sh.data)
                seen[key] = (idx, np.array(host, copy=True) if copy else host)
        return list(seen.values())
    arr = np.asarray(x)
    return [(_index_json(tuple(slice(0, d) for d in arr.shape), arr.shape),
             arr)]


def _flatten_with_names(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "name", getattr(p, "idx", p)))
            for p in path)
        out.append((name, leaf))
    return out


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _clone_shardings(leaves: List[Any]):
    """Per-leaf out_shardings for the snapshot clone.

    Committed arrays keep their own sharding. Uncommitted leaves (scalars on
    the default device) are normalized onto the committed leaves' mesh,
    replicated — a mixed device assignment would be rejected by jit, and a
    mesh-replicated clone persists byte-identically (replicas dedup to one
    full-coverage shard).
    """
    from jax.sharding import NamedSharding, PartitionSpec  # noqa: PLC0415
    meshes = {l.sharding.mesh for l in leaves
              if getattr(l, "committed", False)
              and isinstance(l.sharding, NamedSharding)}
    mesh = meshes.pop() if len(meshes) == 1 else None
    out = []
    for l in leaves:
        if getattr(l, "committed", False) or mesh is None:
            out.append(l.sharding)
        else:
            out.append(NamedSharding(mesh, PartitionSpec()))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_persist: bool = True, async_snapshot: bool = False,
                 io_retries: int = 3, io_backoff: float = 0.05,
                 io_timeout: float = 30.0, flight=None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        # optional repro.ft.flight.FlightRecorder — persist/GC events land in
        # the crash black box (deque appends are thread-safe, so logging from
        # the persist thread is fine)
        self.flight = flight
        self.async_persist = async_persist
        self.async_snapshot = async_snapshot
        # persist-I/O robustness: ``io_retries`` attempts with exponential
        # backoff starting at ``io_backoff`` seconds, abandoned once the
        # cumulative wait would pass ``io_timeout`` (a wedged filesystem must
        # not hold the fence forever). Exhausted retries surface through
        # save()/wait() — ft/recovery records them as a "ckpt_io" anomaly.
        self.io_retries = max(1, int(io_retries))
        self.io_backoff = io_backoff
        self.io_timeout = io_timeout
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._snapshot_ref: Any = None        # device clone kept alive
        self._clone_cache: Dict[Tuple, Callable] = {}
        self.snapshot_seconds = 0.0           # main-thread stall of last save
        self.d2h_seconds = 0.0                # device->host copy (wherever it ran)
        self.persist_seconds = 0.0

    # -- save ---------------------------------------------------------------

    def _cloner(self, leaves: List[Any]) -> Optional[List[Any]]:
        """Device-side clone of the whole tree (the double buffer).

        One jitted sharding-preserving copy per tree layout — a single async
        dispatch, so the main-thread stall is sub-millisecond regardless of
        state size. Returns the cloned leaves, or None when the leaf mix
        can't be cloned on device (e.g. committed arrays pinned to
        incompatible device sets) — the caller falls back to the blocking
        host-copy snapshot.
        """
        jaxish = [isinstance(l, jax.Array) and not isinstance(l, jax.core.Tracer)
                  for l in leaves]
        arrs = [l for l, j in zip(leaves, jaxish) if j]
        if not arrs:
            return None
        key = tuple((a.shape, str(a.dtype), a.sharding) for a in arrs)
        fn = self._clone_cache.get(key)
        if fn is None:
            try:
                jitted = jax.jit(lambda ls: [jnp.copy(l) for l in ls],
                                 out_shardings=_clone_shardings(arrs))
                jax.block_until_ready(jitted(arrs))   # compile + validate now
            except Exception:
                return None
            fn = self._clone_cache[key] = jitted
        cloned_arrs = fn(arrs)
        it = iter(cloned_arrs)
        # non-jax leaves (numpy, python scalars) are tiny: owned-copy inline
        return [next(it) if j else np.array(np.asarray(l), copy=True)
                for l, j in zip(leaves, jaxish)]

    def save(self, step: int, tree: Any, blocking: bool = False,
             plan=None, mesh=None) -> Path:
        """Snapshot then persist; returns the checkpoint path (sans suffix).

        The snapshot copies each leaf's unique *addressable shards* to host
        (no full-array gather). With ``async_snapshot`` the main thread only
        dispatches a device-side clone (double buffer) and the host copy
        overlaps subsequent train steps; otherwise the host copy is the
        stall. ``blocking=True`` forces everything inline. ``plan``/``mesh``
        record the layout axes in the manifest so replay/reshard can route.
        Raises any failure from the *previous* save's background work.
        """
        self.wait()                                      # fence + raise errors
        t0 = time.time()
        named = _flatten_with_names(tree)
        names = [n for n, _ in named]
        cloned = None
        if self.async_snapshot and not blocking:
            cloned = self._cloner([x for _, x in named])
        if cloned is not None:
            # double-buffer path: stall = flatten + clone dispatch only
            self.snapshot_seconds = time.time() - t0
            host = None
        else:
            host = [(n, _leaf_shards(x)) for n, x in named]
            self.snapshot_seconds = time.time() - t0

        path = self.dir / f"ckpt_{step:08d}"
        mesh_axes = dict(mesh.shape) if mesh is not None else None
        plan_meta = _plan_meta(plan)
        shapes = [[int(d) for d in np.shape(x)] for _, x in named]
        self._snapshot_ref = cloned                      # keep clone alive

        def _snapshot_and_persist():
            nonlocal host
            if host is None:
                t1 = time.time()
                host = [(n, _leaf_shards(x, copy=False))
                        for n, x in zip(names, cloned)]
                self.d2h_seconds = time.time() - t1
            t1 = time.time()
            arrays = {}
            shard_meta = []
            for i, (_, shards) in enumerate(host):
                keys = []
                for j, (idx, a) in enumerate(shards):
                    # single-shard leaves keep the legacy "a{i}" key
                    key = f"a{i}" if len(shards) == 1 else f"a{i}_s{j}"
                    arrays[key] = a
                    # sha256 prefix (legacy) + CRC32 + dtype/shape digests:
                    # restore verifies all of them, so a flipped bit, a
                    # truncated member, or a silently retyped array all
                    # surface as CorruptCheckpointError
                    keys.append({"key": key, "index": idx,
                                 "checksum": _checksum(a),
                                 "crc32": _crc32(a),
                                 "dtype": str(a.dtype),
                                 "shape": [int(d) for d in a.shape]})
                shard_meta.append(keys)
            manifest = {
                "step": step,
                "names": names,
                "checksums": [m[0]["checksum"] for m in shard_meta],
                "dtypes": [str(a.dtype) for _, ss in host for _, a in ss[:1]],
                "shapes": shapes,
                "shards": shard_meta,
                "plan": plan_meta,
                "mesh_axes": mesh_axes,
                "time": time.time(),
            }
            self._persist_with_retry(step, path, arrays, manifest)
            self.persist_seconds = time.time() - t1
            if self.flight is not None:
                self.flight.record("ckpt.persist", step, tier="disk",
                                   seconds=self.persist_seconds)
            self._gc()

        def _bg():
            try:
                _snapshot_and_persist()
            except BaseException as e:  # surfaced at next save()/wait()
                self._error = e
            finally:
                self._snapshot_ref = None                # free the clone

        if (self.async_persist or cloned is not None) and not blocking:
            self._pending = threading.Thread(target=_bg, daemon=True)
            self._pending.start()
        else:
            try:
                _snapshot_and_persist()
            finally:
                self._snapshot_ref = None
        return path

    def _persist_once(self, step: int, path: Path, arrays, manifest) -> None:
        """One atomic persist attempt: npz then manifest, each written to a
        temp path and ``os.replace``d into place. The npz lands first — a
        crash between the two leaves no manifest, so the half-written
        checkpoint is never listed, let alone picked as latest. The
        ``ckpt.persist`` fault point fires per attempt (hang /
        persist_exc); ``ckpt.shard_write`` fires *after* a
        successful-looking write (silent corruption: the shard file is
        dropped or truncated but the writer saw no error)."""
        inj = _inject()
        if inj is not None:
            inj.io_fault("ckpt.persist", step)
        tmp_npz = str(path) + ".tmp.npz"          # savez appends .npz itself
        np.savez(tmp_npz[:-4], **arrays)
        os.replace(tmp_npz, str(path) + ".npz")
        tmp_json = Path(str(path) + ".json.tmp")
        tmp_json.write_text(json.dumps(manifest))
        os.replace(tmp_json, path.with_suffix(".json"))
        if inj is not None:
            sp = inj.io_spec_for("ckpt.shard_write", step,
                                 ("drop_write", "truncate_write"))
            if sp is not None:
                npz = Path(str(path) + ".npz")
                if sp.kind == "drop_write":
                    npz.unlink(missing_ok=True)
                else:
                    data = npz.read_bytes()
                    npz.write_bytes(data[:max(len(data) // 2, 1)])

    def _persist_with_retry(self, step: int, path: Path, arrays,
                            manifest) -> None:
        """Exponential-backoff retry around the persist write: transient I/O
        errors (NFS blips, injected persist_exc) are retried up to
        ``io_retries`` times with delays ``io_backoff * 2^k``, bounded by the
        cumulative ``io_timeout`` deadline; the final failure propagates."""
        deadline = time.time() + self.io_timeout
        delay = self.io_backoff
        for attempt in range(1, self.io_retries + 1):
            try:
                return self._persist_once(step, path, arrays, manifest)
            except Exception as e:
                if attempt >= self.io_retries or time.time() + delay > deadline:
                    if self.flight is not None:
                        self.flight.record("ckpt.persist_fail", step,
                                           attempts=attempt, error=repr(e))
                    raise
                time.sleep(delay)
                delay *= 2

    def wait(self):
        """Completion fence: join in-flight snapshot/persist work and raise
        any failure it hit (a persist that dies with its daemon thread would
        otherwise be mistaken for a durable checkpoint)."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"background checkpoint persist failed: {err!r}") from err

    def _is_intact(self, step: int) -> bool:
        """Structural intactness: manifest parses, the npz zip container
        opens, and every recorded shard member is present. Catches dropped
        and truncated shard writes (a truncated zip loses its end-of-file
        central directory) without re-reading shard bytes — cheap enough to
        run per GC pass. Bit flips inside a member are left to the full
        checksum verify at restore time. No fence: also called from the
        persist thread by :meth:`_gc` (``wait()`` there would join the
        thread into itself)."""
        path = self.dir / f"ckpt_{step:08d}"
        try:
            man = self._read_manifest(step)
            with zipfile.ZipFile(str(path) + ".npz") as zf:
                members = set(zf.namelist())
            shard_meta = man.get("shards")
            if shard_meta is None:            # legacy single-array layout
                shard_meta = [[{"key": f"a{i}"}]
                              for i in range(len(man["checksums"]))]
            for metas in shard_meta:
                for m in metas:
                    if m["key"] + ".npy" not in members:
                        return False
            return True
        except (CorruptCheckpointError, OSError, zipfile.BadZipFile,
                KeyError, ValueError):
            return False

    def _gc(self):
        """Evict checkpoints beyond ``keep`` — verify-before-evict.

        Age alone is not a safe eviction key: corrupt checkpoints (dropped /
        truncated shard writes that looked successful) count toward ``keep``,
        so a burst of bad persists used to GC every *restorable* checkpoint
        while keeping only wreckage. Now, if none of the kept (newest
        ``keep``) checkpoints is structurally intact, the newest intact
        candidate among the evictees is spared — a keep-floor of one
        restorable checkpoint whenever one exists. Runs on the persist
        thread, so it must never call :meth:`wait`."""
        steps = []
        for p in self.dir.glob("ckpt_*.json"):
            try:
                steps.append(int(p.stem.split("_", 1)[1]))
            except (IndexError, ValueError):
                continue
        steps.sort()
        doomed = steps[:-self.keep] if self.keep > 0 else list(steps)
        if not doomed:
            return
        spare = None
        if not any(self._is_intact(s) for s in steps[len(doomed):]):
            for s in reversed(doomed):
                if self._is_intact(s):
                    spare = s
                    break
        for s in doomed:
            if s == spare:
                if self.flight is not None:
                    self.flight.record("ckpt.gc_spared", s,
                                       reason="newest_intact_keep_floor")
                continue
            old = self.dir / f"ckpt_{s:08d}.json"
            old.unlink(missing_ok=True)
            old.with_suffix(".npz").unlink(missing_ok=True)

    # -- restore --------------------------------------------------------------

    def steps(self, newest_first: bool = False) -> List[int]:
        """Steps of every checkpoint on disk, parsed from the *filenames*
        (never the manifest contents, so a corrupted JSON still lists and
        can be skipped by a fallback restore)."""
        self.wait()
        out = []
        for p in self.dir.glob("ckpt_*.json"):
            try:
                out.append(int(p.stem.split("_", 1)[1]))
            except (IndexError, ValueError):
                continue
        out.sort(reverse=newest_first)
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def _read_manifest(self, step: int) -> Dict[str, Any]:
        """Manifest JSON for an explicit step — no completion fence, so it
        is safe from the persist thread (:meth:`_is_intact`/:meth:`_gc`)."""
        path = self.dir / f"ckpt_{step:08d}"
        try:
            return json.loads(path.with_suffix(".json").read_text())
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            # json.JSONDecodeError subclasses ValueError — without this wrap
            # it would be mistaken for check_plan's layout-mismatch error
            raise CorruptCheckpointError(
                f"unreadable manifest for step {step} in {self.dir}: "
                f"{e!r}") from e

    def manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The JSON manifest of a checkpoint (layout metadata included)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return self._read_manifest(step)

    def check_plan(self, plan, step: Optional[int] = None, *,
                   mesh=None, elastic: bool = False) -> str:
        """Route a restore: ``"replay"`` or ``"reshard"``.

        Compares the checkpoint's recorded ParallelPlan layout axes (and,
        when ``mesh`` is given, the mesh axis sizes) against the requested
        ones. Matching layouts replay shard-to-shard. Differing layouts
        return ``"reshard"`` when ``elastic=True`` — take
        :meth:`restore_resharded` — and raise ``ValueError`` otherwise:
        replaying a shard-written checkpoint onto a different cp/tp/dp
        layout silently reshards, which is exactly the failure mode a
        non-elastic ft/recovery must refuse.
        """
        man = self.manifest(step)
        diffs = layout_diffs(man, plan, mesh)
        if not diffs:
            return "replay"
        if elastic:
            return "reshard"
        raise ValueError(
            f"checkpoint layout mismatch (recorded != requested): {diffs}")

    def _load_full(self, step: Optional[int], verify: bool
                   ) -> Tuple[int, Dict[str, Any], List[np.ndarray]]:
        """Reassemble every leaf into a full host array from its recorded
        shard slices; returns (step, manifest, arrays)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return self._read_full(step, verify)

    def _read_full(self, step: int, verify: bool
                   ) -> Tuple[int, Dict[str, Any], List[np.ndarray]]:
        """:meth:`_load_full` minus the fence and step resolution — usable
        where ``wait()`` is illegal (persist thread) or already done."""
        path = self.dir / f"ckpt_{step:08d}"
        manifest = self._read_manifest(step)
        try:
            data = np.load(str(path) + ".npz")
        except (OSError, ValueError, zipfile.BadZipFile) as e:
            # missing / truncated / corrupted zip container
            raise CorruptCheckpointError(
                f"unreadable shard file {path}.npz: {e!r}") from e
        shard_meta = manifest.get("shards")
        if shard_meta is None:                # legacy single-array layout
            shard_meta = [[{"key": f"a{i}", "index": None, "checksum": c}]
                          for i, c in enumerate(manifest["checksums"])]
        arrays = []
        for metas, shape, dt, n in zip(
                shard_meta, manifest["shapes"], manifest["dtypes"],
                manifest["names"]):
            for m in metas:
                try:
                    a = data[m["key"]]
                except Exception as e:        # truncated/dropped zip member
                    raise CorruptCheckpointError(
                        f"unreadable shard {m['key']} for {n} in "
                        f"{path}: {e!r}") from e
                if not verify:
                    continue
                if _checksum(a) != m["checksum"] or \
                        ("crc32" in m and _crc32(a) != m["crc32"]):
                    raise CorruptCheckpointError(
                        f"checksum mismatch for {n} in {path}")
                if "dtype" in m and str(a.dtype) != m["dtype"]:
                    raise CorruptCheckpointError(
                        f"dtype digest mismatch for {n} in {path}: "
                        f"{a.dtype} != {m['dtype']}")
                if "shape" in m and list(a.shape) != list(m["shape"]):
                    raise CorruptCheckpointError(
                        f"shape digest mismatch for {n} in {path}: "
                        f"{list(a.shape)} != {m['shape']}")
            if len(metas) == 1:
                # one unique shard ⇒ it covers the whole array (a valid
                # sharding's shards union to the full index space)
                arrays.append(data[metas[0]["key"]])
                continue
            full = np.zeros(shape, dtype=np.dtype(dt))
            for m in metas:
                sl = tuple(slice(a, b) for a, b in m["index"])
                full[sl] = data[m["key"]]
            arrays.append(full)
        return step, manifest, arrays

    def restore(self, tree_like: Any, step: Optional[int] = None,
                verify: bool = True) -> Tuple[int, Any]:
        """Restore into the structure of ``tree_like``; returns (step, tree).

        Shards are reassembled by their recorded index slices; leaves whose
        ``tree_like`` twin carries a sharding are re-placed with it
        (device_put), so a cp/tp-sharded state restores shard-to-shard.
        """
        step, manifest, arrays = self._load_full(step, verify)
        named = _flatten_with_names(tree_like)
        assert [n for n, _ in named] == manifest["names"], \
            "checkpoint tree structure mismatch"
        leaves = []
        for a, (_, l) in zip(arrays, named):
            arr = jax.numpy.asarray(a, dtype=l.dtype)
            # re-place committed leaves on their recorded layout; an
            # uncommitted leaf (e.g. the scalar opt step) stays uncommitted —
            # committing it to one device would conflict with mesh-committed
            # siblings inside the jitted step
            if isinstance(l, jax.Array) and getattr(l, "committed", False):
                arr = jax.device_put(arr, l.sharding)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(tree_like)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_resharded(self, tree_like: Any, shardings: Any = None,
                          step: Optional[int] = None, verify: bool = True
                          ) -> Tuple[int, Any]:
        """Elastic restore onto a *different* mesh layout (survey §8.3.2).

        Full arrays are reassembled from the manifest's global-index shard
        slices — written on whatever mesh the checkpoint came from — and
        every leaf is re-sliced onto the target layout: ``shardings`` is a
        pytree (same structure as ``tree_like``) of target shardings, e.g.
        :func:`repro.core.sharding.train_state_shardings` under the new
        plan/mesh, which re-scatters the ZeRO-1 optimizer moment shards over
        the new data axis and re-shards tp/cp params onto the new model
        axes. Leaves whose ``shardings`` entry is None fall back to the
        ``tree_like`` twin's own sharding (matching :meth:`restore`).
        Returns (step, tree) with every leaf device_put on the target.
        """
        step, manifest, arrays = self._load_full(step, verify)
        named = _flatten_with_names(tree_like)
        assert [n for n, _ in named] == manifest["names"], \
            "checkpoint tree structure mismatch"
        treedef = jax.tree_util.tree_structure(tree_like)
        if shardings is None:
            target = [None] * len(named)
        else:
            target = treedef.flatten_up_to(shardings)
        leaves = []
        for a, (_, l), s in zip(arrays, named, target):
            arr = jax.numpy.asarray(a, dtype=getattr(l, "dtype", None) or a.dtype)
            if s is None and isinstance(l, jax.Array) \
                    and getattr(l, "committed", False):
                s = l.sharding      # same committed-only rule as restore()
            if s is not None:
                arr = jax.device_put(arr, s)
            leaves.append(arr)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
