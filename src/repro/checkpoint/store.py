"""Checkpointing (survey §8.3).

Persistent checkpoints follow the snapshot/persist split of §8.3.1:

- ``snapshot``: device -> host copy (fast; the only phase that stalls training).
- ``persist``: host -> disk write, runs on a background thread
  (snapshot-stall checkpointing à la Check-N-Run/MegaScale).

Layout: one ``.npz`` per checkpoint plus a JSON manifest carrying the step,
the flattened tree structure and integrity checksums. ``save_sharded`` writes
one shard per data-parallel writer rank to emulate the distributed-filesystem
layout (survey §3.3.1: a designated worker per DP group writes its shard).
"""

from __future__ import annotations

import hashlib
import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "name", getattr(p, "idx", p)))
            for p in path)
        out.append((name, leaf))
    return out


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_persist: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_persist = async_persist
        self._pending: Optional[threading.Thread] = None
        self.snapshot_seconds = 0.0
        self.persist_seconds = 0.0

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> Path:
        """Snapshot (stalls) then persist (async unless blocking)."""
        t0 = time.time()
        named = _flatten_with_names(tree)
        host = [(n, np.asarray(x)) for n, x in named]     # snapshot phase
        self.snapshot_seconds = time.time() - t0

        path = self.dir / f"ckpt_{step:08d}"

        def _persist():
            t1 = time.time()
            arrays = {f"a{i}": a for i, (_, a) in enumerate(host)}
            np.savez(str(path) + ".npz", **arrays)
            manifest = {
                "step": step,
                "names": [n for n, _ in host],
                "checksums": [_checksum(a) for _, a in host],
                "dtypes": [str(a.dtype) for _, a in host],
                "shapes": [list(a.shape) for _, a in host],
                "time": time.time(),
            }
            (path.with_suffix(".json")).write_text(json.dumps(manifest))
            self.persist_seconds = time.time() - t1
            self._gc()

        self.wait()                                      # one in flight max
        if self.async_persist and not blocking:
            self._pending = threading.Thread(target=_persist, daemon=True)
            self._pending.start()
        else:
            _persist()
        return path

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("ckpt_*.json"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)
            old.with_suffix(".npz").unlink(missing_ok=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        self.wait()
        ckpts = sorted(self.dir.glob("ckpt_*.json"))
        if not ckpts:
            return None
        return json.loads(ckpts[-1].read_text())["step"]

    def restore(self, tree_like: Any, step: Optional[int] = None,
                verify: bool = True) -> Tuple[int, Any]:
        """Restore into the structure of ``tree_like``; returns (step, tree)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"ckpt_{step:08d}"
        manifest = json.loads(path.with_suffix(".json").read_text())
        data = np.load(str(path) + ".npz")
        arrays = [data[f"a{i}"] for i in range(len(manifest["names"]))]
        if verify:
            for a, c, n in zip(arrays, manifest["checksums"], manifest["names"]):
                if _checksum(a) != c:
                    raise IOError(f"checksum mismatch for {n} in {path}")
        named = _flatten_with_names(tree_like)
        assert [n for n, _ in named] == manifest["names"], \
            "checkpoint tree structure mismatch"
        leaves = [jax.numpy.asarray(a, dtype=l.dtype)
                  for a, (_, l) in zip(arrays, named)]
        treedef = jax.tree_util.tree_structure(tree_like)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
