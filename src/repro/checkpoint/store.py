"""Checkpointing (survey §8.3).

Persistent checkpoints follow the snapshot/persist split of §8.3.1:

- ``snapshot``: device -> host copy (fast; the only phase that stalls training).
- ``persist``: host -> disk write, runs on a background thread
  (snapshot-stall checkpointing à la Check-N-Run/MegaScale).

Layout: one ``.npz`` per checkpoint plus a JSON manifest carrying the step,
the flattened tree structure and integrity checksums.

Shard-aware (survey §3.3.1: a designated worker per group writes its shard):
the snapshot phase walks ``jax.Array.addressable_shards`` and copies each
*unique* device shard to host instead of gathering the full array — under
cp/tp/ZeRO meshes the device→host copy moves 1/shards of the bytes and the
replicated copy never materializes. The manifest records each shard's index
slices plus the :class:`repro.core.config.ParallelPlan` axes
(``tp``/``cp``/``pp``/``dp_shard``/``zero_stage``/impl knobs) and mesh axis
sizes, so ``ft/recovery.py`` can refuse to replay a checkpoint onto an
incompatible layout. ``restore`` reassembles full arrays from the shard
slices and re-places them with each target leaf's sharding.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

# the ParallelPlan fields recorded in the manifest (impl/schedule knobs ride
# along for forensics) ...
PLAN_AXES = ("tp", "tp_impl", "cp", "cp_impl", "dp_shard", "zero_stage",
             "ep", "pp", "pp_schedule")
# ... and the subset check_plan actually compares: only the axes that change
# how saved state maps onto devices. A pure schedule/impl change
# (gpipe→1f1b, gather→ring) is replay-safe — restore reassembles full
# arrays and re-places them — so it must not be refused.
PLAN_LAYOUT_AXES = ("tp", "cp", "dp_shard", "zero_stage", "ep", "pp")


def _plan_meta(plan) -> Optional[Dict[str, Any]]:
    if plan is None:
        return None
    d = dataclasses.asdict(plan)
    return {k: d[k] for k in PLAN_AXES if k in d}


def _index_json(index: Tuple[slice, ...], shape) -> List[List[int]]:
    """A shard's global-index slices as JSON: [[start, stop], ...]."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def _leaf_shards(x) -> List[Tuple[List[List[int]], np.ndarray]]:
    """Unique (index, host copy) pairs for one leaf.

    jax.Arrays snapshot per addressable shard (replicas deduped by index);
    anything else (numpy, python scalars) is a single whole-array shard.
    """
    if isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer):
        if not x.is_fully_addressable:
            # multi-process meshes: this process sees only its own shards;
            # recording a partial shard list and zero-filling the rest at
            # restore would be silent corruption — fail loudly (the
            # multi-host per-writer layout is future work)
            raise ValueError(
                "sharded checkpoint save requires fully-addressable arrays; "
                "multi-process meshes need a per-host writer rank")
        seen: Dict[Tuple, Tuple[List[List[int]], np.ndarray]] = {}
        for sh in x.addressable_shards:
            idx = _index_json(tuple(sh.index), x.shape)
            key = tuple(map(tuple, idx))
            if key not in seen:
                seen[key] = (idx, np.asarray(sh.data))
        return list(seen.values())
    arr = np.asarray(x)
    return [(_index_json(tuple(slice(0, d) for d in arr.shape), arr.shape),
             arr)]


def _flatten_with_names(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "name", getattr(p, "idx", p)))
            for p in path)
        out.append((name, leaf))
    return out


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_persist: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_persist = async_persist
        self._pending: Optional[threading.Thread] = None
        self.snapshot_seconds = 0.0
        self.persist_seconds = 0.0

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False,
             plan=None, mesh=None) -> Path:
        """Snapshot (stalls) then persist (async unless blocking).

        The snapshot copies each leaf's unique *addressable shards* to host
        (no full-array gather); ``plan``/``mesh`` record the layout axes in
        the manifest so replay can verify compatibility.
        """
        t0 = time.time()
        named = _flatten_with_names(tree)
        # snapshot phase: per-device shards, replicas deduped by index
        host = [(n, tuple(np.shape(x)),
                 str(getattr(x, "dtype", np.asarray(x).dtype)),
                 _leaf_shards(x)) for n, x in named]
        self.snapshot_seconds = time.time() - t0

        path = self.dir / f"ckpt_{step:08d}"
        mesh_axes = dict(mesh.shape) if mesh is not None else None

        def _persist():
            t1 = time.time()
            arrays = {}
            shard_meta = []
            for i, (_, _, _, shards) in enumerate(host):
                keys = []
                for j, (idx, a) in enumerate(shards):
                    # single-shard leaves keep the legacy "a{i}" key
                    key = f"a{i}" if len(shards) == 1 else f"a{i}_s{j}"
                    arrays[key] = a
                    keys.append({"key": key, "index": idx,
                                 "checksum": _checksum(a)})
                shard_meta.append(keys)
            np.savez(str(path) + ".npz", **arrays)
            manifest = {
                "step": step,
                "names": [n for n, _, _, _ in host],
                "checksums": [m[0]["checksum"] for m in shard_meta],
                "dtypes": [d for _, _, d, _ in host],
                "shapes": [list(s) for _, s, _, _ in host],
                "shards": shard_meta,
                "plan": _plan_meta(plan),
                "mesh_axes": mesh_axes,
                "time": time.time(),
            }
            (path.with_suffix(".json")).write_text(json.dumps(manifest))
            self.persist_seconds = time.time() - t1
            self._gc()

        self.wait()                                      # one in flight max
        if self.async_persist and not blocking:
            self._pending = threading.Thread(target=_persist, daemon=True)
            self._pending.start()
        else:
            _persist()
        return path

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("ckpt_*.json"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)
            old.with_suffix(".npz").unlink(missing_ok=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        self.wait()
        ckpts = sorted(self.dir.glob("ckpt_*.json"))
        if not ckpts:
            return None
        return json.loads(ckpts[-1].read_text())["step"]

    def manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The JSON manifest of a checkpoint (layout metadata included)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"ckpt_{step:08d}"
        return json.loads(path.with_suffix(".json").read_text())

    def check_plan(self, plan, step: Optional[int] = None) -> None:
        """Raise ValueError if the checkpoint's recorded ParallelPlan axes
        disagree with ``plan`` — replaying onto a different cp/tp/pp layout
        silently reshards, which is exactly the failure mode ft/recovery
        must refuse."""
        recorded = self.manifest(step).get("plan")
        if recorded is None or plan is None:
            return
        want = _plan_meta(plan)
        diffs = {k: (recorded[k], want[k]) for k in PLAN_LAYOUT_AXES
                 if k in recorded and k in want and recorded[k] != want[k]}
        if diffs:
            raise ValueError(
                f"checkpoint layout mismatch (recorded != requested): {diffs}")

    def restore(self, tree_like: Any, step: Optional[int] = None,
                verify: bool = True) -> Tuple[int, Any]:
        """Restore into the structure of ``tree_like``; returns (step, tree).

        Shards are reassembled by their recorded index slices; leaves whose
        ``tree_like`` twin carries a sharding are re-placed with it
        (device_put), so a cp/tp-sharded state restores shard-to-shard.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"ckpt_{step:08d}"
        manifest = json.loads(path.with_suffix(".json").read_text())
        data = np.load(str(path) + ".npz")
        shard_meta = manifest.get("shards")
        if shard_meta is None:                # legacy single-array layout
            shard_meta = [[{"key": f"a{i}", "index": None, "checksum": c}]
                          for i, c in enumerate(manifest["checksums"])]
        arrays = []
        for i, (metas, shape, dt, n) in enumerate(zip(
                shard_meta, manifest["shapes"], manifest["dtypes"],
                manifest["names"])):
            if verify:
                for m in metas:
                    if _checksum(data[m["key"]]) != m["checksum"]:
                        raise IOError(f"checksum mismatch for {n} in {path}")
            if len(metas) == 1:
                # one unique shard ⇒ it covers the whole array (a valid
                # sharding's shards union to the full index space)
                arrays.append(data[metas[0]["key"]])
                continue
            full = np.zeros(shape, dtype=np.dtype(dt))
            for m in metas:
                sl = tuple(slice(a, b) for a, b in m["index"])
                full[sl] = data[m["key"]]
            arrays.append(full)
        named = _flatten_with_names(tree_like)
        assert [n for n, _ in named] == manifest["names"], \
            "checkpoint tree structure mismatch"
        leaves = []
        for a, (_, l) in zip(arrays, named):
            arr = jax.numpy.asarray(a, dtype=l.dtype)
            sharding = getattr(l, "sharding", None)
            if sharding is not None and isinstance(l, jax.Array):
                arr = jax.device_put(arr, sharding)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(tree_like)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
