from .store import CheckpointManager

__all__ = ["CheckpointManager"]
