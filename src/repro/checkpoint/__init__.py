from .memory import MemoryCheckpointTier
from .store import CheckpointManager, CorruptCheckpointError

__all__ = ["CheckpointManager", "CorruptCheckpointError",
           "MemoryCheckpointTier"]
