"""AdamW, written against raw pytrees (optax is not in the image).

Moments are stored in fp32 regardless of compute dtype (mixed-precision
training keeps an fp32 master copy of optimizer state, survey §5.2.1). State
sharding follows ``repro.core.sharding.opt_state_specs`` — ZeRO-1 (survey
§6.2.1): moments shard over the ``data`` axis even when params replicate.

:func:`adamw_update` is the plain replicated math; :func:`adamw_update_sharded`
is the ZeRO-1 execution of the same math — grads are reduce-scattered onto the
moment shards (a sharding constraint that GSPMD lowers to reduce-scatter
instead of all-reduce), the elementwise update runs on each device's 1/DP slice
of the fp32 moments, and only the updated params are all-gathered back to
their replicated layout. Numerically identical to the replicated update;
per-device optimizer memory and update FLOPs drop by the data-axis size.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: Any                  # first moment, same tree as params
    nu: Any                  # second moment


def adamw_init(params: Any, *, mesh: Mesh = None,
               specs: Any = None) -> AdamWState:
    """Zero moments; with ``mesh`` + ``specs`` (PartitionSpecs from
    ``core.sharding.opt_state_specs``) they are *born* on the ZeRO-1 layout —
    data-scattered from step 0 instead of waiting for the first sharded
    update to constrain them. An elastic restore needs this: the state
    template's moment leaves must already carry the target shardings."""
    if mesh is not None and specs is not None:
        zeros = lambda p, s: jnp.zeros(p.shape, jnp.float32,
                                       device=NamedSharding(mesh, s))
        moments = lambda: jax.tree.map(zeros, params, specs)
    else:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        moments = lambda: jax.tree.map(zeros, params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=moments(),
        nu=moments(),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """Returns (new_params, new_state). Decay is decoupled (AdamW)."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # no weight decay on 1-D params (norm scales, biases) — standard practice
        wd = weight_decay if p.ndim > 1 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)


def constrain_tree(tree: Any, specs: Any, mesh: Mesh) -> Any:
    """Pin every leaf of ``tree`` to the matching PartitionSpec in ``specs``."""
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), tree, specs)


def adamw_update_sharded(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr,
    *,
    mesh: Mesh,
    param_specs: Any,
    opt_specs: Any,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """ZeRO-1 sharded AdamW step (survey §6.2.1).

    ``opt_specs`` (from ``core.sharding.opt_state_specs``) shard the fp32
    moments over the ``data`` axis; ``param_specs`` is the params' own layout.
    The grads/params inputs are constrained onto the moment shards (XLA emits
    a reduce-scatter/slice, not an all-reduce), the update math runs shard-
    local, and the updated params are constrained back to ``param_specs`` —
    the all-gather that completes the ZeRO-1 round trip.
    """
    grads = constrain_tree(grads, opt_specs, mesh)
    shard_state = AdamWState(state.step,
                             constrain_tree(state.mu, opt_specs, mesh),
                             constrain_tree(state.nu, opt_specs, mesh))
    shard_params = constrain_tree(params, opt_specs, mesh)
    new_params, new_state = adamw_update(
        grads, shard_state, shard_params, lr, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay)
    # moments stay scattered (that's the memory win); params re-replicate
    return constrain_tree(new_params, param_specs, mesh), new_state
