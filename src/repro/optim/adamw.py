"""AdamW, written against raw pytrees (optax is not in the image).

Moments are stored in fp32 regardless of compute dtype (mixed-precision
training keeps an fp32 master copy of optimizer state, survey §5.2.1). State
sharding follows ``repro.core.sharding.opt_state_specs`` — ZeRO-1 (survey
§6.2.1): moments shard over the ``data`` axis even when params replicate.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: Any                  # first moment, same tree as params
    nu: Any                  # second moment


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """Returns (new_params, new_state). Decay is decoupled (AdamW)."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # no weight decay on 1-D params (norm scales, biases) — standard practice
        wd = weight_decay if p.ndim > 1 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)
