from .adamw import (AdamWState, adamw_init, adamw_update,
                    adamw_update_sharded, constrain_tree)
from .schedule import cosine_schedule, linear_warmup
from .clip import clip_by_global_norm

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "adamw_update_sharded",
    "constrain_tree",
    "cosine_schedule", "linear_warmup", "clip_by_global_norm",
]
