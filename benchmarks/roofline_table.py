"""Summarize dry-run artifacts into the §Roofline table (deliverable (g)).

    PYTHONPATH=src python -m benchmarks.roofline_table [--dir experiments/dryrun]
        [--tag baseline] [--mesh pod16x16] [--markdown]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def load_rows(d: Path, tag: str, mesh: str):
    rows = []
    for p in sorted(d.glob(f"{tag}__*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec.get("status"),
                         "reason": rec.get("reason", rec.get("error", ""))})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_compute": r["t_compute_s"], "t_memory": r["t_memory_s"],
            "t_collective": r["t_collective_s"], "bottleneck": r["bottleneck"],
            "useful": r["useful_flops_ratio"],
            "mem_temp": (rec.get("memory_analysis") or {}).get(
                "temp_size_in_bytes"),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_rows(Path(args.dir), args.tag, args.mesh)
    if args.markdown:
        print("| arch | shape | t_compute | t_memory | t_collective | "
              "bottleneck | useful_flops |")
        print("|---|---|---|---|---|---|---|")
        for r in rows:
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                      f"{r['status']}: {r['reason'][:60]} | — |")
            else:
                print(f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
                      f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
                      f"**{r['bottleneck']}** | {r['useful']:.2f} |")
    else:
        print("arch,shape,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
              "useful_flops_ratio")
        for r in rows:
            if r["status"] != "ok":
                print(f"{r['arch']},{r['shape']},,,,{r['status']},")
            else:
                print(f"{r['arch']},{r['shape']},{r['t_compute']:.4e},"
                      f"{r['t_memory']:.4e},{r['t_collective']:.4e},"
                      f"{r['bottleneck']},{r['useful']:.3f}")


if __name__ == "__main__":
    main()
