"""Benchmark harness — one function per survey table/figure family.

Prints ``name,us_per_call,derived`` CSV rows. Wall-times are real measurements
on this host (CPU device; relative numbers are what matters). ``derived``
carries the table's analytic quantity (bytes, ratios, latencies).

    PYTHONPATH=src python -m benchmarks.run [--only <prefix>]

Roofline terms for the production mesh come from the dry-run artifacts
(`python -m repro.launch.dryrun`), summarized by benchmarks/roofline_table.py.
"""

from __future__ import annotations

import argparse
import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Family, InputShape, ModelConfig, MoEConfig,
                        ParallelPlan, SSMConfig)
from repro.core import sharding as shardlib
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticDataset
from repro.ft import Monitor
from repro.models import build_model
from repro.models.layers import attention_blockwise, attention_direct
from repro.train import Hyper, init_train_state, make_train_step

ROWS: List[str] = []


def emit(name: str, us: float, derived: str = ""):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run_multidevice(script: str, n_devices: int, sentinel: str,
                    timeout: int = 1200) -> str:
    """Run a python snippet in a subprocess with N forced host devices and
    require a success sentinel on its stdout (benches in-process must see 1
    device, per the dry-run contract — mirror of tests/conftest.py)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0 or sentinel not in proc.stdout:
        raise RuntimeError(
            f"multidevice bench subprocess failed\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


def _tiny_cfg(**kw) -> ModelConfig:
    base = dict(arch_id="bench", family=Family.DENSE, n_layers=2, d_model=128,
                n_heads=4, n_kv_heads=2, d_ff=256, vocab=512)
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# survey §5.1.1 (FlashAttention / memory-efficient attention table)

def bench_attention():
    rng = np.random.default_rng(0)
    b, h, hd = 1, 4, 64
    for s in (256, 1024, 4096):
        q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
        k, v = q, q
        direct = jax.jit(lambda q, k, v: attention_direct(q, k, v, causal=True))
        blockw = jax.jit(lambda q, k, v: attention_blockwise(
            q, k, v, causal=True, block_size=256))
        us_d = timeit(direct, q, k, v)
        us_b = timeit(blockw, q, k, v)
        # derived: live score-matrix bytes (direct) vs blockwise working set
        direct_bytes = b * h * s * s * 4
        block_bytes = b * h * s * 256 * 4
        emit(f"attention.direct.s{s}", us_d, f"score_bytes={direct_bytes}")
        emit(f"attention.blockwise.s{s}", us_b,
             f"score_bytes={block_bytes};ratio={direct_bytes/block_bytes:.0f}x")

    # Pallas kernel (interpret mode -> correctness/latency sanity, small shape)
    from repro.kernels import flash_attention
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    us_f = timeit(lambda: flash_attention(q, q, q, block_q=128, block_k=128),
                  iters=1)
    emit("attention.pallas_interpret.s256", us_f,
         "note=python-interpreted;validates-correctness-not-speed")

    # fwd+bwd through each implementation (survey §5.1.1: FlashAttention-2's
    # one-write/two-reads backward is what makes the fused kernel pay off in
    # training, not just prefill)
    from repro.models.layers import attention
    s = 256
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k, v = q, q

    def fwdbwd(impl, block_size):
        def loss(q, k, v):
            return jnp.sum(attention(q, k, v, causal=True, impl=impl,
                                     block_size=block_size))
        return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))

    # bytes the autodiff backward re-materializes (scores + probs, fp32) vs
    # the fused backward's extra residual (one lse row per query)
    rematerialized = 2 * b * h * s * s * 4
    lse_bytes = b * h * s * 4
    for name, impl, block_size, iters in [
        ("xla_direct", "xla", 1024, 3),       # t <= 2*block -> direct
        ("xla_blockwise", "xla", 64, 3),
        ("pallas", "pallas", 1024, 1),        # interpret mode off-TPU
    ]:
        fn = fwdbwd(impl, block_size)
        us = timeit(lambda: fn(q, k, v), iters=iters)
        extra = {"xla_direct": f";bwd_score_bytes={rematerialized}",
                 "pallas": f";lse_bytes={lse_bytes}"}.get(name, "")
        emit(f"attention.fwdbwd.{name}.s{s}", us,
             f"phase=fwd+bwd;impl={impl}{extra}")


# ---------------------------------------------------------------------------
# survey §4.1.1/§6.2 (ZeRO/FSDP memory-vs-communication table)

def bench_memory_sharding():
    from jax.sharding import PartitionSpec as P
    cfg = _tiny_cfg(n_layers=4, d_model=512, d_ff=2048, vocab=8192)
    plan = ParallelPlan()
    model = build_model(cfg, plan)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    class M:
        shape = {"data": 16, "model": 16}

    def frac(tree_specs):
        tot = used = 0
        for p, s in zip(jax.tree.leaves(params),
                        jax.tree.leaves(tree_specs,
                                        is_leaf=lambda x: isinstance(x, P))):
            n = 1
            for ax in s:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= M.shape[a]
            tot += int(np.prod(p.shape))
            used += int(np.prod(p.shape)) // n
        return used / tot

    for name, pl in [
        ("replicated_F1", ParallelPlan(dp_shard=1, zero_stage=0)),
        ("zero1", ParallelPlan(dp_shard=1, zero_stage=1)),
        ("fsdp_F16", ParallelPlan(dp_shard=16, zero_stage=1)),
    ]:
        t0 = time.perf_counter()
        specs = shardlib.param_specs(params, cfg, pl, M)
        us = (time.perf_counter() - t0) * 1e6
        ospecs = shardlib.opt_state_specs(specs, params, pl, M)
        pf, of = frac(specs), frac(ospecs)
        # model states = 16Φ (survey §6): 4Φ params+grads, 12Φ optimizer
        per_dev = (4 * pf + 12 * of) / 16
        emit(f"memory.model_states.{name}", us,
             f"param_frac={pf:.4f};opt_frac={of:.4f};"
             f"model_state_frac_per_dev={per_dev:.4f}")


# ---------------------------------------------------------------------------
# survey §4.1/§6.1 (parallelism & recomputation throughput table)

def bench_train_plans():
    cfg = _tiny_cfg()
    shape = InputShape("b", 64, 8, "train")
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    for name, plan in [
        ("remat_none", ParallelPlan(remat="none", compute_dtype="float32")),
        ("remat_selective", ParallelPlan(remat="selective", compute_dtype="float32")),
        ("remat_full", ParallelPlan(remat="full", compute_dtype="float32")),
        ("microbatch4", ParallelPlan(remat="none", compute_dtype="float32",
                                     microbatches=4)),
    ]:
        model = build_model(cfg, plan)
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, plan, Hyper(total_steps=10)))
        us = timeit(step, state, batch, warmup=1, iters=3)
        toks = shape.global_batch * shape.seq_len
        emit(f"train.{name}", us, f"tokens_per_s={toks/(us/1e6):.0f}")


# ---------------------------------------------------------------------------
# survey §4.1.5 (MoE dispatch table)

def bench_moe():
    from repro.kernels import dispatch_expert_gemm, expert_gemm
    from repro.kernels.ref import expert_gemm_ref
    cfg = _tiny_cfg(family=Family.MOE, d_ff=0,
                    moe=MoEConfig(num_experts=8, top_k=2, d_expert=256))
    shape = InputShape("b", 64, 8, "train")
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    us = timeit(fwd, params, batch)
    n = shape.global_batch * shape.seq_len
    e = cfg.moe
    cap = int(n * e.top_k / e.num_experts * e.capacity_factor)
    a2a_bytes = 2 * e.num_experts * cap * cfg.d_model * 2   # two all-to-alls, bf16
    emit("moe.dense_dispatch.fwd", us,
         f"capacity={cap};a2a_bytes_if_ep={a2a_bytes}")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 128, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 128, 256)), jnp.float32)
    us_ref = timeit(jax.jit(expert_gemm_ref), x, w)
    emit("moe.expert_gemm.xla", us_ref, "shape=E8xC128xd128xf256")
    us_k = timeit(lambda: expert_gemm(x, w), iters=1)
    emit("moe.expert_gemm.pallas_interpret", us_k,
         "note=python-interpreted;validates-correctness-not-speed")

    # fwd+bwd through the grouped GEMM (survey §4.1.5): the custom-VJP
    # backward runs two more grouped GEMMs through the same tiled kernel,
    # with group_sizes skipping the padding-row tiles of imbalanced experts
    gs = jnp.asarray([128, 96, 64, 17, 0, 128, 33, 80], jnp.int32)
    masked_rows = int(gs.sum())
    flop_frac = masked_rows / (8 * 128)

    def fwdbwd(impl):
        def loss(x, w):
            return jnp.sum(dispatch_expert_gemm(x, w, gs, impl=impl))
        return jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))

    for name, impl, iters in [("xla", "xla", 3),
                              ("pallas_interpret", "pallas", 1)]:
        fn = fwdbwd(impl)
        us = timeit(lambda: fn(x, w), iters=iters)
        emit(f"moe.expert_gemm.fwdbwd.{name}", us,
             f"phase=fwd+bwd;group_sizes_flop_frac={flop_frac:.2f}")


# ---------------------------------------------------------------------------
# Mamba2 SSD (the §Perf pair-B residual bottleneck)

def bench_ssd():
    from repro.kernels import ssd_chunk_scan
    from repro.models.ssm import ssd_scan
    rng = np.random.default_rng(0)
    b, l, h, p, g, n, chunk = 1, 512, 4, 32, 1, 64, 128
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    us_x = timeit(jax.jit(lambda *a: ssd_scan(*a, chunk=chunk)[0]),
                  x, dt, A, B, C)
    # HBM traffic the pure-jnp path materializes for the decay matrices alone
    l_bytes = b * (l // chunk) * h * chunk * chunk * 4
    vmem = chunk * (p + 2 * n + chunk) * 4 + p * n * 4
    emit("ssd.xla_chunked.l512", us_x,
         f"decay_matrix_hbm_bytes={l_bytes};kernel_vmem_bytes={vmem}")
    us_k = timeit(lambda: ssd_chunk_scan(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
        B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3), chunk=chunk)[0],
        iters=1)
    emit("ssd.pallas_interpret.l512", us_k,
         "note=python-interpreted;validates-correctness-not-speed")

    # fwd+bwd: XLA autodiff re-materializes the (b, c, h, q, q) decay tensor
    # for the backward; the fused custom-VJP kernel saves only per-chunk
    # entering states and recomputes decays tile-by-tile in VMEM
    from repro.kernels import dispatch_ssd_scan
    enter_bytes = b * (l // chunk) * h * p * n * 4

    def fwdbwd(impl):
        def loss(x, dt, B, C):
            y, _ = dispatch_ssd_scan(x, dt, A, B, C, chunk=chunk, impl=impl)
            return jnp.sum(y)
        return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2, 3)))

    for name, impl, iters in [("xla", "xla", 3),
                              ("pallas_interpret", "pallas", 1)]:
        fn = fwdbwd(impl)
        us = timeit(lambda: fn(x, dt, B, C), iters=iters)
        extra = (f";bwd_decay_hbm_bytes={2 * l_bytes}" if impl == "xla"
                 else f";entering_state_bytes={enter_bytes}")
        emit(f"ssd.fwdbwd.{name}.l512", us, f"phase=fwd+bwd{extra}")


# ---------------------------------------------------------------------------
# survey §6.1/§6.2 (memory-lean training path: remat × family trade-off table)

def bench_trainstep():
    """Peak-live-memory vs step-time per remat policy, per family — the §6.1
    trade-off the 1F1B/remat/ZeRO-1 path exists to exploit. ``us_per_call`` is
    a real jitted step; ``peak_temp_bytes`` comes from
    ``jax.stages.Compiled.memory_analysis()`` (XLA's buffer assignment for the
    step's live intermediates, the quantity remat actually shrinks).
    The GPipe-vs-1F1B compiled-memory ordering needs a multi-device mesh and
    is asserted in tests/test_train_memory.py instead.
    """
    shape = InputShape("b", 64, 8, "train")
    fams = [
        ("dense", _tiny_cfg(n_layers=4)),
        ("moe", _tiny_cfg(n_layers=4, family=Family.MOE, d_ff=0,
                          moe=MoEConfig(num_experts=4, top_k=2, d_expert=128))),
        ("ssm", _tiny_cfg(n_layers=4, n_heads=0, n_kv_heads=0, d_ff=0,
                          family=Family.SSM,
                          ssm=SSMConfig(d_state=16, head_dim=32, expand=2))),
    ]
    toks = shape.global_batch * shape.seq_len
    for fam_name, cfg in fams:
        ds = SyntheticDataset(cfg, shape)
        batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
        for remat in ("none", "selective", "full"):
            plan = ParallelPlan(remat=remat, compute_dtype="float32")
            model = build_model(cfg, plan)
            state = init_train_state(model, jax.random.PRNGKey(0))
            step = jax.jit(make_train_step(model, plan, Hyper(total_steps=10)))
            # AOT-compile once and time the Compiled directly (a jit call
            # would not reuse this executable and would compile again)
            compiled = step.lower(state, batch).compile()
            ma = compiled.memory_analysis()
            temp = getattr(ma, "temp_size_in_bytes", None) if ma else None
            args = getattr(ma, "argument_size_in_bytes", None) if ma else None
            us = timeit(compiled, state, batch, warmup=1, iters=3)
            emit(f"trainstep.{fam_name}.remat_{remat}", us,
                 f"tokens_per_s={toks/(us/1e6):.0f};peak_temp_bytes={temp};"
                 f"arg_bytes={args}")


# ---------------------------------------------------------------------------
# survey §4.1.2/§5.2 (overlap-aware tensor parallelism: gspmd vs ring overlap)

_TP_BENCH_SCRIPT = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (Family, InputShape, ModelConfig, MoEConfig, SSMConfig,
                        ParallelPlan, sharding)
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.perf.hlo_cost import analyze_hlo
from repro.train import Hyper, make_loss_fn
from repro.train.tensor_parallel import make_tp_loss_fn

fams = {
    "dense": ModelConfig("btp", Family.DENSE, n_layers=2, d_model=128,
                         n_heads=4, n_kv_heads=2, d_ff=256, vocab=512),
    # capacity_factor >= E/top_k -> no token drops: under overlap TP the
    # router sees each data shard's token stream (gspmd routes globally), so
    # drop decisions would differ and the cross-impl loss check would trip
    "moe": ModelConfig("btp", Family.MOE, n_layers=2, d_model=128, n_heads=4,
                       n_kv_heads=2, d_ff=0, vocab=512,
                       moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                                     capacity_factor=4.0)),
    "mamba2": ModelConfig("btp", Family.SSM, n_layers=2, d_model=128,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab=512,
                          ssm=SSMConfig(d_state=16, head_dim=32, expand=2,
                                        chunk=32)),
}
shape = InputShape("b", 64, 8, "train")
mesh = jax.make_mesh((2, 2), ("data", "model"))
n_dev = 4
for fam, cfg in fams.items():
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    plan = ParallelPlan(remat="none", compute_dtype="float32", tp=2)
    model = build_model(cfg, plan, mesh, ("data",))
    params = model.init(jax.random.PRNGKey(0))
    pspecs = sharding.param_specs(params, cfg, plan, mesh)
    shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    gp = jax.device_put(params, shard)
    gb = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    losses = {}
    for impl in ("gspmd", "overlap"):
        if impl == "gspmd":
            lf = make_loss_fn(model, Hyper(z_loss=0.0))
        else:
            lf = make_tp_loss_fn(cfg, plan, mesh, ("data",), z_loss=0.0)
        gf = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))
        compiled = gf.lower(gp, gb).compile()
        cost = analyze_hlo(compiled.as_text(), n_dev)
        ma = compiled.memory_analysis()
        temp = getattr(ma, "temp_size_in_bytes", None) if ma else None
        loss, _ = jax.block_until_ready(compiled(gp, gb))
        losses[impl] = float(loss)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(compiled(gp, gb))
        us = (time.perf_counter() - t0) / 3 * 1e6
        toks = shape.global_batch * shape.seq_len
        print(f"ROW tp.{fam}.{impl},{us:.1f},"
              f"tokens_per_s={toks/(us/1e6):.0f};"
              f"collective_link_bytes={cost.collective_link_bytes:.0f};"
              f"hbm_bytes={cost.bytes:.0f};peak_temp_bytes={temp}",
              flush=True)
    assert abs(losses["gspmd"] - losses["overlap"]) < 1e-4, losses
print("TP_BENCH_OK", flush=True)
"""


def bench_tp():
    """tokens/sec + compiled communication/memory for ``tp_impl`` ∈
    {gspmd, overlap} × {dense, MoE, Mamba2} on a (data=2, model=2) host mesh.

    ``collective_link_bytes`` (from ``perf.hlo_cost`` over the optimized HLO)
    is the bytes-transferred headline: sequence-sharded activations +
    ring-decomposed collective matmuls vs GSPMD's per-row-GEMM all-reduces.
    Wall-times on CPU host devices only sanity-check that overlap is not
    pathological — the ring's latency win needs real accelerator DMAs.
    Runs in a subprocess (in-process code must see 1 device, per the dry-run
    contract); also asserts gspmd and overlap agree on the loss.
    """
    out = run_multidevice(_TP_BENCH_SCRIPT, 4, "TP_BENCH_OK")
    for line in out.splitlines():
        if line.startswith("ROW "):
            name, us, derived = line[4:].split(",", 2)
            emit(name, float(us), derived)


# ---------------------------------------------------------------------------
# survey §4.1.4 (context parallelism: gather vs ring at long S)

_CP_BENCH_SCRIPT = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.core.compat import shard_map
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.models.layers import init_attn
from repro.perf.hlo_cost import analyze_hlo
from repro.train import Hyper, make_loss_fn
from repro.train import executor as exlib
from repro.train.executor import make_executor_loss_fn
from repro.train.tensor_parallel import RingCtx

CP = 2
mesh = jax.make_mesh((CP,), ("cp",))
cfg = ModelConfig("bcp", Family.DENSE, n_layers=2, d_model=128, n_heads=2,
                  n_kv_heads=2, d_ff=256, vocab=512)
rng = np.random.default_rng(0)
attn_p = jax.tree.map(lambda a: a.astype(jnp.float32),
                      init_attn(jax.random.PRNGKey(0), cfg))
pspec = jax.tree.map(lambda _: P(), attn_p)


def bench_attn_block(s, mode, iters):
    # fwd+bwd of ONE attention block -- the 4.1.4 headline: ring keeps the
    # per-device working set at S/cp chunks while cp=1 / gather hold full-S
    # K/V (and the backward's full-S softmax residuals)
    x = jnp.asarray(rng.standard_normal((1, s, cfg.d_model)), jnp.float32)
    if mode == "cp1":
        def loss(p, xv):
            a = exlib.attn_block(exlib.local_context(), p, xv, cfg,
                                 positions=jnp.arange(s), dtype=jnp.float32)
            return jnp.sum(a)
        xin = x
    else:
        ctx = exlib.ParallelContext(cp=RingCtx("cp", CP), cp_impl=mode)

        def local(p, xl):
            positions = exlib.cp_local_positions(ctx, xl.shape[1])
            a = exlib.attn_block(ctx, p, xl, cfg, positions=positions,
                                 dtype=jnp.float32)
            return jax.lax.psum(jnp.sum(a), "cp")

        def loss(p, xv):
            return shard_map(local, mesh=mesh,
                             in_specs=(pspec, P(None, "cp", None)),
                             out_specs=P())(p, xv)
        xin = x[:, exlib.zigzag_permutation(s, CP)] if mode == "ring" else x
    gf = jax.jit(jax.value_and_grad(loss))
    compiled = gf.lower(attn_p, xin).compile()
    ma = compiled.memory_analysis()
    temp = getattr(ma, "temp_size_in_bytes", None) if ma else None
    cost = analyze_hlo(compiled.as_text(), CP if mode != "cp1" else 1)
    jax.block_until_ready(compiled(attn_p, xin))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(compiled(attn_p, xin))
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"ROW cp.attnblock.s{s}.{mode},{us:.1f},"
          f"tokens_per_s={s/(us/1e6):.0f};peak_temp_bytes={temp};"
          f"collective_link_bytes={cost.collective_link_bytes:.0f}",
          flush=True)
    return temp


temps = {}
for s in (4096, 16384):
    for mode in ("cp1", "gather", "ring"):
        temps[(s, mode)] = bench_attn_block(s, mode, iters=1 if s > 8192 else 2)
# the acceptance headline: ring's peak attention-block activation memory at
# S=16k sits below the cp=1 baseline (KV + softmax residuals shrink by cp).
# memory_analysis() can be unavailable on some backends — report that
# instead of tripping a TypeError on None < None
if temps[(16384, "ring")] is not None and temps[(16384, "cp1")] is not None:
    assert temps[(16384, "ring")] < temps[(16384, "cp1")], temps
    print(f"ROW cp.attnblock.s16384.ring_vs_cp1,0.0,"
          f"peak_temp_ratio={temps[(16384, 'ring')]/temps[(16384, 'cp1')]:.3f};"
          f"ring_below_cp1_baseline=True", flush=True)
else:
    print("ROW cp.attnblock.s16384.ring_vs_cp1,0.0,"
          "peak_temp_ratio=unavailable;memory_analysis_unsupported=True",
          flush=True)

# whole-model loss+grad at the short end (both impls vs the GSPMD baseline)
shape = InputShape("b", 4096, 2, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
plan0 = ParallelPlan(remat="none", compute_dtype="float32")
model = build_model(cfg, plan0)
params = model.init(jax.random.PRNGKey(0))
losses = {}
toks = shape.global_batch * shape.seq_len
for mode in ("cp1", "gather", "ring"):
    if mode == "cp1":
        lf = make_loss_fn(model, Hyper(z_loss=0.0))
    else:
        plan = ParallelPlan(remat="none", compute_dtype="float32", cp=CP,
                            cp_impl=mode)
        lf = make_executor_loss_fn(cfg, plan, mesh, (), z_loss=0.0)
    gf = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))
    compiled = gf.lower(params, batch).compile()
    ma = compiled.memory_analysis()
    temp = getattr(ma, "temp_size_in_bytes", None) if ma else None
    cost = analyze_hlo(compiled.as_text(), CP if mode != "cp1" else 1)
    loss, _ = jax.block_until_ready(compiled(params, batch))
    losses[mode] = float(loss)
    t0 = time.perf_counter()
    for _ in range(2):
        jax.block_until_ready(compiled(params, batch))
    us = (time.perf_counter() - t0) / 2 * 1e6
    print(f"ROW cp.model.dense.s4096.{mode},{us:.1f},"
          f"tokens_per_s={toks/(us/1e6):.0f};peak_temp_bytes={temp};"
          f"collective_link_bytes={cost.collective_link_bytes:.0f}",
          flush=True)
assert abs(losses["gather"] - losses["cp1"]) < 1e-4, losses
assert abs(losses["ring"] - losses["cp1"]) < 1e-4, losses
print("CP_BENCH_OK", flush=True)
"""


def bench_cp():
    """tokens/sec + compiled peak memory + collective bytes for
    ``cp_impl`` ∈ {gather, ring} vs the cp=1 baseline at S ∈ {4k, 16k}
    (survey §4.1.4, long-context training).

    The attention-block rows are the headline: at S=16k the ring path's
    compiled peak activation memory must sit measurably below the cp=1
    baseline (each device holds S/cp KV chunks and S/(2·cp) score tiles
    instead of full-S tensors) — asserted in the subprocess, recorded as the
    ``ring_vs_cp1`` row. Wall-times on CPU host devices only sanity-check
    that the ring is not pathological; the latency win needs real
    accelerator DMAs. Also asserts ring == gather == cp1 on the model loss.
    """
    out = run_multidevice(_CP_BENCH_SCRIPT, 2, "CP_BENCH_OK", timeout=2400)
    for line in out.splitlines():
        if line.startswith("ROW "):
            name, us, derived = line[4:].split(",", 2)
            emit(name, float(us), derived)


# ---------------------------------------------------------------------------
# survey §4.1.5 (expert parallelism: overlapped vs blocking all-to-all)

_EP_BENCH_SCRIPT = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, MoEConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.perf.hlo_cost import analyze_hlo
from repro.train import Hyper, make_loss_fn
from repro.train.executor import make_executor_loss_fn

EP = 2
mesh = jax.make_mesh((2, EP), ("data", "model"))
shape = InputShape("bep", 512, 4, "train")
toks = shape.global_batch * shape.seq_len

def moe_cfg(shared):
    # capacity_factor == E/top_k: no-drop, so both impls are exactly the
    # dense-dispatch math (asserted against the GSPMD baseline below)
    return ModelConfig("bep", Family.MOE, n_layers=2, d_model=128, n_heads=4,
                       n_kv_heads=2, d_ff=0, vocab=512,
                       moe=MoEConfig(num_experts=8, top_k=2, d_expert=128,
                                     num_shared_experts=shared,
                                     capacity_factor=4.0))

for fam, shared in (("olmoe", 0), ("deepseek", 1)):
    cfg = moe_cfg(shared)
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    model = build_model(cfg, ParallelPlan(remat="none",
                                          compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(0))
    lf0 = make_loss_fn(model, Hyper(z_loss=0.0))
    dense_loss, _ = jax.jit(lf0)(params, batch)
    stats = {}
    for impl in ("blocking", "overlap"):
        plan = ParallelPlan(remat="none", compute_dtype="float32", ep=EP,
                            ep_impl=impl)
        lf = make_executor_loss_fn(cfg, plan, mesh, ("data",), z_loss=0.0)
        gf = jax.jit(jax.value_and_grad(lambda p, b: lf(p, b)[0]))
        compiled = gf.lower(params, batch).compile()
        ma = compiled.memory_analysis()
        temp = getattr(ma, "temp_size_in_bytes", None) if ma else None
        cost = analyze_hlo(compiled.as_text(), mesh.size)
        a2a = cost.collective_bytes_by_kind.get("all-to-all", 0.0)
        perm = cost.collective_bytes_by_kind.get("collective-permute", 0.0)
        loss, _ = jax.block_until_ready(compiled(params, batch))
        assert abs(float(loss) - float(dense_loss)) < 2e-6, (
            fam, impl, float(loss), float(dense_loss))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(compiled(params, batch))
        us = (time.perf_counter() - t0) / 3 * 1e6
        stats[impl] = {"us": us, "a2a": a2a, "perm": perm}
        print(f"ROW ep.model.{fam}.ep{EP}.{impl},{us:.1f},"
              f"tokens_per_s={toks/(us/1e6):.0f};peak_temp_bytes={temp};"
              f"a2a_link_bytes={a2a:.0f};ppermute_link_bytes={perm:.0f}",
              flush=True)
    # the §4.1.5 headline: the overlap ring moves the entire exposed
    # dispatch/combine all-to-all onto ppermute ticks interleaved with the
    # per-peer expert-GEMM chunks — zero blocking a2a bytes remain
    overlapped = stats["blocking"]["a2a"] - stats["overlap"]["a2a"]
    assert stats["blocking"]["a2a"] > 0, stats
    assert overlapped > 0, stats
    assert stats["overlap"]["perm"] > stats["blocking"]["perm"], stats
    print(f"ROW ep.overlap_vs_blocking.{fam},0.0,"
          f"overlapped_a2a_bytes={overlapped:.0f};exposed_a2a_ratio="
          f"{stats['overlap']['a2a'] / stats['blocking']['a2a']:.3f};"
          f"tokens_ratio={stats['blocking']['us'] / stats['overlap']['us']:.3f}",
          flush=True)
print("EP_BENCH_OK", flush=True)
"""


def bench_ep():
    """tokens/sec + exchanged bytes + compiled peak memory for ``ep_impl`` ∈
    {blocking, overlap} × {OLMoE-style, DeepSeek-shared} MoE at ep=2 on a
    (data=2, model=2) host mesh (survey §4.1.5).

    The bytes rows are the headline: blocking exposes the dispatch/combine
    ``all_to_all`` pair on the critical path, the overlap ring converts all
    of it into ``ppermute`` ticks interleaved with expert-GEMM chunks
    (``overlapped_a2a_bytes`` > 0, zero exposed a2a left). Wall-times on CPU
    host devices only sanity-check the ring is not pathological — the
    latency win needs real accelerator DMAs. Both impls are asserted equal
    to the dense-dispatch GSPMD loss (no-drop capacity).
    """
    out = run_multidevice(_EP_BENCH_SCRIPT, 4, "EP_BENCH_OK", timeout=2400)
    for line in out.splitlines():
        if line.startswith("ROW "):
            name, us, derived = line[4:].split(",", 2)
            emit(name, float(us), derived)


# ---------------------------------------------------------------------------
# survey §8.3 (checkpointing latency table)

def bench_checkpoint(tmp="/tmp/repro_bench_ckpt"):
    import shutil
    for layers, tag in [(2, "small"), (8, "medium")]:
        cfg = _tiny_cfg(n_layers=layers, d_model=512, d_ff=2048, vocab=8192)
        model = build_model(cfg, ParallelPlan(compute_dtype="float32"))
        state = init_train_state(model, jax.random.PRNGKey(0))
        nbytes = sum(x.nbytes for x in jax.tree.leaves(state))
        shutil.rmtree(tmp, ignore_errors=True)
        shutil.rmtree(tmp + "_a", ignore_errors=True)
        mgr = CheckpointManager(tmp, async_persist=False)
        t0 = time.perf_counter()
        mgr.save(0, state, blocking=True)
        us_sync = (time.perf_counter() - t0) * 1e6
        mgr2 = CheckpointManager(tmp + "_a", async_persist=True)
        t0 = time.perf_counter()
        mgr2.save(1, state)                       # stall = snapshot only
        us_stall = (time.perf_counter() - t0) * 1e6
        mgr2.wait()
        t0 = time.perf_counter()
        _, _ = mgr.restore(state, step=0)
        us_restore = (time.perf_counter() - t0) * 1e6
        # double-buffered snapshot (survey §8.3.1): the stall is one jitted
        # device-side clone dispatch; host copy + persist drain off-thread.
        # Warm save first so the cloner's compile is not in the stall number.
        mgr3 = CheckpointManager(tmp + "_d", async_snapshot=True)
        mgr3.save(0, state)
        mgr3.wait()
        t0 = time.perf_counter()
        mgr3.save(1, state)
        us_db = (time.perf_counter() - t0) * 1e6
        mgr3.wait()
        emit(f"ckpt.sync.{tag}", us_sync, f"bytes={nbytes}")
        emit(f"ckpt.snapshot_stall.{tag}", us_stall,
             f"bytes={nbytes};stall_reduction={us_sync/max(us_stall,1):.1f}x")
        emit(f"ckpt.snapshot_stall.double_buffered.{tag}", us_db,
             f"bytes={nbytes};vs_blocking_snapshot="
             f"{us_stall/max(us_db,1):.1f}x")
        emit(f"ckpt.restore.{tag}", us_restore, f"bytes={nbytes}")
        shutil.rmtree(tmp, ignore_errors=True)
        shutil.rmtree(tmp + "_a", ignore_errors=True)
        shutil.rmtree(tmp + "_d", ignore_errors=True)

    # elastic reshard-restore latency (survey §8.3.2): a ZeRO-1 checkpoint
    # written on a 2x2 mesh restored onto the surviving 1x2, vs the
    # same-layout replay of the same bytes (4 forced host devices)
    script = r"""
import time, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, ModelConfig, ParallelPlan, sharding
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import shrink_mesh
from repro.models import build_model
from repro.train import init_train_state
cfg = ModelConfig("b", Family.DENSE, n_layers=4, d_model=512, n_heads=8,
                  n_kv_heads=8, d_ff=2048, vocab=8192)
plan = ParallelPlan(remat="none", compute_dtype="float32", zero_stage=1)
mesh = jax.make_mesh((2, 2), ("data", "model"))
model = build_model(cfg, plan, mesh, ("data",))
state = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
nbytes = sum(x.nbytes for x in jax.tree.leaves(state))
mgr = CheckpointManager(tempfile.mkdtemp(), async_persist=False)
mgr.save(0, state, blocking=True, plan=plan, mesh=mesh)
t0 = time.perf_counter()
_, replay = mgr.restore(state)
jax.block_until_ready(jax.tree.leaves(replay))
same_us = (time.perf_counter() - t0) * 1e6
mesh2 = shrink_mesh(mesh, "data", lost=1)
model2 = build_model(cfg, plan, mesh2, ("data",))
tmpl = init_train_state(model2, jax.random.PRNGKey(1), mesh=mesh2, plan=plan)
sh = sharding.train_state_shardings(tmpl, cfg, plan, mesh2)
assert mgr.check_plan(plan, mesh=mesh2, elastic=True) == "reshard"
t0 = time.perf_counter()
_, resharded = mgr.restore_resharded(tmpl, shardings=sh)
jax.block_until_ready(jax.tree.leaves(resharded))
reshard_us = (time.perf_counter() - t0) * 1e6
for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(resharded.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print(f"RESHARD_OK bytes={nbytes} same_us={same_us:.0f} "
      f"reshard_us={reshard_us:.0f}", flush=True)
"""
    out = run_multidevice(script, 4, "RESHARD_OK")
    import re
    m = re.search(r"bytes=(\d+) same_us=(\d+) reshard_us=(\d+)", out)
    emit("ckpt.reshard_restore.2x2_to_1x2", float(m.group(3)),
         f"bytes={m.group(1)};same_layout_us={m.group(2)};values_match=True")


# ---------------------------------------------------------------------------
# survey §8.3.1 (fast-recovery tier: RAM restore vs disk walk, peer rebuild,
# just-in-time preemption snapshot)

def bench_recover(tmp="/tmp/repro_bench_recover"):
    """Hot in-memory checkpoint tier vs the verified disk restore, the
    peer-redundant rebuild after a simulated lost host-group, and the
    just-in-time preemption snapshot against the grace budget.

    The headline row is the acceptance gate: the RAM-tier restore must be
    >= 10x faster than the disk restore of the same bytes (no file read, no
    re-verify on the primary path — the disk walk reads the npz and recomputes
    every shard digest). The rebuild row additionally asserts the
    mirror-served restore bit-matches the disk restore."""
    import shutil
    from repro.checkpoint import MemoryCheckpointTier
    from repro.ft import FlightRecorder
    from repro.ft.preempt import PreemptionGuard, choose_tier

    cfg = _tiny_cfg(n_layers=8, d_model=512, d_ff=2048, vocab=8192)
    model = build_model(cfg, ParallelPlan(compute_dtype="float32"))
    state = init_train_state(model, jax.random.PRNGKey(0))
    nbytes = sum(x.nbytes for x in jax.tree.leaves(state))
    shutil.rmtree(tmp, ignore_errors=True)

    mgr = CheckpointManager(tmp, async_persist=False)
    mgr.save(0, state, blocking=True)
    mem = MemoryCheckpointTier(keep=2, groups=4)
    t0 = time.perf_counter()
    mem.save(0, state)
    us_mem_save = (time.perf_counter() - t0) * 1e6

    def disk_restore():
        _, t = mgr.restore(state, step=0)
        jax.block_until_ready(jax.tree.leaves(t))

    def mem_restore():
        _, t = mem.restore(state, step=0)
        jax.block_until_ready(jax.tree.leaves(t))

    us_disk = timeit(disk_restore, warmup=1, iters=3)
    us_mem = timeit(mem_restore, warmup=1, iters=3)
    speedup = us_disk / max(us_mem, 1e-9)
    emit("recover.restore.disk", us_disk, f"bytes={nbytes};verify=sha256+crc32")
    emit("recover.restore.memory", us_mem,
         f"bytes={nbytes};speedup_vs_disk={speedup:.1f}x")
    assert speedup >= 10.0, (
        f"memory-tier restore only {speedup:.1f}x faster than disk "
        f"({us_mem:.0f}us vs {us_disk:.0f}us) — acceptance floor is 10x")

    # peer rebuild: zero one host-group's primaries AND the mirrors it held;
    # the surviving ring-neighbor mirrors serve its shards (digest-verified)
    lost = mem.lose_group(1)
    t0 = time.perf_counter()
    _, rebuilt = mem.restore(state, step=0)
    jax.block_until_ready(jax.tree.leaves(rebuilt))
    us_rebuild = (time.perf_counter() - t0) * 1e6
    _, from_disk = mgr.restore(state, step=0)
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(from_disk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    emit("recover.restore.memory_rebuild", us_rebuild,
         f"bytes={nbytes};lost_shards={lost};mirror_served={mem.last_rebuild};"
         f"bitmatch_disk_restore=True")

    # just-in-time preemption snapshot: the RAM save IS the snapshot the
    # grace window must absorb; choose_tier compares the measured disk
    # persist estimate against the remaining budget
    guard = PreemptionGuard(grace=30.0, signals=())
    guard.trigger()
    tier = choose_tier(guard, mgr, mem)
    emit("recover.jit_snapshot.memory", us_mem_save,
         f"bytes={nbytes};grace_s=30.0;chosen_tier={tier};"
         f"disk_est_s={mgr.snapshot_seconds + mgr.persist_seconds:.3f}")

    # flight recorder: per-event cost of the always-on black box
    fl = FlightRecorder(maxlen=256)
    t0 = time.perf_counter()
    for i in range(1000):
        fl.record("step", i, loss=1.0, grad_norm=0.5)
    us_ev = (time.perf_counter() - t0) * 1e6 / 1000
    emit("recover.flight.record", us_ev, "ring=256;per_event")
    shutil.rmtree(tmp, ignore_errors=True)


# ---------------------------------------------------------------------------
# survey §8.1/§8.2 (failure detection & recovery table)

def bench_fault_tolerance(tmp="/tmp/repro_bench_ft"):
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    cfg = _tiny_cfg()
    shape = InputShape("b", 32, 4, "train")
    ds = SyntheticDataset(cfg, shape)
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, plan, Hyper(total_steps=50)))

    mon = Monitor(min_history=4)
    t0 = time.perf_counter()
    for s in range(8):
        mon.record(s, 2.0, 1.0, now=float(s))
    a = mon.record(8, float("nan"), 1.0, now=8.0)
    us_detect = (time.perf_counter() - t0) * 1e6
    emit("ft.nan_detection", us_detect,
         f"detected={a is not None};steps_to_detect=0")

    mgr = CheckpointManager(tmp, async_persist=False)
    mgr.save(0, state, blocking=True)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    us_step = timeit(step, state, batch, warmup=1, iters=3)
    t0 = time.perf_counter()
    _, _ = mgr.restore(state)
    us_restore = (time.perf_counter() - t0) * 1e6
    k = 5
    emit("ft.recovery.restore", us_restore,
         f"replay_k{k}_us={k*us_step:.0f};total_us={us_restore + k*us_step:.0f}")
    shutil.rmtree(tmp, ignore_errors=True)


# ---------------------------------------------------------------------------
# survey §8.2 (SDC defense: integrity-audit overhead sweep)

def bench_integrity():
    """Step-time overhead of ``plan.integrity = "audit"`` per family — the
    exact bitwise param/grad checksum + cross-replica compare the SDC defense
    adds to every step (survey §8.2: algorithm-level checks vs full redundant
    compute). Asserts the audited step stays within 2× of the plain step on
    every family — the audit is one elementwise bitcast+sum pass and two
    scalar collectives, so anything worse is a regression in the checksum
    path itself (single host device: the collective part is free here, the
    checksum pass is what's measured)."""
    shape = InputShape("b", 64, 8, "train")
    fams = [
        ("dense", _tiny_cfg(n_layers=4)),
        ("moe", _tiny_cfg(n_layers=4, family=Family.MOE, d_ff=0,
                          moe=MoEConfig(num_experts=4, top_k=2, d_expert=128))),
        ("ssm", _tiny_cfg(n_layers=4, n_heads=0, n_kv_heads=0, d_ff=0,
                          family=Family.SSM,
                          ssm=SSMConfig(d_state=16, head_dim=32, expand=2))),
    ]
    toks = shape.global_batch * shape.seq_len
    for fam_name, cfg in fams:
        ds = SyntheticDataset(cfg, shape)
        batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
        times = {}
        for mode in ("off", "audit"):
            plan = ParallelPlan(remat="none", compute_dtype="float32",
                                integrity=mode)
            model = build_model(cfg, plan)
            state = init_train_state(model, jax.random.PRNGKey(0))
            step = jax.jit(make_train_step(model, plan, Hyper(total_steps=10)))
            if mode == "audit":                  # the audit must be wired in
                _, metrics = step(state, batch)
                assert float(metrics["integrity_div"]) == 0.0, metrics
            times[mode] = timeit(step, state, batch, warmup=1, iters=3)
            emit(f"integrity.{fam_name}.{mode}", times[mode],
                 f"tokens_per_s={toks/(times[mode]/1e6):.0f}")
        ratio = times["audit"] / times["off"]
        assert ratio < 2.0, (
            f"integrity audit overhead {ratio:.2f}x on {fam_name} "
            f"exceeds the 2x bound")
        emit(f"integrity.{fam_name}.overhead", times["audit"] - times["off"],
             f"ratio={ratio:.3f}x;bound=2.0x")


# ---------------------------------------------------------------------------
# survey §4.1.4 (long-context decode path)

def bench_decode():
    cfg = _tiny_cfg()
    plan = ParallelPlan(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    params = model.init(jax.random.PRNGKey(0))
    for t in (1024, 8192):
        cache = model.init_cache(4, t)
        tokens = jnp.array([1, 2, 3, 4], jnp.int32)
        fn = jax.jit(lambda p, c, tok: model.decode_step(p, c, tok,
                                                         jnp.int32(t // 2)))
        us = timeit(fn, params, cache, tokens)
        cache_bytes = sum(x.nbytes for x in jax.tree.leaves(cache))
        emit(f"decode.ctx{t}", us, f"cache_bytes={cache_bytes}")


# ---------------------------------------------------------------------------
# survey §8.1 (fail-slow defense: detection latency + rebalance recovery)

def bench_straggler():
    """Fail-slow economics on a 2-stage pipeline (survey §8.1, Malleus):
    tokens/s in three regimes — healthy baseline, degraded (a seeded ``slow``
    fault adds per-layer host delay to stage 1), and rebalanced (the Malleus
    ``pp_layout`` chosen by the straggler ladder) — plus the detector's
    attribution latency in steps. Asserts the rebalanced regime is strictly
    faster than the degraded one and recovers >= 25% of the lost step-time
    overhead (theoretical for this shape: shedding 1 of stage 1's 2 layers
    halves the injected delay, ~50%; the bound leaves headroom for host
    noise)."""
    script = """
import dataclasses, tempfile, time
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import (Monitor, RemeshSpec, StragglerDetector, StragglerTimer,
                      run_with_recovery)
from repro.ft.inject import FaultSpec, armed
from repro.models import build_model
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("bench", Family.DENSE, n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
mesh = jax.make_mesh((2, 2), ("pod", "data"))
plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                    microbatches=4)
SEQ, BATCH = 32, 8
ds = SyntheticDataset(cfg, InputShape("b", SEQ, BATCH, "train"))
get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
model = build_model(cfg, ParallelPlan(remat="none", compute_dtype="float32"))
state0 = {"params": model.init(jax.random.PRNGKey(0))}

def make_step(pl):
    lf = pipelined_loss_fn(cfg, pl, mesh, ("data",))
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p, b: lf(p, b)[0])(state["params"], batch)
        params = jax.tree.map(lambda p, g: p - 1e-3 * g,
                              state["params"], grads)
        return {"params": params}, {"loss": loss,
                                    "grad_norm": jnp.float32(1.0)}
    return jax.jit(step)

# the injected per-layer delay must dominate the healthy step time for the
# regime arithmetic to be about the fault (shedding a layer also shifts
# compute onto the bottleneck stage — the real Malleus tradeoff)
SLEEP, FAULT_STEP, CONFIRM = 0.15, 6, 3
fault = lambda: FaultSpec("pp.stage.tick", "slow", step=0, span=10**6,
                          rank=1, sleep_s=SLEEP)

def regime(layout, faulted, n=6):
    '''Median full step wall time (jitted step + timer fan-out, which
    executes any armed slow delay) under the given layout/fault regime.'''
    pl = dataclasses.replace(plan, pp_layout=layout)
    step_fn = make_step(pl)
    timer = StragglerTimer(cfg=cfg, plan=pl,
                           detector=StragglerDetector(confirm=10**6))
    st = state0
    st, m = step_fn(st, get_batch(0)); float(m["loss"])   # compile
    ts = []
    specs = [fault()] if faulted else []
    with armed(specs):
        for s in range(1, n):
            b = get_batch(s)
            t0 = time.perf_counter()
            st, m = step_fn(st, b)
            float(m["loss"])
            timer.after_step(s, time.perf_counter() - t0)
            ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]

t_base = regime(None, False)
t_deg = regime(None, True)
t_reb = regime((3, 1), True)

# the e2e ladder, for the detection latency + the applied layout
detector = StragglerDetector(window=8, factor=2.0, confirm=CONFIRM,
                             min_seconds=1e-3)
timer = StragglerTimer(cfg=cfg, plan=plan, detector=detector)
applied = []
def rebalance(layout):
    applied.append(tuple(layout))
    pl2 = dataclasses.replace(plan, pp_layout=tuple(layout))
    return RemeshSpec(train_step=make_step(pl2), state_template=state0,
                      plan=pl2, mesh=mesh)
ckpt = CheckpointManager(tempfile.mkdtemp(), keep=4, async_persist=False)
with armed([dataclasses.replace(fault(), step=FAULT_STEP)]):
    final, report = run_with_recovery(
        state0, make_step(plan), get_batch, 14, ckpt,
        Monitor(hang_min_seconds=60.0), ckpt_every=3, plan=plan, mesh=mesh,
        policy=RecoveryPolicy(straggler="rebalance", max_restores=4,
                              straggler_confirm=CONFIRM),
        straggler=timer, rebalance=rebalance)
strag = [a for a in report.anomalies if a.kind == "straggler"]
assert strag and report.rebalances == 1, (strag, report)
assert applied[0] == (3, 1), applied
detect_steps = strag[0].step - FAULT_STEP + 1
assert detect_steps <= CONFIRM, (strag[0].step, FAULT_STEP)

toks = SEQ * BATCH
assert t_reb < t_deg, (t_reb, t_deg)      # rebalance strictly recovers
frac = (t_deg - t_reb) / max(t_deg - t_base, 1e-9)
assert frac >= 0.25, (t_base, t_deg, t_reb, frac)
print(f"BENCH detect_steps={detect_steps} base_us={t_base*1e6:.1f} "
      f"deg_us={t_deg*1e6:.1f} reb_us={t_reb*1e6:.1f} "
      f"tps_base={toks/t_base:.0f} tps_deg={toks/t_deg:.0f} "
      f"tps_reb={toks/t_reb:.0f} frac={frac:.3f}")
print("STRAGGLER_BENCH_OK", flush=True)
"""
    out = run_multidevice(script, 4, "STRAGGLER_BENCH_OK", timeout=1200)
    kv = dict(tok.split("=") for line in out.splitlines()
              if line.startswith("BENCH ") for tok in line.split()[1:])
    emit("straggler.detect.latency", float(kv["detect_steps"]),
         f"steps={kv['detect_steps']};confirm=3")
    emit("straggler.tokens_per_s.baseline", float(kv["base_us"]),
         f"tokens_per_s={kv['tps_base']}")
    emit("straggler.tokens_per_s.degraded", float(kv["deg_us"]),
         f"tokens_per_s={kv['tps_deg']};fault=slow@stage1")
    emit("straggler.tokens_per_s.rebalanced", float(kv["reb_us"]),
         f"tokens_per_s={kv['tps_reb']};pp_layout=(3,1)")
    emit("straggler.rebalance.recovery",
         float(kv["deg_us"]) - float(kv["reb_us"]),
         f"overhead_recovered={kv['frac']};bound=0.25;theoretical~0.5")


BENCHES = {
    "attention": bench_attention,
    "memory": bench_memory_sharding,
    "train": bench_train_plans,
    "moe": bench_moe,
    "ssd": bench_ssd,
    "tp": bench_tp,
    "cp": bench_cp,
    "ep": bench_ep,
    "trainstep": bench_trainstep,
    "ckpt": bench_checkpoint,
    "recover": bench_recover,
    "ft": bench_fault_tolerance,
    "integrity": bench_integrity,
    "decode": bench_decode,
    "straggler": bench_straggler,
}


# ---------------------------------------------------------------------------
# --quick: CI smoke over every fused Pallas kernel


def bench_quick():
    """One tiny shape per fused op, fwd+bwd through ``pallas_call`` in
    interpret mode — catches kernel regressions that only break under
    ``pallas_call`` (BlockSpec/grid/scratch plumbing) without a TPU.
    Raises on non-finite values so scripts/ci.sh fails loudly.
    """
    from repro.kernels import (dispatch_expert_gemm, dispatch_ssd_scan,
                               flash_attention)
    rng = np.random.default_rng(0)

    def check(name, val, grads):
        assert np.isfinite(float(val)), f"{name}: non-finite loss"
        for g in jax.tree.leaves(grads):
            assert bool(jnp.isfinite(g).all()), f"{name}: non-finite grads"

    q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    attn = jax.value_and_grad(
        lambda q, k, v: jnp.sum(flash_attention(
            q, k, v, window=16, softcap=20.0, block_q=32, block_k=32,
            interpret=True)), argnums=(0, 1, 2))
    us = timeit(lambda: check("attention", *attn(q, q, q)), warmup=0, iters=1)
    emit("quick.attention.fwdbwd", us, "interpret=True;finite=True")

    x = jnp.asarray(rng.standard_normal((2, 32, 24)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((2, 24, 16)), jnp.float32)
    gs = jnp.asarray([20, 0], jnp.int32)
    gemm = jax.value_and_grad(
        lambda x, w: jnp.sum(dispatch_expert_gemm(
            x, w, gs, impl="pallas", block_c=16, block_f=16, block_d=16,
            interpret=True)), argnums=(0, 1))
    us = timeit(lambda: check("expert_gemm", *gemm(x, w)), warmup=0, iters=1)
    emit("quick.expert_gemm.fwdbwd", us, "interpret=True;finite=True")

    b, l, h, p, g, n, chunk = 1, 40, 2, 8, 1, 8, 16   # unaligned l -> padded
    xs = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dts = jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    ssd = jax.value_and_grad(
        lambda x, dt, B, C: jnp.sum(dispatch_ssd_scan(
            x, dt, A, B, C, chunk=chunk, impl="pallas", interpret=True)[0]),
        argnums=(0, 1, 2, 3))
    us = timeit(lambda: check("ssd", *ssd(xs, dts, B, C)), warmup=0, iters=1)
    emit("quick.ssd.fwdbwd", us, "interpret=True;finite=True")

    # memory-lean train step: one jitted step under the production recipe
    # (selective remat) with compiled-memory introspection — catches remat
    # policy / ZeRO plumbing regressions without a mesh
    cfg = _tiny_cfg()
    shape = InputShape("b", 32, 4, "train")
    ds = SyntheticDataset(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    plan = ParallelPlan(remat="selective", compute_dtype="float32")
    model = build_model(cfg, plan)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, plan, Hyper(total_steps=10)))
    compiled = step.lower(state, batch).compile()
    ma = compiled.memory_analysis()
    temp = getattr(ma, "temp_size_in_bytes", None) if ma else None

    def run_step():
        _, metrics = compiled(state, batch)
        assert np.isfinite(float(metrics["loss"])), "trainstep: non-finite loss"
        return metrics["loss"]

    us = timeit(run_step, warmup=0, iters=1)
    emit("quick.trainstep.selective", us,
         f"remat=selective;finite=True;peak_temp_bytes={temp}")

    # overlap-TP smoke: ring collective matmuls + sequence-sharded activations
    # must reproduce the GSPMD loss/grads on a 2-way model mesh
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.tensor_parallel import make_tp_loss_fn
cfg = ModelConfig("q", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
shape = InputShape("q", 16, 4, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
mesh = jax.make_mesh((1, 2), ("data", "model"))
plan = ParallelPlan(remat="none", compute_dtype="float32", tp=2,
                    tp_impl="overlap")
model = build_model(cfg, plan)
params = model.init(jax.random.PRNGKey(0))
lf_g = make_loss_fn(model, Hyper(z_loss=1e-4))
lf_o = make_tp_loss_fn(cfg, plan, mesh, ("data",), z_loss=1e-4)
lg, gg = jax.jit(jax.value_and_grad(lambda p, b: lf_g(p, b)[0]))(params, batch)
lo, go = jax.jit(jax.value_and_grad(lambda p, b: lf_o(p, b)[0]))(params, batch)
assert abs(float(lg) - float(lo)) < 1e-5, (float(lg), float(lo))
for a, b in zip(jax.tree.leaves(gg), jax.tree.leaves(go)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-6)
print("TP_OK", flush=True)
"""
    us = timeit(lambda: run_multidevice(script, 2, "TP_OK", timeout=900),
                warmup=0, iters=1)
    emit("quick.tp.overlap", us, "mesh=1x2;grads_match_gspmd=True")

    # ring context-parallel smoke: zigzag ring attention + executor loss on a
    # 2-way cp mesh must reproduce the single-device loss/grads
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.executor import make_executor_loss_fn
cfg = ModelConfig("q", Family.DENSE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
shape = InputShape("q", 16, 4, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
mesh = jax.make_mesh((1, 2), ("data", "cp"))
plan = ParallelPlan(remat="none", compute_dtype="float32", cp=2,
                    cp_impl="ring")
model = build_model(cfg, plan)
params = model.init(jax.random.PRNGKey(0))
lf_g = make_loss_fn(model, Hyper(z_loss=1e-4))
lf_c = make_executor_loss_fn(cfg, plan, mesh, ("data",), z_loss=1e-4)
lg, gg = jax.jit(jax.value_and_grad(lambda p, b: lf_g(p, b)[0]))(params, batch)
lc, gc = jax.jit(jax.value_and_grad(lambda p, b: lf_c(p, b)[0]))(params, batch)
assert abs(float(lg) - float(lc)) < 1e-5, (float(lg), float(lc))
for a, b in zip(jax.tree.leaves(gg), jax.tree.leaves(gc)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-6)
print("CP_OK", flush=True)
"""
    us = timeit(lambda: run_multidevice(script, 2, "CP_OK", timeout=900),
                warmup=0, iters=1)
    emit("quick.cp.ring", us, "mesh=1x2;grads_match_single_device=True")

    # expert-parallel smoke: the overlapped dispatch/combine a2a ring on a
    # 2-way expert mesh must reproduce the dense-dispatch loss/grads
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import Family, InputShape, ModelConfig, MoEConfig, ParallelPlan
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.train import Hyper, make_loss_fn
from repro.train.executor import make_executor_loss_fn
cfg = ModelConfig("q", Family.MOE, n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=0, vocab=128,
                  moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                                capacity_factor=2.0))
shape = InputShape("q", 16, 4, "train")
ds = SyntheticDataset(cfg, shape)
batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
mesh = jax.make_mesh((1, 2), ("data", "model"))
plan = ParallelPlan(remat="none", compute_dtype="float32", ep=2,
                    ep_impl="overlap")
model = build_model(cfg, plan)
params = model.init(jax.random.PRNGKey(0))
lf_g = make_loss_fn(model, Hyper(z_loss=1e-4))
lf_e = make_executor_loss_fn(cfg, plan, mesh, ("data",), z_loss=1e-4)
lg, gg = jax.jit(jax.value_and_grad(lambda p, b: lf_g(p, b)[0]))(params, batch)
le, ge = jax.jit(jax.value_and_grad(lambda p, b: lf_e(p, b)[0]))(params, batch)
assert abs(float(lg) - float(le)) < 1e-5, (float(lg), float(le))
for a, b in zip(jax.tree.leaves(gg), jax.tree.leaves(ge)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-6)
print("EP_OK", flush=True)
"""
    us = timeit(lambda: run_multidevice(script, 2, "EP_OK", timeout=900),
                warmup=0, iters=1)
    emit("quick.ep.overlap", us, "mesh=1x2;grads_match_dense_dispatch=True")

    # elastic recovery smoke: hang on a 2x2 ZeRO-1 run -> remesh to 1x2 ->
    # reshard-restore -> the finished loss sequence bit-matches a reference
    # that re-laid-out at the same step boundary
    script = r"""
import time, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy, sharding)
from repro.data import SyntheticDataset
from repro.ft import Monitor, RemeshSpec, run_with_recovery
from repro.launch.mesh import shrink_mesh
from repro.models import build_model
from repro.train import Hyper, init_train_state, make_train_step
cfg = ModelConfig("q", Family.DENSE, n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64)
plan = ParallelPlan(remat="none", compute_dtype="float32", zero_stage=1)
hyper = Hyper(peak_lr=1e-3, total_steps=20, z_loss=0.0)
ds = SyntheticDataset(cfg, InputShape("q", 16, 8, "train"))
get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
mesh = jax.make_mesh((2, 2), ("data", "model"))
model = build_model(cfg, plan, mesh, ("data",))
state0 = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
step_big = jax.jit(make_train_step(model, plan, hyper, mesh=mesh))
mesh2 = shrink_mesh(mesh, "data", lost=1)
model2 = build_model(cfg, plan, mesh2, ("data",))
tmpl = init_train_state(model2, jax.random.PRNGKey(1), mesh=mesh2, plan=plan)
sh = sharding.train_state_shardings(tmpl, cfg, plan, mesh2)
step_small = jax.jit(make_train_step(model2, plan, hyper, mesh=mesh2))
tmpl = jax.tree.map(jax.device_put, tmpl, sh)
jax.block_until_ready(step_small(tmpl, get_batch(0))[0].params)
fired = {"n": 0}
def injector(step, st):
    if step == 7 and fired["n"] == 0:
        fired["n"] = 1
        time.sleep(1.0)
    return st
ckpt = CheckpointManager(tempfile.mkdtemp(), async_persist=False)
final, report = run_with_recovery(
    state0, step_big, get_batch, 10, ckpt,
    Monitor(min_history=3, hang_min_seconds=0.3), ckpt_every=3,
    plan=plan, mesh=mesh, policy=RecoveryPolicy(hang="remesh"),
    fault_injector=injector, remesh=lambda: RemeshSpec(
        train_step=step_small, state_template=tmpl, shardings=sh,
        plan=plan, mesh=mesh2))
assert report.remeshes == 1 and report.actions == [(7, "hang", "remesh")]
ref = init_train_state(model, jax.random.PRNGKey(0), mesh=mesh, plan=plan)
ref_losses = []
for s in range(6):
    ref, m = step_big(ref, get_batch(s))
    ref_losses.append(float(m["loss"]))
ref = jax.tree.map(jax.device_put, ref, sh)
for s in range(6, 10):
    ref, m = step_small(ref, get_batch(s))
    ref_losses.append(float(m["loss"]))
assert report.losses == ref_losses, (report.losses, ref_losses)
print("ELASTIC_OK", flush=True)
"""
    us = timeit(lambda: run_multidevice(script, 4, "ELASTIC_OK", timeout=900),
                warmup=0, iters=1)
    emit("quick.ft.elastic", us,
         "mesh=2x2_to_1x2;remesh=1;losses_bitmatch_reference=True")

    # fail-slow smoke (survey §8.1): a seeded slow fault on pipeline stage 1
    # must be attributed (rank, compute) within the confirm window and the
    # straggler ladder must rebalance pp_layout through an elastic
    # checkpoint reshard restore, completing the run on the uneven layout
    script = """
import dataclasses, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.core import (Family, InputShape, ModelConfig, ParallelPlan,
                        RecoveryPolicy)
from repro.data import SyntheticDataset
from repro.ft import (Monitor, RemeshSpec, StragglerDetector, StragglerTimer,
                      run_with_recovery)
from repro.ft.inject import FaultSpec, armed
from repro.models import build_model
from repro.train.pipeline import pipelined_loss_fn

cfg = ModelConfig("q", Family.DENSE, n_layers=4, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64)
mesh = jax.make_mesh((2, 2), ("pod", "data"))
plan = ParallelPlan(remat="none", compute_dtype="float32", pp=2,
                    microbatches=4)
ds = SyntheticDataset(cfg, InputShape("q", 16, 8, "train"))
get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
model = build_model(cfg, ParallelPlan(remat="none", compute_dtype="float32"))
state0 = {"params": model.init(jax.random.PRNGKey(0))}

def make_step(pl):
    lf = pipelined_loss_fn(cfg, pl, mesh, ("data",))
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p, b: lf(p, b)[0])(state["params"], batch)
        params = jax.tree.map(lambda p, g: p - 1e-3 * g,
                              state["params"], grads)
        return {"params": params}, {"loss": loss,
                                    "grad_norm": jnp.float32(1.0)}
    return jax.jit(step)

detector = StragglerDetector(window=8, factor=2.0, confirm=2,
                             min_seconds=1e-3)
timer = StragglerTimer(cfg=cfg, plan=plan, detector=detector)
applied = []
def rebalance(layout):
    applied.append(tuple(layout))
    pl2 = dataclasses.replace(plan, pp_layout=tuple(layout))
    return RemeshSpec(train_step=make_step(pl2), state_template=state0,
                      plan=pl2, mesh=mesh)
ckpt = CheckpointManager(tempfile.mkdtemp(), keep=4, async_persist=False)
with armed([FaultSpec("pp.stage.tick", "slow", step=5, span=999, rank=1,
                      sleep_s=0.04)]):
    final, report = run_with_recovery(
        state0, make_step(plan), get_batch, 12, ckpt,
        Monitor(hang_min_seconds=60.0), ckpt_every=3, plan=plan, mesh=mesh,
        policy=RecoveryPolicy(straggler="rebalance", max_restores=4,
                              straggler_confirm=2),
        straggler=timer, rebalance=rebalance)
strag = [a for a in report.anomalies if a.kind == "straggler"]
assert strag and strag[0].step <= 5 + 2, (strag, report)
assert "rank=1" in strag[0].detail and "class=compute" in strag[0].detail
assert report.rebalances == 1 and applied[0] == (3, 1), (report, applied)
assert report.steps_done == 12 and np.isfinite(report.losses[-1])
print("STRAGGLER_OK", flush=True)
"""
    us = timeit(lambda: run_multidevice(script, 4, "STRAGGLER_OK",
                                        timeout=900),
                warmup=0, iters=1)
    emit("quick.ft.straggler", us,
         "fault=slow@stage1;attributed=rank1_compute;"
         "rebalance=(3,1);reshard_restore=True")

    # chaos smoke: a dropped shard write corrupts the newest checkpoint, a
    # bit flip injected into the state three steps later forces a rollback —
    # recovery must detect the corruption (CRC mismatch), fall back to the
    # previous intact checkpoint, and land bit-identical to the fault-free
    # schedule (survey §8.2: fail-slow/SDC defenses must not change
    # convergence)
    import tempfile
    from repro.checkpoint import store as ckpt_store
    from repro.core import ParallelPlan as _PP
    from repro.ft import RecoveryPolicy, run_with_recovery
    from repro.ft.inject import FaultSpec, armed, make_injector

    cfg = _tiny_cfg(n_layers=2, d_model=32, d_ff=64, vocab=64)
    plan = _PP(remat="none", compute_dtype="float32")
    model = build_model(cfg, plan)
    ds = SyntheticDataset(cfg, InputShape("t", 16, 4, "train"))
    get_batch = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
    step = jax.jit(make_train_step(model, plan, Hyper(total_steps=30)))
    state0 = init_train_state(model, jax.random.PRNGKey(0))
    ckpt = ckpt_store.CheckpointManager(
        tempfile.mkdtemp(), keep=3, async_persist=False)
    injector = make_injector(
        [FaultSpec("train.step", "bitflip", step=13)])

    def chaos_run():
        with armed([FaultSpec("ckpt.shard_write", "drop_write", step=10)]):
            final, report = run_with_recovery(
                state0, step, get_batch, 15, ckpt,
                Monitor(min_history=4, hang_min_seconds=30.0),
                ckpt_every=5, plan=plan, fault_injector=injector,
                policy=RecoveryPolicy())
        assert report.ckpt_fallbacks == 1, report
        # a high-exponent bit flip lands as a spike or an inf/nan loss
        # depending on where it hits — either way the policy rolls back
        assert any(s == 13 and k in ("nan", "spike") and a == "rollback"
                   for s, k, a in report.actions), report.actions
        ref = init_train_state(model, jax.random.PRNGKey(0))
        for s in range(15):
            ref, _ = step(ref, get_batch(s))
        for a, b in zip(jax.tree.leaves(final.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    us = timeit(chaos_run, warmup=0, iters=1)
    emit("quick.ft.chaos", us,
         "faults=drop_write+bitflip;fallback=1;params_bitmatch_reference=True")

    # preemption smoke (survey §8.3.1): a preemption notice mid-run must
    # flush the checkpoint store, take a just-in-time snapshot inside the
    # grace budget, write a PREEMPTED marker, and return cleanly — then a
    # resume consumes the marker and lands bit-identical to the fault-free
    # schedule
    from repro.checkpoint import MemoryCheckpointTier
    from repro.ft import FlightRecorder
    from repro.ft.preempt import PreemptionGuard, read_marker

    pdir = tempfile.mkdtemp()
    pckpt = ckpt_store.CheckpointManager(pdir, keep=3, async_persist=False)
    flight = FlightRecorder(maxlen=64, path=f"{pdir}/flight.json")
    guard = PreemptionGuard(grace=30.0, signals=())

    def notice(s, st):
        if s == 8:
            guard.trigger()              # stands in for the cloud's SIGTERM
        return st

    def preempt_run():
        _, rep = run_with_recovery(
            state0, step, get_batch, 15, pckpt,
            Monitor(min_history=1000, hang_min_seconds=60.0), ckpt_every=5,
            plan=plan, fault_injector=notice, policy=RecoveryPolicy(),
            mem_ckpt=MemoryCheckpointTier(keep=2, groups=2),
            preempt=guard, flight=flight)
        assert rep.preempted and rep.preempt_step == 9, rep
        assert read_marker(pdir) is not None
        resumed, _ = run_with_recovery(
            state0, step, get_batch, 15, pckpt,
            Monitor(min_history=1000, hang_min_seconds=60.0), ckpt_every=5,
            plan=plan, policy=RecoveryPolicy(), resume=True)
        assert read_marker(pdir) is None     # consumed on resume
        ref = init_train_state(model, jax.random.PRNGKey(0))
        for s in range(15):
            ref, _ = step(ref, get_batch(s))
        for a, b in zip(jax.tree.leaves(resumed.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    us = timeit(preempt_run, warmup=0, iters=1)
    emit("quick.ft.preempt", us,
         "preempt_step=9;marker_consumed=True;params_bitmatch_reference=True")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="fused-kernel fwd+bwd smoke only (one shape per op, "
                         "interpret mode) — the scripts/ci.sh regression gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows to PATH as JSON "
                         "(machine-readable perf trajectory)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.quick:
        bench_quick()                 # --only doesn't apply to the CI smoke
    else:
        for name, fn in BENCHES.items():
            if args.only and not name.startswith(args.only):
                continue
            fn()
    if args.json:
        import json
        recs = []
        for row in ROWS:
            name, us, derived = row.split(",", 2)
            recs.append({"name": name, "us_per_call": float(us),
                         "derived": derived})
        # one-line perf delta vs the previous run of this JSON, so the
        # trajectory is visible in CI logs before the file is overwritten.
        # A missing/unreadable/mismatched previous JSON (first run of a new
        # bench, e.g. BENCH_cp.json) must not error — note it and move on.
        try:
            with open(args.json) as f:
                prev = {r["name"]: r["us_per_call"] for r in json.load(f)}
        except (OSError, json.JSONDecodeError, KeyError, TypeError):
            prev = {}
        deltas = [(r["us_per_call"] - prev[r["name"]]) / prev[r["name"]]
                  for r in recs if prev.get(r["name"])]
        if deltas:
            avg = sum(deltas) / len(deltas) * 100
            worst = max(deltas) * 100
            print(f"perf delta vs previous {args.json}: "
                  f"avg {avg:+.1f}% us_per_call, worst {worst:+.1f}% "
                  f"({len(deltas)} shared rows)")
        else:
            print(f"perf delta vs previous {args.json}: no previous rows "
                  f"(first run) — skipping")
        with open(args.json, "w") as f:
            json.dump(recs, f, indent=1)
        print(f"wrote {len(recs)} rows to {args.json}")


if __name__ == "__main__":
    main()
